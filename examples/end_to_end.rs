//! End-to-end driver: exercises every layer of the system on the real
//! trained `small` model (EXPERIMENTS.md §End-to-end records a run).
//!
//!   1. load the JAX-trained checkpoint + synthetic corpus (build-time L2)
//!   2. verify native-vs-PJRT logits parity (L3 <-> L2/L1 via HLO)
//!   3. calibrate + quantize with RTN / GPTQ / GPTVQ 1D/2D/4D at ~2.25 bpv
//!   4. evaluate perplexity + zero-shot probes for each
//!   5. pack the best VQ model into GVQMODL1 and serve generation from it
//!
//!     cargo run --release --example end_to_end

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_dir, ExpContext};
use gptvq::report::{fmt_f, Table};
use gptvq::runtime::{Arg, Runtime};
use gptvq::serve::{Engine, GenRequest, ServeBackend};

fn gptvq_cfg(d: usize, bits: u32) -> GptvqConfig {
    GptvqConfig::for_setting(d, bits, 0.25)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "small".into());
    let ctx = ExpContext::load(&preset)?;
    println!(
        "[1/5] loaded preset={preset}: {} quantizable weights, corpus {}+{} tokens",
        ctx.model.quantizable_weights(),
        ctx.train.len(),
        ctx.valid.len()
    );

    // ---- 2. PJRT parity (skipped when built without the pjrt feature) -----
    let dir = artifacts_dir();
    match Runtime::cpu(&dir) {
        Ok(mut rt) => {
            let logits_file = format!("model_logits_{preset}.hlo.txt");
            let toks: Vec<Vec<u8>> = vec![ctx.valid.tokens[..64].to_vec()];
            let mut args = vec![Arg::tokens_2d(&toks)?];
            args.push(Arg::from_matrix(&ctx.model.embed));
            for l in &ctx.model.layers {
                args.push(Arg::from_vec_f64(&l.ln_attn));
                args.push(Arg::from_matrix(&l.wq));
                args.push(Arg::from_matrix(&l.wk));
                args.push(Arg::from_matrix(&l.wv));
                args.push(Arg::from_matrix(&l.wo));
                args.push(Arg::from_vec_f64(&l.ln_ffn));
                args.push(Arg::from_matrix(&l.w_gate));
                args.push(Arg::from_matrix(&l.w_up));
                args.push(Arg::from_matrix(&l.w_down));
            }
            args.push(Arg::from_vec_f64(&ctx.model.final_norm));
            args.push(Arg::from_matrix(&ctx.model.head));
            let hlo_out = rt.execute(&logits_file, &args)?;
            let native = gptvq::model::forward::forward_logits(&ctx.model, &toks[0]);
            let v = ctx.model.cfg.vocab;
            let mut max_div = 0f64;
            for t in 0..64 {
                for c in 0..v {
                    max_div =
                        max_div.max((native.get(t, c) - hlo_out[0].data[t * v + c] as f64).abs());
                }
            }
            println!(
                "[2/5] PJRT ({}) logits parity vs native rust forward: max |diff| = {max_div:.2e}",
                rt.platform()
            );
            assert!(max_div < 5e-3, "parity failure");
        }
        Err(e) => println!("[2/5] PJRT parity skipped: {e}"),
    }

    // ---- 3+4. quantize + evaluate ------------------------------------------
    let fp_ppl = ctx.fp_perplexity();
    let fp_zero = ctx.zero_shot(&ctx.model, 40);
    let avg = |xs: &[(String, f64)]| xs.iter().map(|x| x.1).sum::<f64>() / xs.len().max(1) as f64;

    let mut t = Table::new(
        "end-to-end: W2-regime quantization of the trained byte-LM",
        &["method", "bpv", "wiki-ppl", "zs-avg", "quant s"],
    );
    t.row(&["FP32".into(), "32".into(), fmt_f(fp_ppl), fmt_f(avg(&fp_zero)), "-".into()]);

    let methods = vec![
        Method::Rtn { bits: 2, group_size: 64 },
        Method::Gptq { bits: 2, group_size: 64 },
        Method::Gptvq(gptvq_cfg(1, 2)),
        Method::Gptvq(gptvq_cfg(2, 2)),
        Method::Gptvq(gptvq_cfg(4, 2)),
    ];
    let mut best: Option<gptvq::report::experiments::QuantRun> = None;
    for m in methods {
        let run = ctx.run_method(m)?;
        let zs = ctx.zero_shot(&run.model, 40);
        t.row(&[
            run.method.clone(),
            fmt_f(run.bpv),
            fmt_f(run.ppl),
            fmt_f(avg(&zs)),
            fmt_f(run.quantize_seconds),
        ]);
        println!("[3/5] {} -> ppl {:.3}", run.method, run.ppl);
        let better = best.as_ref().map(|b| run.ppl < b.ppl && run.vq_model.is_some()).unwrap_or(run.vq_model.is_some());
        if better {
            best = Some(run);
        }
    }
    t.emit("end_to_end");

    // ---- 5. pack + serve ----------------------------------------------------
    let best = best.expect("at least one VQ run");
    let vq = best.vq_model.as_ref().unwrap();
    let path = std::env::temp_dir().join("gptvq_end_to_end.gvq");
    vq.save(&path)?;
    let packed_bytes: usize = vq.linears.values().map(|l| l.packed_bytes()).sum();
    println!(
        "[5/5] packed best VQ model ({}) to {} — {:.2} MB of VQ payload ({:.3} bpv)",
        best.method,
        path.display(),
        packed_bytes as f64 / 1e6,
        8.0 * packed_bytes as f64 / best.total_weights as f64,
    );
    let loaded = gptvq::vqformat::VqModel::load(&path)?;
    // serve straight from the packed container: fused LUT decode-matmul,
    // KV-cached decode, Engine-scheduled continuous batching
    let backend = ServeBackend::fused(&ctx.model, loaded);
    let backend_name = backend.name();
    let mut engine = Engine::new(backend, 4);
    for (id, prompt) in ["The man went to", "Every good child", "This work and the", "A group of people"]
        .iter()
        .enumerate()
    {
        engine.submit(GenRequest::new(id as u64, prompt.as_bytes().to_vec(), 24))?;
    }
    let stats = engine.run_to_completion()?;
    println!(
        "served {} requests from the packed model ({} backend): {:.1} tok/s, \
         latency p50 {:.3}s / p95 {:.3}s / p99 {:.3}s, ttft p95 {:.3}s",
        stats.requests,
        backend_name,
        stats.tokens_per_second(),
        stats.p50_latency(),
        stats.p95_latency(),
        stats.p99_latency(),
        stats.ttft_percentile(95.0)
    );
    let sample_session = engine.submit(GenRequest::new(99, b"The man went to".to_vec(), 32))?;
    engine.run_to_completion()?;
    let sample = sample_session.response().expect("sample finished").output;
    println!("sample continuation: {:?}", String::from_utf8_lossy(&sample));
    println!("end_to_end OK");
    Ok(())
}
