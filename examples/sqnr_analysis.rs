//! Figure 2 analysis: SQNR of uniform / 1D / 2D / 4D VQ grids at equal
//! overhead on the trained model's weight matrices (pure grid fits — the
//! figure isolates representational accuracy, not error feedback).
//!
//!     cargo run --release --example sqnr_analysis

use gptvq::eval::sqnr_model;
use gptvq::quant::bpv::{centroids_for, group_size_for_overhead};
use gptvq::quant::kmeans::kmeans_vq_quantize;
use gptvq::quant::uniform::rtn_quantize;
use gptvq::report::experiments::ExpContext;
use gptvq::report::{fmt_f, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "small".into());
    let ctx = ExpContext::load(&preset)?;
    let subset: Vec<_> = ctx.model.quant_targets();
    let originals: Vec<_> = subset.iter().map(|&(l, k)| ctx.model.linear(l, k).transpose()).collect();

    for bits in [2u32, 3] {
        let mut t = Table::new(
            format!("SQNR vs quantizer dimensionality at {bits} bits/dim (Fig 2)"),
            &["quantizer", "sqnr dB"],
        );
        let uni: Vec<_> = originals.iter().map(|w| rtn_quantize(w, bits, 64).dequantize()).collect();
        let pairs: Vec<(&_, &_)> = originals.iter().zip(uni.iter()).collect();
        t.row(&["uniform".into(), fmt_f(sqnr_model(&pairs))]);

        for d in [1usize, 2] {
            let k = centroids_for(d, bits);
            let gs = group_size_for_overhead(d, k, 8, None, 0.25).unwrap();
            let q: Vec<_> = originals
                .iter()
                .map(|w| kmeans_vq_quantize(w, d, k, gs, 256, None, 40, 0))
                .collect();
            let pairs: Vec<(&_, &_)> = originals.iter().zip(q.iter()).collect();
            t.row(&[format!("VQ {d}D"), fmt_f(sqnr_model(&pairs))]);
        }
        // 4D only at 2 bits (k = 4096 at 3 bits/dim is out of scale here)
        if bits == 2 {
            let k = centroids_for(4, bits);
            let gs = group_size_for_overhead(4, k, 8, None, 0.25).unwrap();
            let q: Vec<_> = originals
                .iter()
                .map(|w| kmeans_vq_quantize(w, 4, k, gs, 256, None, 40, 0))
                .collect();
            let pairs: Vec<(&_, &_)> = originals.iter().zip(q.iter()).collect();
            t.row(&["VQ 4D".into(), fmt_f(sqnr_model(&pairs))]);
        }
        t.emit(&format!("sqnr_analysis_b{bits}"));
    }
    println!("expected shape (paper Fig 2): SQNR increases with dimensionality");
    Ok(())
}
