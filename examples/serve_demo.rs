//! Serving demo: quantize, pack, and serve continuous-batched generation,
//! comparing the dense FP, decoded-dense VQ, and fused-VQ backends on
//! tokens/s, tail latency, and request-path payload.
//!
//! Runs on the trained artifacts when they exist, and falls back to a
//! synthetic demo model otherwise, so the serving path is always
//! demonstrable.
//!
//!     cargo run --release --example serve_demo

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::ExpContext;
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{ContinuousBatcher, GenRequest, ServeBackend};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "tiny".into());
    let ctx = ExpContext::load(&preset).ok();
    let synth; // synthetic corpus, built only when artifacts are missing
    let (template, train) = match &ctx {
        Some(c) => (c.model.clone(), &c.train),
        None => {
            println!("artifacts not built — serving a synthetic demo model");
            synth = synthetic_stream(60_000, 7);
            (Model::synthetic(ModelConfig::demo(64), 7), &synth)
        }
    };

    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 40;
    g.update_iters = 10;
    g.group_size = 512;
    let mut pcfg = PipelineConfig::new(Method::Gptvq(g));
    pcfg.calib_sequences = 8;
    pcfg.calib_seq_len = template.cfg.max_seq.min(32);
    let mut qmodel = template.clone();
    let report = quantize_model(&mut qmodel, train, &pcfg)?;
    let mean_bpv = report.mean_effective_bpv();
    let vq = report.vq_model.expect("gptvq produces a container");

    let backends = [
        ("FP32 dense", ServeBackend::Dense(template.clone())),
        ("VQ decoded dense", ServeBackend::dense_from_container(&template, &vq)?),
        ("VQ fused LUT", ServeBackend::fused(&template, vq)),
    ];

    let prompts = [
        "The man went to the",
        "Every child in the",
        "This important work",
        "A group of people met",
        "Some teachers said",
        "That final question",
    ];

    let mut t = Table::new(
        "serving: dense vs fused-VQ backends (continuous batching, KV cache)",
        &["backend", "tok/s", "p50 s", "p95 s", "p99 s", "payload MB"],
    );
    for (name, backend) in &backends {
        let mut batcher = ContinuousBatcher::new(3);
        for (id, p) in prompts.iter().enumerate() {
            batcher.submit(GenRequest {
                id: id as u64,
                prompt: p.as_bytes().to_vec(),
                max_new_tokens: 16,
            });
        }
        let stats = batcher.run_to_completion(backend);
        t.row(&[
            (*name).into(),
            fmt_f(stats.tokens_per_second()),
            fmt_f(stats.p50_latency()),
            fmt_f(stats.p95_latency()),
            fmt_f(stats.p99_latency()),
            fmt_f(backend.payload_bytes() as f64 / 1e6),
        ]);
    }
    t.emit("serve_demo");
    println!(
        "fused-VQ serves from {mean_bpv:.3} bpv of packed weights — \
         no dense matrix is materialized on the request path"
    );
    Ok(())
}
