//! Serving demo: quantize, pack, and serve batched generation requests,
//! comparing FP vs VQ tokens/s and footprint.
//!
//!     cargo run --release --example serve_demo

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::ExpContext;
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{model_from_container, Batcher, GenRequest};

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "tiny".into());
    let ctx = ExpContext::load(&preset).map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
    cfg.em_iters = 40;
    cfg.update_iters = 10;
    let run = ctx.run_method(Method::Gptvq(cfg)).map_err(|e| anyhow::anyhow!("{e}"))?;
    let vq = run.vq_model.as_ref().unwrap();
    let served = model_from_container(&ctx.model, vq).map_err(|e| anyhow::anyhow!("{e}"))?;

    let prompts = [
        "The man went to the",
        "Every child in the",
        "This important work",
        "A group of people met",
        "Some teachers said",
        "That final question",
    ];

    let mut t = Table::new("serving: FP vs VQ-packed model", &["model", "tok/s", "p50 latency s", "payload MB"]);
    for (name, model, payload) in [
        ("FP32", &ctx.model, (ctx.model.quantizable_weights() * 4) as f64 / 1e6),
        (
            "GPTVQ 2D packed",
            &served,
            vq.linears.values().map(|l| l.packed_bytes()).sum::<usize>() as f64 / 1e6,
        ),
    ] {
        let mut batcher = Batcher::new(3);
        for (id, p) in prompts.iter().enumerate() {
            batcher.submit(GenRequest {
                id: id as u64,
                prompt: p.as_bytes().to_vec(),
                max_new_tokens: 16,
            });
        }
        let stats = batcher.run_to_completion(model);
        t.row(&[
            name.into(),
            fmt_f(stats.tokens_per_second()),
            fmt_f(stats.p50_latency()),
            fmt_f(payload),
        ]);
    }
    t.emit("serve_demo");
    println!(
        "quantized ppl {:.3} (fp {:.3}) at {:.3} bpv — same-speed serving, ~{:.0}x smaller weights",
        run.ppl,
        ctx.fp_perplexity(),
        run.bpv,
        32.0 / run.bpv
    );
    Ok(())
}
