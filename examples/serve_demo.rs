//! Serving demo: quantize, pack, and serve Engine-scheduled generation,
//! comparing the dense FP, decoded-dense VQ, and fused-VQ backends on
//! tokens/s, tail latency (including TTFT and queue wait), and
//! request-path payload — then a speculative multi-token run streaming
//! tokens through a session sink.
//!
//! Runs on the trained artifacts when they exist, and falls back to a
//! synthetic demo model otherwise, so the serving path is always
//! demonstrable.
//!
//!     cargo run --release --example serve_demo

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::ExpContext;
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{Engine, GenRequest, SelfSpeculative, ServeBackend};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "tiny".into());
    let ctx = ExpContext::load(&preset).ok();
    let synth; // synthetic corpus, built only when artifacts are missing
    let (template, train) = match &ctx {
        Some(c) => (c.model.clone(), &c.train),
        None => {
            println!("artifacts not built — serving a synthetic demo model");
            synth = synthetic_stream(60_000, 7);
            (Model::synthetic(ModelConfig::demo(64), 7), &synth)
        }
    };

    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 40;
    g.update_iters = 10;
    g.group_size = 512;
    let mut pcfg = PipelineConfig::new(Method::Gptvq(g));
    pcfg.calib_sequences = 8;
    pcfg.calib_seq_len = template.cfg.max_seq.min(32);
    let mut qmodel = template.clone();
    let report = quantize_model(&mut qmodel, train, &pcfg)?;
    let mean_bpv = report.mean_effective_bpv();
    let vq = report.vq_model.expect("gptvq produces a container");

    let prompts = [
        "The man went to the",
        "Every child in the",
        "This important work",
        "A group of people met",
        "Some teachers said",
        "That final question",
    ];

    let mut t = Table::new(
        "serving: Engine over dense vs fused-VQ backends (KV cache, FIFO scheduler)",
        &["backend", "tok/s", "p50 s", "p99 s", "ttft p95 s", "queue p95 s", "payload MB"],
    );
    let backends = [
        ("FP32 dense", ServeBackend::Dense(template.clone())),
        ("VQ decoded dense", ServeBackend::dense_from_container(&template, &vq)?),
        ("VQ fused LUT", ServeBackend::fused(&template, vq.clone())),
    ];
    for (which, backend) in backends {
        let payload_mb = backend.payload_bytes() as f64 / 1e6;
        let mut engine = Engine::new(backend, 3);
        for (id, p) in prompts.iter().enumerate() {
            engine.submit(GenRequest::new(id as u64, p.as_bytes().to_vec(), 16))?;
        }
        let stats = engine.run_to_completion()?;
        t.row(&[
            which.into(),
            fmt_f(stats.tokens_per_second()),
            fmt_f(stats.p50_latency()),
            fmt_f(stats.p99_latency()),
            fmt_f(stats.ttft_percentile(95.0)),
            fmt_f(stats.queue_wait_percentile(95.0)),
            fmt_f(payload_mb),
        ]);
    }
    t.emit("serve_demo");

    // speculative multi-token decode on the fused backend, streaming the
    // continuation through the session's token sink as it is generated
    let mut engine = Engine::new(ServeBackend::fused(&template, vq), 1)
        .with_decode(Box::new(SelfSpeculative::new(4)))?;
    let streamed = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
    let sink_buf = std::rc::Rc::clone(&streamed);
    let session = engine.submit_with_sink(
        GenRequest::new(99, prompts[0].as_bytes().to_vec(), 24),
        // a sink reports flow control per token; this one never blocks
        Box::new(move |tok: u8| {
            sink_buf.borrow_mut().push(tok);
            gptvq::serve::SinkStatus::Ready
        }),
    )?;
    let stats = engine.run_to_completion()?;
    let resp = session.response().expect("session finished");
    assert_eq!(*streamed.borrow(), resp.output, "sink saw exactly the output");
    println!(
        "speculative fused-VQ continuation ({:.2} tokens/step, {:.0}% drafts accepted, \
         ttft {:.3}s): {:?}",
        stats.tokens_per_step(),
        stats.acceptance_rate().unwrap_or(0.0) * 100.0,
        resp.ttft_s,
        String::from_utf8_lossy(&resp.output)
    );
    println!(
        "fused-VQ serves from {mean_bpv:.3} bpv of packed weights — \
         no dense matrix is materialized on the request path"
    );
    Ok(())
}
