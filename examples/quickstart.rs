//! Quickstart: quantize the trained `tiny` model with GPTVQ 2D @ 2.25 bpv
//! and compare perplexity against FP32 and uniform GPTQ.
//!
//!     make artifacts && cargo run --release --example quickstart

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::ExpContext;
use gptvq::report::{fmt_f, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("GPTVQ_PRESET").unwrap_or_else(|_| "tiny".into());
    let ctx = ExpContext::load(&preset)?;
    println!(
        "loaded preset={} ({} params), corpus: {} train / {} valid tokens",
        preset,
        ctx.model.quantizable_weights(),
        ctx.train.len(),
        ctx.valid.len()
    );

    let fp_ppl = ctx.fp_perplexity();

    let mut gptvq = GptvqConfig::for_setting(2, 2, 0.25);
    gptvq.em_iters = 50;
    gptvq.update_iters = 15;
    let vq = ctx.run_method(Method::Gptvq(gptvq))?;
    let uniform =
        ctx.run_method(Method::Gptq { bits: 2, group_size: 64 })?;

    let mut t = Table::new("quickstart: W2 quantization of the tiny byte-LM", &["model", "bpv", "ppl"]);
    t.row(&["FP32".into(), "32".into(), fmt_f(fp_ppl)]);
    t.row(&[uniform.method.clone(), fmt_f(uniform.bpv), fmt_f(uniform.ppl)]);
    t.row(&[vq.method.clone(), fmt_f(vq.bpv), fmt_f(vq.ppl)]);
    t.emit("quickstart");

    println!(
        "GPTVQ quantized {} weights in {:.1}s ({:.0} weights/s)",
        vq.total_weights,
        vq.quantize_seconds,
        vq.total_weights as f64 / vq.quantize_seconds
    );
    if vq.ppl < uniform.ppl {
        println!("=> vector quantization beats the uniform grid at equal bits, as in the paper");
    }
    Ok(())
}
