//! Table 3 demo: VQ LUT-decode throughput vs INT4/INT8 dequantization,
//! with footprint accounting — the paper's "VQ decodes faster than int4
//! dequantizes because fewer bytes move" argument on this CPU.
//!
//!     cargo run --release --example decode_latency

use gptvq::decode::{decode_vq_f32, dequant_int4, dequant_int8, pack_int4, PackedIndices};
use gptvq::report::{fmt_f, Table};
use gptvq::util::timer::bench;
use gptvq::util::Rng;
use gptvq::vqformat::demo_linear;

const N: usize = 4 << 20; // weights decoded per measurement

fn main() {
    let mut rng = Rng::new(1);
    let mut out = vec![0f32; N];

    let mut t = Table::new(
        "VQ decode vs integer dequant (Table 3 analog)",
        &["setting", "bpv", "rel footprint", "Mweights/s", "rel latency"],
    );

    // INT4 baseline
    let codes4: Vec<u16> = (0..N).map(|_| rng.below(16) as u16).collect();
    let packed4 = pack_int4(&codes4);
    let gs = 64;
    let scales: Vec<f32> = (0..N / gs).map(|_| rng.range(0.01, 0.1) as f32).collect();
    let zeros: Vec<f32> = (0..N / gs).map(|_| rng.gaussian() as f32).collect();
    let s4 = bench(1, 5, || dequant_int4(&packed4, &scales, &zeros, gs, &mut out));
    let base_rate = N as f64 / s4.median_s;
    t.row(&[
        "INT4".into(),
        "4".into(),
        "1.00x".into(),
        fmt_f(base_rate / 1e6),
        "1.00x".into(),
    ]);

    // INT8 baseline
    let codes8: Vec<i8> = (0..N).map(|_| rng.below(256) as i8).collect();
    let s8 = bench(1, 5, || dequant_int8(&codes8, &scales, gs, &mut out));
    t.row(&[
        "INT8".into(),
        "8".into(),
        "2.00x".into(),
        fmt_f(N as f64 / s8.median_s / 1e6),
        format!("{:.2}x", s8.median_s / s4.median_s),
    ]);

    // VQ settings from Table 3: (label, d, bits-per-index, group)
    for (label, d, bits, group) in [
        ("2D 2.5B @ 512", 2usize, 5u32, 512usize),
        ("2D 2.5B @ 2048", 2, 5, 2048),
        ("2D 2B @ 1024", 2, 4, 1024),
        ("1D 3B @ 128", 1, 3, 128),
    ] {
        let k = 1usize << bits;
        let n_idx = N / d;
        let idx: Vec<u16> = (0..n_idx).map(|_| rng.below(k) as u16).collect();
        let packed = PackedIndices::pack(&idx, bits);
        let lut: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
        let s = bench(1, 5, || decode_vq_f32(&packed, &lut, d, &mut out));
        let bpv = gptvq::decode::vq_bytes_per_weight(d, bits, k, group) * 8.0;
        t.row(&[
            label.into(),
            fmt_f(bpv),
            format!("{:.2}x", bpv / 4.0),
            fmt_f(N as f64 / s.median_s / 1e6),
            format!("{:.2}x", s.median_s / s4.median_s),
        ]);
    }
    t.emit("decode_latency");
    println!("paper shape: VQ footprint < INT4 at comparable or better decode latency");

    // serving hot path: fused decode-matmul from the packed container vs
    // materializing the dense matrix first
    let (rows, cols, d, k) = (512usize, 1024usize, 2usize, 16usize);
    let lin = demo_linear(rows, cols, d, k, &mut rng);
    let x: Vec<f64> = rng.gaussian_vec(cols);
    let s_fused = bench(1, 5, || {
        let _ = lin.matvec(&x);
    });
    let s_dense = bench(1, 5, || {
        let _ = lin.decode().matvec(&x);
    });
    println!(
        "fused LUT decode-matmul ({rows}x{cols}): {:.2}x the latency of decode-then-matvec \
         (lower is better; the dense matrix is never built)",
        s_fused.median_s / s_dense.median_s
    );
}
