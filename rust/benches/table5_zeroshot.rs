//! Table 5: zero-shot probe accuracy (LM-eval-harness substitute) per
//! method at the W2 settings — relative degradation is the readout.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table5_zeroshot: artifacts not built, skipping");
        return;
    }
    let items = std::env::var("GPTVQ_TASK_ITEMS").ok().and_then(|v| v.parse().ok()).unwrap_or(40);
    let ctx = ExpContext::load(&preset).unwrap();

    let mut t = Table::new(
        format!("Table 5: zero-shot probes, preset {preset} ({items} items/task)"),
        &["method", "cloze", "pair", "induction", "avg"],
    );
    let fp_scores = ctx.zero_shot(&ctx.model, items);
    let fmt_row = |name: &str, scores: &[(String, f64)]| -> Vec<String> {
        let get = |n: &str| scores.iter().find(|s| s.0 == n).map(|s| s.1).unwrap_or(f64::NAN);
        let avg = scores.iter().map(|s| s.1).sum::<f64>() / scores.len().max(1) as f64;
        vec![
            name.into(),
            fmt_f(get("cloze")),
            fmt_f(get("pair")),
            fmt_f(get("induction")),
            fmt_f(avg),
        ]
    };
    t.row(&fmt_row("FP32", &fp_scores));

    let methods: Vec<(String, Method)> = vec![
        ("RTN W2@g64".into(), Method::Rtn { bits: 2, group_size: 64 }),
        ("GPTQ W2@g64".into(), Method::Gptq { bits: 2, group_size: 64 }),
        ("GPTVQ 1D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(1, 2, 0.25))),
        ("GPTVQ 2D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 2, 0.25))),
        ("GPTVQ 4D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(4, 2, 0.25))),
    ];
    for (name, m) in methods {
        let run = ctx.run_method(m).unwrap();
        let scores = ctx.zero_shot(&run.model, items);
        t.row(&fmt_row(&name, &scores));
        println!("{name}: done (ppl {:.3})", run.ppl);
    }
    t.emit("table5_zeroshot");
}
