//! Figure 1 (top): representation error of uniform vs non-uniform scalar
//! vs 2D vector quantization on correlated 2D Gaussian data at equal index
//! bits (3 bits/dim -> 64 grid points).

use gptvq::quant::vq::em::em_diag;
use gptvq::quant::vq::seed::seed_mahalanobis;
use gptvq::quant::vq::{assign_diag, decode, Codebook};
use gptvq::report::{fmt_f, Table};
use gptvq::tensor::Matrix;
use gptvq::util::Rng;

const N: usize = 20_000;
const BITS: u32 = 3;

fn mse(a: &Matrix, b: &Matrix) -> f64 {
    a.sub(b).frob_norm_sq() / a.len() as f64
}

fn main() {
    let mut rng = Rng::new(2024);
    // correlated 2D gaussian (rho = 0.8), the Fig 1 setting
    let rho: f64 = 0.8;
    let pts = Matrix::from_fn(N, 2, |_, _| 0.0);
    let mut pts = pts;
    for i in 0..N {
        let z1 = rng.gaussian();
        let z2 = rng.gaussian();
        pts.set(i, 0, z1);
        pts.set(i, 1, rho * z1 + (1.0 - rho * rho).sqrt() * z2);
    }
    let ones = Matrix::from_fn(N, 2, |_, _| 1.0);

    let mut t = Table::new(
        "Fig 1: 2D correlated gaussian, 3 bits/dim (64 points total)",
        &["quantizer", "mse", "vs uniform"],
    );

    // uniform: 8 equidistant levels per axis over min..max
    let k_axis = 1usize << BITS;
    let mut uni = pts.clone();
    for axis in 0..2 {
        let col: Vec<f64> = pts.col_copy(axis);
        let lo = col.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let step = (hi - lo) / (k_axis - 1) as f64;
        for i in 0..N {
            let q = ((pts.get(i, axis) - lo) / step).round() * step + lo;
            uni.set(i, axis, q);
        }
    }
    let mse_uni = mse(&pts, &uni);
    t.row(&["uniform".into(), fmt_f(mse_uni), "1.00x".into()]);

    // non-uniform scalar: k-means per axis (8 centroids each)
    let mut nonuni = pts.clone();
    for axis in 0..2 {
        let col = Matrix::from_vec(N, 1, pts.col_copy(axis)).unwrap();
        let h1 = Matrix::from_fn(N, 1, |_, _| 1.0);
        let seed = seed_mahalanobis(&col, k_axis).unwrap();
        let em = em_diag(&col, &h1, seed, 60);
        let dec = decode(&em.codebook, &em.assignments);
        for i in 0..N {
            nonuni.set(i, axis, dec.get(i, 0));
        }
    }
    let mse_nonuni = mse(&pts, &nonuni);
    t.row(&["non-uniform (scalar)".into(), fmt_f(mse_nonuni), format!("{:.2}x", mse_nonuni / mse_uni)]);

    // 2D VQ: 64 centroids over the joint distribution
    let k_vq = 1usize << (2 * BITS);
    let seed = seed_mahalanobis(&pts, k_vq).unwrap();
    let em = em_diag(&pts, &ones, seed, 60);
    let assign = assign_diag(&pts, &em.codebook, &ones);
    let dec = {
        let mut m = Matrix::zeros(N, 2);
        for (i, &a) in assign.iter().enumerate() {
            m.row_mut(i).copy_from_slice(em.codebook.centroid(a as usize));
        }
        m
    };
    let mse_vq = mse(&pts, &dec);
    t.row(&["VQ 2D".into(), fmt_f(mse_vq), format!("{:.2}x", mse_vq / mse_uni)]);

    // sanity: matches the paper's ordering
    assert!(mse_nonuni <= mse_uni * 1.05, "non-uniform should beat uniform");
    assert!(mse_vq < mse_nonuni, "VQ should beat scalar non-uniform on correlated data");
    let _ = Codebook::new(2, 2); // keep import
    t.emit("fig1_grids");
}
