//! Runtime throughput, two halves:
//!
//! 1. **Serving decode throughput** (always runs, synthetic demo model):
//!    tokens/sec of KV-cached incremental decode vs the seed's
//!    full-recompute loop at demo scale (32-token prompts, 32 new
//!    tokens) — acceptance target ≥ 3× — plus the fused-VQ backend and
//!    the continuous batcher under concurrent load.
//! 2. **Quantization throughput** (needs `make artifacts`): §4.3 "method
//!    runtime" weights/second per setting with a Llama-scale
//!    extrapolation — the analog of the paper's "30 min – 11 h on one
//!    H100" claim for this single-core CPU testbed.

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{
    generate_greedy, generate_greedy_backend, generate_greedy_full, ContinuousBatcher,
    GenRequest, ServeBackend,
};
use gptvq::util::timer::bench;

const PROMPT_LEN: usize = 32;
const NEW_TOKENS: usize = 32;

fn serving_section() {
    // max_seq 128 so the 64-token demo generation never slides the window
    let model = Model::synthetic(ModelConfig::demo(128), 11);
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| (i * 7 + 13) as u8).collect();

    // parity before speed: cached and full-recompute decode must agree
    let cached = generate_greedy(&model, &prompt, NEW_TOKENS);
    let full = generate_greedy_full(&model, &prompt, NEW_TOKENS);
    assert_eq!(cached, full, "KV-cached decode diverged from full recompute");

    let s_full = bench(1, 5, || {
        let _ = generate_greedy_full(&model, &prompt, NEW_TOKENS);
    });
    let s_kv = bench(1, 5, || {
        let _ = generate_greedy(&model, &prompt, NEW_TOKENS);
    });

    // fused-VQ backend over a quantized container of the same model
    let stream = synthetic_stream(60_000, 11);
    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 10;
    g.update_iters = 3;
    g.group_size = 512;
    let mut pcfg = PipelineConfig::new(Method::Gptvq(g));
    pcfg.calib_sequences = 4;
    pcfg.calib_seq_len = 32;
    let mut qmodel = model.clone();
    let report = quantize_model(&mut qmodel, &stream, &pcfg).unwrap();
    let fused = ServeBackend::fused(&model, report.vq_model.unwrap());
    let s_fused = bench(1, 5, || {
        let _ = generate_greedy_backend(&fused, &prompt, NEW_TOKENS);
    });

    let rate = |s: &gptvq::util::timer::Stats| NEW_TOKENS as f64 / s.median_s;
    let mut t = Table::new(
        format!("serving decode throughput ({PROMPT_LEN}-token prompts, {NEW_TOKENS} new tokens)"),
        &["decode path", "tok/s", "vs full recompute"],
    );
    t.row(&["full recompute (seed)".into(), fmt_f(rate(&s_full)), "1.00x".into()]);
    t.row(&[
        "KV-cached dense".into(),
        fmt_f(rate(&s_kv)),
        format!("{:.2}x", s_full.median_s / s_kv.median_s),
    ]);
    t.row(&[
        "KV-cached fused-VQ".into(),
        fmt_f(rate(&s_fused)),
        format!("{:.2}x", s_full.median_s / s_fused.median_s),
    ]);
    t.emit("runtime_throughput_serving");
    let speedup = s_full.median_s / s_kv.median_s;
    println!(
        "KV-cache speedup: {speedup:.1}x (acceptance target >= 3x): {}",
        if speedup >= 3.0 { "MET" } else { "NOT MET" }
    );

    // continuous batcher under concurrent load: mixed-length requests,
    // mid-stream retirement, tail-latency percentiles
    let backend = ServeBackend::Dense(model.clone());
    let mut batcher = ContinuousBatcher::new(4);
    for id in 0..8u64 {
        batcher.submit(GenRequest {
            id,
            prompt: prompt.clone(),
            max_new_tokens: 8 + (id as usize % 4) * 8,
        });
    }
    let stats = batcher.run_to_completion(&backend);
    println!(
        "continuous batching: {} requests, {:.1} tok/s, latency p50 {:.3}s / p95 {:.3}s / p99 {:.3}s",
        stats.requests,
        stats.tokens_per_second(),
        stats.p50_latency(),
        stats.p95_latency(),
        stats.p99_latency()
    );
}

fn quantization_section() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("quantization throughput: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("GPTVQ runtime (preset {preset}) + Llama-scale extrapolation"),
        &["method", "weights/s", "7B est (h)", "70B est (h)"],
    );

    let methods: Vec<(String, Method)> = vec![
        ("GPTQ W2@g128".into(), Method::Gptq { bits: 2, group_size: 128 }),
        ("GPTVQ 1D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(1, 2, 0.125))),
        ("GPTVQ 2D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 2, 0.125))),
        ("GPTVQ 2D 3b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 3, 0.125))),
        ("GPTVQ 4D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(4, 2, 0.25))),
    ];
    for (name, m) in methods {
        let run = ctx.run_method(m).unwrap();
        let wps = run.total_weights as f64 / run.quantize_seconds;
        let est = |params: f64| params / wps / 3600.0;
        t.row(&[name, fmt_f(wps), fmt_f(est(7e9)), fmt_f(est(70e9))]);
    }
    t.emit("runtime_throughput");
    println!("paper: 0.5-1 h (7B) and 3-11 h (70B) on one H100; scale by the CPU/GPU gap");
}

fn main() {
    serving_section();
    quantization_section();
}
