//! Runtime throughput, six sections:
//!
//! 1. **Serving decode throughput** (always runs, synthetic demo model):
//!    tokens/sec of KV-cached incremental decode vs the seed's
//!    full-recompute loop at demo scale — acceptance target ≥ 3× — plus
//!    the fused-VQ backend (the deprecated `generate_greedy*` shims are
//!    used on purpose: they are the pinned baselines).
//! 2. **Scheduler ladder**: the same mixed-length workload under
//!    `Fifo` / `RoundRobin` / `ShortestRemaining` with a constrained
//!    per-step budget, reporting throughput *and* tail fairness (p99,
//!    TTFT, queue wait). Schedulers change wall time, never tokens —
//!    asserted here.
//! 3. **Batched ladder**: `StepMode::Batched` vs `StepMode::PerSlot` at
//!    1/2/4/8 active slots — token-identity and the one-forward-per-step
//!    accounting hard-asserted, tok/s scaling reported (the `--smoke`
//!    lines CI grep for), plus a fused-VQ rung.
//! 4. **Speculative decode**: `SelfSpeculative(k)` vs `OneToken` on the
//!    dense and fused-VQ backends — token-identity asserted, acceptance
//!    rate and tokens/step reported (the `--smoke` lines CI grep for).
//! 5. **Overload ladder**: seeded open-loop traffic at 0.5×/1×/2×/4× of
//!    decode capacity against a bounded queue + per-request deadlines —
//!    graceful degradation hard-asserted (step-domain goodput at 4× stays
//!    within 20% of the 1× plateau, shed count monotone in offered load,
//!    identically-seeded reruns bitwise-identical for non-shed sessions).
//! 6. **KV-pressure ladder**: a fixed-byte paged arena against the
//!    per-session contiguous baseline — int8-paged concurrency multiple
//!    (target ≥ 4× sessions at equal step-domain goodput, hard-asserted),
//!    bytes/token for f64 vs int8 pages, the int8 NLL drift against its
//!    documented bound, monotone `KvExhausted` shedding as offered
//!    sessions exceed the arena, and bitwise rerun identity (the
//!    `--smoke` lines CI greps for).
//! 7. **Quantization throughput** (needs `make artifacts`): §4.3 "method
//!    runtime" weights/second per setting with a Llama-scale
//!    extrapolation.
//!
//! `--smoke` shrinks the workloads for CI.

#![allow(deprecated)] // generate_greedy*/ContinuousBatcher are the baselines

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::forward::{forward_logits_cached, nll_from_logits};
use gptvq::model::kv::KvCache;
use gptvq::model::kvpool::{KvPool, KvStoreKind, PagedKvCache, KV_INT8_NLL_REL_TOL};
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{
    generate, generate_greedy, generate_greedy_backend, generate_greedy_full,
    offered_tokens_per_step, DecodePolicy, Engine, Fifo, GenRequest, LoadGenConfig, OneToken,
    Outcome, Rejected, RoundRobin, Scheduler, SelfSpeculative, ServeBackend, ServeStats,
    ShortestRemaining, StepMode, SubmitOutcome,
};
use gptvq::util::timer::bench;
use gptvq::vqformat::VqModel;

const PROMPT_LEN: usize = 32;
const NEW_TOKENS: usize = 32;

/// Quantize the demo model into a packed container (shared by the fused
/// sections).
fn demo_container(model: &Model) -> VqModel {
    let stream = synthetic_stream(60_000, 11);
    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 10;
    g.update_iters = 3;
    g.group_size = 512;
    let mut pcfg = PipelineConfig::new(Method::Gptvq(g));
    pcfg.calib_sequences = 4;
    pcfg.calib_seq_len = 32;
    let mut qmodel = model.clone();
    let report = quantize_model(&mut qmodel, &stream, &pcfg).unwrap();
    report.vq_model.unwrap()
}

fn serving_section() {
    // max_seq 128 so the 64-token demo generation never slides the window
    let model = Model::synthetic(ModelConfig::demo(128), 11);
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| (i * 7 + 13) as u8).collect();

    // parity before speed: cached and full-recompute decode must agree
    let cached = generate_greedy(&model, &prompt, NEW_TOKENS);
    let full = generate_greedy_full(&model, &prompt, NEW_TOKENS);
    assert_eq!(cached, full, "KV-cached decode diverged from full recompute");

    let s_full = bench(1, 5, || {
        let _ = generate_greedy_full(&model, &prompt, NEW_TOKENS);
    });
    let s_kv = bench(1, 5, || {
        let _ = generate_greedy(&model, &prompt, NEW_TOKENS);
    });

    // fused-VQ backend over a quantized container of the same model
    let fused = ServeBackend::fused(&model, demo_container(&model));
    let s_fused = bench(1, 5, || {
        let _ = generate_greedy_backend(&fused, &prompt, NEW_TOKENS);
    });

    let rate = |s: &gptvq::util::timer::Stats| NEW_TOKENS as f64 / s.median_s;
    let mut t = Table::new(
        format!("serving decode throughput ({PROMPT_LEN}-token prompts, {NEW_TOKENS} new tokens)"),
        &["decode path", "tok/s", "vs full recompute"],
    );
    t.row(&["full recompute (seed)".into(), fmt_f(rate(&s_full)), "1.00x".into()]);
    t.row(&[
        "KV-cached dense".into(),
        fmt_f(rate(&s_kv)),
        format!("{:.2}x", s_full.median_s / s_kv.median_s),
    ]);
    t.row(&[
        "KV-cached fused-VQ".into(),
        fmt_f(rate(&s_fused)),
        format!("{:.2}x", s_full.median_s / s_fused.median_s),
    ]);
    t.emit("runtime_throughput_serving");
    let speedup = s_full.median_s / s_kv.median_s;
    println!(
        "KV-cache speedup: {speedup:.1}x (acceptance target >= 3x): {}",
        if speedup >= 3.0 { "MET" } else { "NOT MET" }
    );
}

/// Mixed-length request set for the scheduler ladder: a few long
/// requests up front, short ones behind them (the FIFO worst case).
fn ladder_requests(prompt: &[u8], smoke: bool) -> Vec<GenRequest> {
    let scale = if smoke { 1 } else { 2 };
    let mut reqs = Vec::new();
    for id in 0..8u64 {
        let long = id < 3;
        reqs.push(GenRequest::new(id, prompt.to_vec(), if long { 16 * scale } else { 4 * scale }));
    }
    reqs
}

fn scheduler_ladder_section(smoke: bool) {
    let model = Model::synthetic(ModelConfig::demo(128), 13);
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| (i * 5 + 17) as u8).collect();
    let schedulers: Vec<(&str, fn() -> Box<dyn Scheduler>)> = vec![
        ("fifo", || Box::new(Fifo::new())),
        ("round-robin", || Box::new(RoundRobin::new())),
        ("shortest-remaining", || Box::new(ShortestRemaining::new())),
    ];
    let mut t = Table::new(
        "scheduler ladder (4 slots, step budget 2, mixed 3-long/5-short workload)",
        &["policy", "tok/s", "p50 s", "p99 s", "ttft p95 s", "queue p95 s"],
    );
    let mut reference: Option<Vec<(u64, Vec<u8>)>> = None;
    for (name, mk) in &schedulers {
        let mut engine = Engine::new(ServeBackend::Dense(model.clone()), 4)
            .with_scheduler(mk())
            .with_step_budget(2);
        let mut outputs = Vec::new();
        for r in ladder_requests(&prompt, smoke) {
            outputs.push((r.id, engine.submit(r).expect("valid request")));
        }
        let stats = engine.run_to_completion().expect("scheduler ladder stalled");
        let mut transcript: Vec<(u64, Vec<u8>)> = outputs
            .into_iter()
            .map(|(id, s)| (id, s.response().unwrap().output))
            .collect();
        transcript.sort_by_key(|(id, _)| *id);
        // the determinism rule: policies never change tokens
        match &reference {
            None => reference = Some(transcript),
            Some(r) => assert_eq!(r, &transcript, "{name} changed output tokens"),
        }
        t.row(&[
            (*name).into(),
            fmt_f(stats.tokens_per_second()),
            fmt_f(stats.p50_latency()),
            fmt_f(stats.p99_latency()),
            fmt_f(stats.ttft_percentile(95.0)),
            fmt_f(stats.queue_wait_percentile(95.0)),
        ]);
        println!(
            "scheduler ladder: policy={name} tok/s={:.1} p99={:.4}s ttft_p95={:.4}s queue_p95={:.4}s",
            stats.tokens_per_second(),
            stats.p99_latency(),
            stats.ttft_percentile(95.0),
            stats.queue_wait_percentile(95.0),
        );
    }
    t.emit("runtime_throughput_schedulers");
}

/// Cross-slot batching A/B: the same N-slot workload through
/// `StepMode::Batched` (ONE ragged forward per step) and
/// `StepMode::PerSlot` (one forward per slot per step). Token identity
/// and the decode-call accounting are deterministic, so they are hard
/// assertions; the wall-clock scaling target is reported MET/NOT MET
/// like the KV-cache speedup above.
fn batched_ladder_section(smoke: bool) {
    let model = Model::synthetic(ModelConfig::demo(128), 17);
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| (i * 11 + 7) as u8).collect();
    let new_tokens = if smoke { 16 } else { 32 };

    // equal-length requests with distinct streams: every slot decodes
    // every step, so the accounting below is exact
    let requests = |slots: usize| -> Vec<GenRequest> {
        (0..slots as u64)
            .map(|id| {
                let mut p = prompt.clone();
                p[0] = p[0].wrapping_add(id as u8);
                GenRequest::new(id, p, new_tokens)
            })
            .collect()
    };
    let run = |backend: ServeBackend, slots: usize, mode: StepMode| {
        let mut engine = Engine::new(backend, slots).with_step_mode(mode);
        let mut sessions = Vec::new();
        for r in requests(slots) {
            sessions.push(engine.submit(r).expect("valid request"));
        }
        let stats = engine.run_to_completion().expect("batched ladder stalled");
        let transcript: Vec<Vec<u8>> =
            sessions.iter().map(|s| s.response().unwrap().output).collect();
        (stats, transcript)
    };

    let mut t = Table::new(
        format!("batched ladder (dense, {new_tokens} new tokens per slot)"),
        &["slots", "mode", "tok/s", "tokens/step", "decode calls"],
    );
    let mut tok_s = std::collections::BTreeMap::new();
    for slots in [1usize, 2, 4, 8] {
        let (bs, bt) = run(ServeBackend::Dense(model.clone()), slots, StepMode::Batched);
        let (ps, pt) = run(ServeBackend::Dense(model.clone()), slots, StepMode::PerSlot);
        assert_eq!(bt, pt, "{slots} slots: batched step changed tokens");
        // exact accounting: N steps of one batched forward each vs
        // N × slots per-slot forwards, same token count
        assert_eq!(bs.decode_calls, new_tokens, "{slots} slots: batched calls");
        assert_eq!(ps.decode_calls, new_tokens * slots, "{slots} slots: per-slot calls");
        assert_eq!(bs.decoded_tokens, ps.decoded_tokens);
        assert!((bs.tokens_per_step() - slots as f64).abs() < 1e-12);
        assert!((ps.tokens_per_step() - 1.0).abs() < 1e-12);
        for (mode, stats) in [("batched", &bs), ("per-slot", &ps)] {
            t.row(&[
                slots.to_string(),
                mode.into(),
                fmt_f(stats.tokens_per_second()),
                format!("{:.2}", stats.tokens_per_step()),
                stats.decode_calls.to_string(),
            ]);
            println!(
                "batched ladder: slots={slots} mode={mode} tok/s={:.1} tokens_per_step={:.2} decode_calls={}",
                stats.tokens_per_second(),
                stats.tokens_per_step(),
                stats.decode_calls,
            );
            tok_s.insert((mode, slots), stats.tokens_per_second());
        }
    }
    t.emit("runtime_throughput_batched");
    // acceptance: under batching, aggregate tok/s grows with slot count
    // (the per-step weight pass amortizes); per-slot mode stays flat
    let scale = tok_s[&("batched", 8usize)] / tok_s[&("batched", 1usize)];
    let vs_per_slot = tok_s[&("batched", 8usize)] / tok_s[&("per-slot", 8usize)];
    println!(
        "batched ladder: scaling 1->8 slots {scale:.2}x (target >= 1.5x): {}",
        if scale >= 1.5 { "MET" } else { "NOT MET" }
    );
    println!(
        "batched ladder: batched vs per-slot at 8 slots {vs_per_slot:.2}x (target >= 1.2x): {}",
        if vs_per_slot >= 1.2 { "MET" } else { "NOT MET" }
    );

    // fused-VQ rung: the batched step decodes each LUT linear once per
    // step instead of once per slot — the backend the batching win is for
    let vq = demo_container(&model);
    let slots = 4usize;
    let (bs, bt) = run(ServeBackend::fused(&model, vq.clone()), slots, StepMode::Batched);
    let (ps, pt) = run(ServeBackend::fused(&model, vq), slots, StepMode::PerSlot);
    assert_eq!(bt, pt, "fused batched step changed tokens");
    assert_eq!(bs.decode_calls, new_tokens);
    assert_eq!(ps.decode_calls, new_tokens * slots);
    for (mode, stats) in [("batched", &bs), ("per-slot", &ps)] {
        println!(
            "batched ladder: slots={slots} mode=fused-{mode} tok/s={:.1} tokens_per_step={:.2} decode_calls={}",
            stats.tokens_per_second(),
            stats.tokens_per_step(),
            stats.decode_calls,
        );
    }
}

fn speculative_section(smoke: bool) {
    // max_seq 256 keeps the whole speculative run inside one window
    let model = Model::synthetic(ModelConfig::demo(256), 21);
    let vq = demo_container(&model);
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| (i * 3 + 29) as u8).collect();
    let new_tokens = if smoke { 24 } else { 48 };
    let n_requests = 4u64;

    let mut t = Table::new(
        format!("speculative decode ({n_requests} requests × {new_tokens} new tokens)"),
        &["backend", "policy", "tok/s", "tokens/step", "accept %"],
    );
    for backend_name in ["dense", "fused-vq"] {
        let mut baseline: Option<Vec<(u64, Vec<u8>)>> = None;
        let mut baseline_calls = 0usize;
        for k in [0usize, 2, 4] {
            let backend = match backend_name {
                "dense" => ServeBackend::Dense(model.clone()),
                _ => ServeBackend::fused(&model, vq.clone()),
            };
            let policy: Box<dyn DecodePolicy> = if k == 0 {
                Box::new(OneToken::new())
            } else {
                Box::new(SelfSpeculative::new(k))
            };
            let mut engine = Engine::new(backend, 2).with_decode(policy).unwrap();
            let mut sessions = Vec::new();
            let t0 = std::time::Instant::now();
            for id in 0..n_requests {
                let mut p = prompt.clone();
                p[0] = p[0].wrapping_add(id as u8); // distinct streams
                let session = engine
                    .submit(GenRequest::new(id, p, new_tokens))
                    .expect("valid request");
                sessions.push((id, session));
            }
            let stats = engine.run_to_completion().expect("speculative section stalled");
            let wall = t0.elapsed().as_secs_f64();
            let mut transcript: Vec<(u64, Vec<u8>)> = sessions
                .into_iter()
                .map(|(id, s)| (id, s.response().unwrap().output))
                .collect();
            transcript.sort_by_key(|(id, _)| *id);
            match &baseline {
                None => {
                    baseline = Some(transcript);
                    baseline_calls = stats.decode_calls;
                }
                Some(b) => {
                    // acceptance pin: speculative output is token-identical
                    // on every backend, always
                    assert_eq!(b, &transcript, "{backend_name} k={k} diverged from one-token");
                    let fewer_steps =
                        stats.decode_calls < baseline_calls && stats.tokens_per_step() > 1.0;
                    if backend_name == "dense" {
                        // dense drafts == target path: the multi-token win
                        // is guaranteed, so it is a hard assertion
                        assert!(
                            fewer_steps,
                            "dense k={k} did not reduce decode steps \
                             ({} calls vs {baseline_calls}, {:.2} tokens/step)",
                            stats.decode_calls,
                            stats.tokens_per_step()
                        );
                    } else {
                        // fused acceptance depends on float-rounding
                        // agreement between the decoded-dense draft and the
                        // LUT target — report, don't abort CI on a
                        // legitimate (if unlikely) acceptance collapse
                        println!(
                            "fused speculative step win (k={k}): {}",
                            if fewer_steps { "MET" } else { "NOT MET" }
                        );
                    }
                }
            }
            let accept = stats.acceptance_rate().map(|r| r * 100.0);
            let policy_label =
                if k == 0 { "one-token".to_string() } else { format!("self-spec k={k}") };
            t.row(&[
                backend_name.into(),
                policy_label,
                fmt_f(stats.total_tokens as f64 / wall),
                format!("{:.2}", stats.tokens_per_step()),
                accept.map(|a| format!("{a:.1}")).unwrap_or_else(|| "-".into()),
            ]);
            println!(
                "speculative acceptance: backend={backend_name} k={k} tokens_per_step={:.2} accept={} decode_calls={}",
                stats.tokens_per_step(),
                accept.map(|a| format!("{a:.1}%")).unwrap_or_else(|| "-".into()),
                stats.decode_calls,
            );
        }
    }
    t.emit("runtime_throughput_speculative");
}

/// One overload rung: drive a bounded-queue, deadline-bearing engine
/// with a seeded open-loop arrival schedule, collecting shed counts and
/// the completed-session transcript alongside the stats. The loop is
/// the same open-loop protocol as `serve::run_open_loop`, inlined here
/// so the bench can keep per-session outputs for the bitwise rerun
/// check (the library runner only keeps aggregates).
fn overload_rung(
    model: &Model,
    rate: f64,
    requests: usize,
) -> (f64, ServeStats, Vec<(u64, Vec<u8>)>) {
    let lg = LoadGenConfig {
        seed: 41,
        rate,
        requests,
        output_max: 24,
        deadline_steps: 64,
        ..LoadGenConfig::default()
    };
    let arrivals = generate(&lg);
    let offered = offered_tokens_per_step(&arrivals);
    let mut engine =
        Engine::new(ServeBackend::Dense(model.clone()), 4).with_queue_cap(8);
    let mut stats = ServeStats::default();
    let mut transcript: Vec<(u64, Vec<u8>)> = Vec::new();
    let mut next = 0usize;
    while next < arrivals.len() || engine.pending() > 0 {
        let now = engine.steps_elapsed();
        while next < arrivals.len() && arrivals[next].step <= now {
            match engine.try_submit(arrivals[next].req.clone()).expect("valid request") {
                SubmitOutcome::Admitted(_) => {}
                SubmitOutcome::Rejected(_) => stats.shed += 1,
            }
            next += 1;
        }
        for resp in engine.step().expect("overload rung stalled") {
            if resp.outcome == Outcome::Completed {
                transcript.push((resp.id, resp.output.clone()));
            }
            stats.record(&resp);
        }
    }
    stats.clock_steps = engine.steps_elapsed() as usize;
    transcript.sort_by_key(|(id, _)| *id);
    (offered, stats, transcript)
}

/// Overload ladder: sweep offered load from half capacity to 4× over it
/// and assert the degradation is graceful — goodput saturates instead of
/// collapsing, excess load is shed (monotonically), and identically
/// seeded runs are bitwise identical for every non-shed session. All
/// asserted quantities live in the deterministic step domain, so the
/// ladder is reproducible across machines.
fn overload_ladder_section(smoke: bool) {
    let model = Model::synthetic(ModelConfig::demo(128), 23);
    let base_requests = if smoke { 32 } else { 64 };
    // capacity is max_batch = 4 tokens/step; with the rung's ~4.4-token
    // mean output, rate 0.9/step offers roughly 1× capacity. Request
    // count scales with the rate so every rung spans a comparable
    // number of arrival steps — otherwise the high rungs are mostly
    // ragged drain-tail and goodput undercounts saturation.
    let rungs = [(0.5f64, 0.45f64), (1.0, 0.9), (2.0, 1.8), (4.0, 3.6)];
    let mut t = Table::new(
        format!("overload ladder ({base_requests} requests/1x, queue cap 8, deadline 64 steps)"),
        &["load", "offered tok/step", "goodput tok/step", "shed %", "expired", "slo p99 ttft"],
    );
    let mut goodputs = Vec::new();
    let mut shed_fracs = Vec::new();
    for (mult, rate) in rungs {
        let requests = (base_requests as f64 * mult) as usize;
        let (offered, stats, _) = overload_rung(&model, rate, requests);
        assert_eq!(
            stats.requests + stats.shed,
            requests,
            "{mult}x: every offered request must resolve exactly once"
        );
        let shed_frac = stats.shed as f64 / requests as f64;
        t.row(&[
            format!("{mult:.1}x"),
            format!("{offered:.2}"),
            format!("{:.2}", stats.goodput_per_step()),
            format!("{:.0}", shed_frac * 100.0),
            stats.expired.to_string(),
            format!("{:.1}", stats.ttft_steps_percentile(99.0)),
        ]);
        println!(
            "overload ladder: load={mult:.1}x offered={offered:.2} goodput_per_step={:.2} \
             shed={} expired={} cancelled={} slo_p99_ttft_steps={:.1} clock_steps={}",
            stats.goodput_per_step(),
            stats.shed,
            stats.expired,
            stats.cancelled,
            stats.ttft_steps_percentile(99.0),
            stats.clock_steps,
        );
        goodputs.push(stats.goodput_per_step());
        shed_fracs.push(shed_frac);
    }
    t.emit("runtime_throughput_overload");

    // graceful degradation, both in the deterministic step domain:
    // saturation must not collapse goodput, and overload must be
    // answered by shedding rather than unbounded queueing
    let plateau = goodputs[1];
    let at_4x = goodputs[3];
    assert!(
        at_4x >= 0.8 * plateau,
        "goodput collapsed under 4x overload: {at_4x:.2} vs 1x plateau {plateau:.2} tokens/step"
    );
    assert!(
        shed_fracs.windows(2).all(|w| w[0] <= w[1] + 1e-12),
        "shed fraction not monotone in offered load: {shed_fracs:?}"
    );
    println!(
        "overload ladder: goodput at 4x {:.2} vs 1x plateau {:.2} (target >= 0.8x): {}",
        at_4x,
        plateau,
        if at_4x >= 0.8 * plateau { "MET" } else { "NOT MET" }
    );

    // determinism under overload: the same seed must shed the same
    // requests and emit bitwise-identical tokens for the survivors
    let (_, s1, t1) = overload_rung(&model, 3.6, base_requests * 4);
    let (_, s2, t2) = overload_rung(&model, 3.6, base_requests * 4);
    assert_eq!(s1.shed, s2.shed, "rerun shed a different request set");
    assert_eq!(s1.expired, s2.expired, "rerun expired a different request set");
    assert_eq!(s1.goodput_tokens, s2.goodput_tokens, "rerun goodput diverged");
    assert_eq!(s1.clock_steps, s2.clock_steps, "rerun step clock diverged");
    assert_eq!(t1, t2, "rerun transcripts diverged for non-shed sessions");
    println!(
        "overload ladder: rerun identity at 4x (shed {} / goodput {} tokens): MET",
        s1.shed, s1.goodput_tokens
    );
}

/// One KV-pressure rung: `offered` full-context requests submitted
/// simultaneously against a paged engine with a fixed arena, drained to
/// completion. Returns total/KV shed counts, the drain stats, and the
/// completed transcripts (for the bitwise rerun check).
fn kv_rung(
    model: &Model,
    offered: usize,
    kv_pages: usize,
    store: KvStoreKind,
) -> (usize, usize, ServeStats, Vec<(u64, Vec<u8>)>) {
    // prompt + budget fills the demo(64) window exactly: admission must
    // reserve every request's full worst-case footprint, which is what
    // makes the arena the binding constraint rather than slot count
    let new_tokens = model.cfg.max_seq / 2;
    let mut engine = Engine::new(ServeBackend::Dense(model.clone()), offered)
        .with_kv_page(8)
        .with_kv_pages(kv_pages)
        .with_kv_store(store);
    let (mut shed, mut shed_kv) = (0usize, 0usize);
    let mut sessions = Vec::new();
    for id in 0..offered as u64 {
        let prompt: Vec<u8> =
            (0..model.cfg.max_seq / 2).map(|i| (i * 7 + 13 + id as usize) as u8).collect();
        match engine
            .try_submit(GenRequest::new(id, prompt, new_tokens))
            .expect("valid request")
        {
            SubmitOutcome::Admitted(s) => sessions.push((id, s)),
            SubmitOutcome::Rejected(r) => {
                shed += 1;
                if matches!(r, Rejected::KvExhausted { .. }) {
                    shed_kv += 1;
                }
            }
        }
    }
    let stats = engine.run_to_completion().expect("kv rung stalled");
    let mut transcript: Vec<(u64, Vec<u8>)> = sessions
        .into_iter()
        .map(|(id, s)| (id, s.response().expect("drained").output))
        .collect();
    transcript.sort_by_key(|(id, _)| *id);
    (shed, shed_kv, stats, transcript)
}

/// KV-pressure ladder: hold the arena's byte budget fixed at FOUR
/// per-session contiguous worst-case caches and show what paging +
/// int8 pages buy — more concurrent sessions in the same bytes at equal
/// step-domain goodput, bounded accuracy drift, page-domain shedding
/// once offered load exceeds the arena, and bitwise reproducibility.
fn kv_pressure_section(smoke: bool) {
    let model = Model::synthetic(ModelConfig::demo(64), 29);
    let cfg = &model.cfg;
    let page_rows = 8usize;
    let pages_per_session = cfg.n_layers * cfg.max_seq.div_ceil(page_rows);
    // probe the stores for resident page bytes rather than hardcoding
    let f64_page = KvPool::new(cfg, page_rows, 1, KvStoreKind::F64Dense).stats().page_bytes;
    let int8_page = KvPool::new(cfg, page_rows, 1, KvStoreKind::Int8Group).stats().page_bytes;
    // one full-context contiguous session, and the fixed arena budget:
    // exactly four of them — the per-session baseline this ladder beats
    let contig_session = pages_per_session * f64_page;
    let budget = 4 * contig_session;
    let int8_cap = budget / int8_page;
    let f64_cap = budget / f64_page;
    let sustained = int8_cap / pages_per_session;

    // --- density rung: the int8 arena carries `sustained` concurrent
    // full-context sessions where the same bytes hold 4 contiguous ones
    let (shed, _, int8_stats, _) = kv_rung(&model, sustained, int8_cap, KvStoreKind::Int8Group);
    assert_eq!(shed, 0, "density rung must fit the arena exactly");
    let mut reference = Engine::new(ServeBackend::Dense(model.clone()), sustained);
    let mut held = Vec::new();
    for id in 0..sustained as u64 {
        let prompt: Vec<u8> =
            (0..cfg.max_seq / 2).map(|i| (i * 7 + 13 + id as usize) as u8).collect();
        held.push(reference.submit(GenRequest::new(id, prompt, cfg.max_seq / 2)).unwrap());
    }
    let ref_stats = reference.run_to_completion().expect("reference stalled");
    // equal goodput in the deterministic step domain: paging and int8
    // storage change bytes, never scheduling or token counts
    assert_eq!(int8_stats.engine_steps, ref_stats.engine_steps, "paged run took extra steps");
    assert_eq!(int8_stats.decoded_tokens, ref_stats.decoded_tokens, "paged run lost tokens");
    let multiple = sustained as f64 / 4.0;
    println!(
        "kv ladder: arena {budget} B sustains {sustained} int8-paged sessions vs 4 \
         per-session contiguous ({multiple:.1}x, target >= 4x): {}",
        if multiple >= 4.0 { "MET" } else { "NOT MET" }
    );
    assert!(multiple >= 4.0, "int8 paging must fit >= 4x sessions in the contiguous budget");
    let bpt = |page: usize| cfg.n_layers * page / page_rows;
    println!(
        "kv ladder: bytes/token f64={} int8={} ({:.1}x denser)",
        bpt(f64_page),
        bpt(int8_page),
        f64_page as f64 / int8_page as f64,
    );

    // --- drift rung: teacher-forced mean NLL through the int8 paged
    // cache vs the f64 oracle, against the documented guardrail
    let toks: Vec<u8> = (0..48).map(|i| (i * 13 + 7) as u8).collect();
    let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    let mut oracle = KvCache::oracle(cfg);
    let nll_o = mean(nll_from_logits(&forward_logits_cached(&model, &mut oracle, &toks), &toks));
    let pool = KvPool::shared(cfg, page_rows, 0, KvStoreKind::Int8Group);
    let mut paged = PagedKvCache::new(&pool, toks.len()).expect("unbounded admit");
    let nll_p = mean(nll_from_logits(&forward_logits_cached(&model, &mut paged, &toks), &toks));
    let drift = (nll_p - nll_o).abs() / nll_o;
    println!(
        "kv ladder: int8 mean NLL {nll_p:.4} vs f64 {nll_o:.4}, drift {drift:.4} \
         (bound {KV_INT8_NLL_REL_TOL}): {}",
        if drift <= KV_INT8_NLL_REL_TOL { "MET" } else { "NOT MET" }
    );
    assert!(drift <= KV_INT8_NLL_REL_TOL, "int8 KV drift exceeded its documented bound");

    // --- pressure rung: offer ever more sessions against the same f64
    // arena (4 full-context sessions' worth of pages) and require the
    // overflow to shed as KvExhausted, monotonically
    let fits = f64_cap / pages_per_session; // = 4
    let ladder: Vec<usize> =
        if smoke { vec![fits / 2, fits, 2 * fits] } else { vec![fits / 2, fits, 2 * fits, 4 * fits] };
    let mut fracs = Vec::new();
    for offered in &ladder {
        let (shed, shed_kv, stats, _) = kv_rung(&model, *offered, f64_cap, KvStoreKind::F64Dense);
        assert_eq!(shed, shed_kv, "only the arena sheds here: no queue cap, no deadlines");
        assert_eq!(stats.requests + shed, *offered, "every request resolves exactly once");
        let frac = shed_kv as f64 / *offered as f64;
        fracs.push(frac);
        println!(
            "kv ladder: offered={offered} arena={f64_cap}p shed_kv={shed_kv} ({:.0}%) \
             goodput_per_step={:.2}",
            frac * 100.0,
            stats.goodput_per_step(),
        );
    }
    assert!(
        fracs.windows(2).all(|w| w[0] <= w[1] + 1e-12),
        "KvExhausted shed fraction not monotone in offered sessions: {fracs:?}"
    );
    assert_eq!(fracs[0], 0.0, "an under-subscribed arena must not shed");
    assert!(*fracs.last().unwrap() > 0.0, "an over-subscribed arena must shed");

    // --- rerun identity: page-domain shedding and every surviving
    // transcript are pure functions of (traffic, config)
    let top = *ladder.last().unwrap();
    let (h1, k1, s1, t1) = kv_rung(&model, top, f64_cap, KvStoreKind::F64Dense);
    let (h2, k2, s2, t2) = kv_rung(&model, top, f64_cap, KvStoreKind::F64Dense);
    assert_eq!((h1, k1), (h2, k2), "rerun shed a different request set");
    assert_eq!(s1.goodput_tokens, s2.goodput_tokens, "rerun goodput diverged");
    assert_eq!(t1, t2, "rerun transcripts diverged for admitted sessions");
    println!(
        "kv ladder: rerun identity at {top} offered (shed {k1}, goodput {} tokens): MET",
        s1.goodput_tokens
    );
}

fn quantization_section() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("quantization throughput: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("GPTVQ runtime (preset {preset}) + Llama-scale extrapolation"),
        &["method", "weights/s", "7B est (h)", "70B est (h)"],
    );

    let methods: Vec<(String, Method)> = vec![
        ("GPTQ W2@g128".into(), Method::Gptq { bits: 2, group_size: 128 }),
        ("GPTVQ 1D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(1, 2, 0.125))),
        ("GPTVQ 2D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 2, 0.125))),
        ("GPTVQ 2D 3b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 3, 0.125))),
        ("GPTVQ 4D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(4, 2, 0.25))),
    ];
    for (name, m) in methods {
        let run = ctx.run_method(m).unwrap();
        let wps = run.total_weights as f64 / run.quantize_seconds;
        let est = |params: f64| params / wps / 3600.0;
        t.row(&[name, fmt_f(wps), fmt_f(est(7e9)), fmt_f(est(70e9))]);
    }
    t.emit("runtime_throughput");
    println!("paper: 0.5-1 h (7B) and 3-11 h (70B) on one H100; scale by the CPU/GPU gap");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    serving_section();
    scheduler_ladder_section(smoke);
    batched_ladder_section(smoke);
    speculative_section(smoke);
    overload_ladder_section(smoke);
    kv_pressure_section(smoke);
    if !smoke {
        quantization_section();
    } else {
        println!("quantization throughput: skipped under --smoke");
    }
}
