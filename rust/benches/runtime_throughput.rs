//! §4.3 "Method runtime": quantization throughput (weights/second) per
//! setting, with an extrapolation to Llama-scale parameter counts — the
//! analog of the paper's "30 min – 11 h on one H100" claim for this
//! single-core CPU testbed.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("runtime_throughput: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("GPTVQ runtime (preset {preset}) + Llama-scale extrapolation"),
        &["method", "weights/s", "7B est (h)", "70B est (h)"],
    );

    let methods: Vec<(String, Method)> = vec![
        ("GPTQ W2@g128".into(), Method::Gptq { bits: 2, group_size: 128 }),
        ("GPTVQ 1D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(1, 2, 0.125))),
        ("GPTVQ 2D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 2, 0.125))),
        ("GPTVQ 2D 3b".into(), Method::Gptvq(GptvqConfig::for_setting(2, 3, 0.125))),
        ("GPTVQ 4D 2b".into(), Method::Gptvq(GptvqConfig::for_setting(4, 2, 0.25))),
    ];
    for (name, m) in methods {
        let run = ctx.run_method(m).unwrap();
        let wps = run.total_weights as f64 / run.quantize_seconds;
        let est = |params: f64| params / wps / 3600.0;
        t.row(&[name, fmt_f(wps), fmt_f(est(7e9)), fmt_f(est(70e9))]);
    }
    t.emit("runtime_throughput");
    println!("paper: 0.5-1 h (7B) and 3-11 h (70B) on one H100; scale by the CPU/GPU gap");
}
