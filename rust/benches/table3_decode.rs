//! Table 3: model footprint and decode throughput of VQ vs INT4/INT8 —
//! the on-device argument (Arm TBL analog on this CPU, see decode/).

use gptvq::decode::{decode_vq_f32, dequant_int4, dequant_int8, pack_int4, PackedIndices};
use gptvq::report::{fmt_f, Table};
use gptvq::util::timer::bench;
use gptvq::util::Rng;
use gptvq::vqformat::demo_linear;

const N: usize = 8 << 20;

/// Serving hot path: fused LUT decode-matmul straight from the packed
/// container vs materializing the dense matrix first (what the seed's
/// request path did at load).
fn fused_matvec_section(rng: &mut Rng) {
    let (rows, cols, d, k) = (512usize, 1024usize, 2usize, 16usize);
    let lin = demo_linear(rows, cols, d, k, rng);
    let x: Vec<f64> = rng.gaussian_vec(cols);
    let s_fused = bench(1, 7, || {
        let _ = lin.matvec(&x);
    });
    let s_dense = bench(1, 7, || {
        let _ = lin.decode().matvec(&x);
    });
    let mut t = Table::new(
        format!("fused VQ decode-matmul vs decode-then-matvec ({rows}x{cols}, d={d}, k={k})"),
        &["path", "matvec/s", "rel latency"],
    );
    t.row(&["decode + dense matvec".into(), fmt_f(1.0 / s_dense.median_s), "1.00x".into()]);
    t.row(&[
        "fused LUT matvec".into(),
        fmt_f(1.0 / s_fused.median_s),
        format!("{:.2}x", s_fused.median_s / s_dense.median_s),
    ]);
    t.emit("table3_fused_matvec");
}

fn main() {
    let mut rng = Rng::new(1);
    let mut out = vec![0f32; N];
    let mut t = Table::new(
        "Table 3: footprint and decode latency (relative to INT4)",
        &["setting", "bpv", "rel footprint", "Mweights/s", "rel latency"],
    );

    let codes4: Vec<u16> = (0..N).map(|_| rng.below(16) as u16).collect();
    let packed4 = pack_int4(&codes4);
    let gs = 64;
    let scales: Vec<f32> = (0..N / gs).map(|_| rng.range(0.01, 0.1) as f32).collect();
    let zeros: Vec<f32> = (0..N / gs).map(|_| rng.gaussian() as f32).collect();
    let s4 = bench(1, 7, || dequant_int4(&packed4, &scales, &zeros, gs, &mut out));
    t.row(&[
        "INT4".into(),
        "4".into(),
        "1.00x".into(),
        fmt_f(N as f64 / s4.median_s / 1e6),
        "1.00x".into(),
    ]);

    let codes8: Vec<i8> = (0..N).map(|_| rng.below(256) as i8).collect();
    let s8 = bench(1, 7, || dequant_int8(&codes8, &scales, gs, &mut out));
    t.row(&[
        "INT8".into(),
        "8".into(),
        "2.00x".into(),
        fmt_f(N as f64 / s8.median_s / 1e6),
        format!("{:.2}x", s8.median_s / s4.median_s),
    ]);

    let mut vq_beats_int4 = false;
    for (label, d, bits, group) in [
        ("2D 2.5B @ 512", 2usize, 5u32, 512usize),
        ("2D 2.5B @ 2048", 2, 5, 2048),
        ("2D 2B @ 1024", 2, 4, 1024),
        ("1D 3B @ 128", 1, 3, 128),
    ] {
        let k = 1usize << bits;
        let n_idx = N / d;
        let idx: Vec<u16> = (0..n_idx).map(|_| rng.below(k) as u16).collect();
        let packed = PackedIndices::pack(&idx, bits);
        let lut: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
        let s = bench(1, 7, || decode_vq_f32(&packed, &lut, d, &mut out));
        let bpv = gptvq::decode::vq_bytes_per_weight(d, bits, k, group) * 8.0;
        let rel = s.median_s / s4.median_s;
        if rel <= 1.0 {
            vq_beats_int4 = true;
        }
        t.row(&[
            label.into(),
            fmt_f(bpv),
            format!("{:.2}x", bpv / 4.0),
            fmt_f(N as f64 / s.median_s / 1e6),
            format!("{rel:.2}x"),
        ]);
    }
    t.emit("table3_decode");
    println!(
        "paper claim (VQ decode at or below INT4 latency): {}",
        if vq_beats_int4 { "reproduced for at least one setting" } else { "NOT reproduced on this CPU" }
    );
    fused_matvec_section(&mut rng);
}
