//! Tables 2 & 4: the main results grid — WikiText2-substitute perplexity
//! of RTN / GPTQ / GPTVQ 1D/2D/4D at the paper's bpv settings, across
//! model sizes.
//!
//! Settings mirror the paper exactly: 2.125 bpv (W2@g128), 2.25 (W2@g64),
//! 3.125 (W3@g128), 4.125 (W4@g128); GPTVQ group sizes hit the same
//! overhead with int8 codebooks.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn gptvq(d: usize, bits: u32, overhead: f64) -> Method {
    Method::Gptvq(GptvqConfig::for_setting(d, bits, overhead))
}

fn main() {
    let presets: Vec<String> = std::env::var("GPTVQ_BENCH_PRESETS")
        .unwrap_or_else(|_| "small,base".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();

    // (bpv label, uniform bits, uniform group, overhead, include 4D)
    let settings: &[(&str, u32, usize, f64, bool)] = &[
        ("2.125 bpv (W2@g128)", 2, 128, 0.125, false),
        ("2.25 bpv (W2@g64)", 2, 64, 0.25, true),
        ("3.125 bpv (W3@g128)", 3, 128, 0.125, false),
        ("4.125 bpv (W4@g128)", 4, 128, 0.125, false),
    ];

    let mut t = Table::new(
        "Tables 2/4: main grid — wiki-substitute perplexity",
        &["setting", "method", "model", "bpv", "ppl"],
    );

    for preset in &presets {
        if !artifacts_available(preset) {
            println!("table2_main: preset {preset} not built, skipping");
            continue;
        }
        let ctx = ExpContext::load(preset).unwrap();
        t.row(&["FP32".into(), "-".into(), preset.clone(), "32".into(), fmt_f(ctx.fp_perplexity())]);

        for &(label, bits, gs, overhead, with_4d) in settings {
            let mut methods: Vec<(String, Method)> = vec![
                ("RTN".into(), Method::Rtn { bits, group_size: gs }),
                ("GPTQ".into(), Method::Gptq { bits, group_size: gs }),
                ("GPTVQ 1D (ours)".into(), gptvq(1, bits, overhead)),
                ("GPTVQ 2D (ours)".into(), gptvq(2, bits, overhead)),
            ];
            if with_4d {
                methods.push(("GPTVQ 4D (ours)".into(), gptvq(4, bits, overhead)));
            }
            for (name, m) in methods {
                let run = ctx.run_method(m).unwrap();
                t.row(&[label.into(), name.clone(), preset.clone(), fmt_f(run.bpv), fmt_f(run.ppl)]);
                println!("[{preset}] {label} {name}: ppl {:.3} ({:.0}s quant)", run.ppl, run.quantize_seconds);
            }
        }
    }
    t.emit("table2_main");
}
