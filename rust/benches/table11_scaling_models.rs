//! Table 11: blockwise scaling on/off at equal total overhead (scaled
//! configs double the group size to pay for the 4-bit scale codes),
//! across model presets.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let presets: Vec<String> = std::env::var("GPTVQ_BENCH_PRESETS")
        .unwrap_or_else(|_| "tiny,small".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();

    let mut t = Table::new(
        "Table 11: scaling at equal overhead across models",
        &["d", "b", "gs", "scale", "model", "ppl"],
    );

    // paper pairs: (d, b, gs-no-scale, gs-with-scale, scale Ns)
    let rows: &[(usize, u32, usize, usize, usize)] = &[
        (1, 2, 256, 512, 64),
        (1, 3, 512, 1024, 64),
        (2, 2, 2048, 4096, 64),
        (2, 3, 8192, 16384, 64),
    ];

    for preset in &presets {
        if !artifacts_available(preset) {
            println!("table11: preset {preset} not built, skipping");
            continue;
        }
        let ctx = ExpContext::load(preset).unwrap();
        for &(d, b, gs_plain, gs_scaled, ns) in rows {
            for (scaled, gs) in [(false, gs_plain), (true, gs_scaled)] {
                let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
                cfg.group_size = gs;
                cfg.scale_block = if scaled { Some(ns) } else { None };
                let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
                t.row(&[
                    format!("{d}"),
                    format!("{b}"),
                    format!("{gs}"),
                    if scaled { "Y" } else { "N" }.into(),
                    preset.clone(),
                    fmt_f(run.ppl),
                ]);
            }
        }
    }
    t.emit("table11_scaling_models");
}
