//! Quantization-engine throughput: weights/sec of `gptvq_quantize` at
//! 1 vs N threads and f64 vs f32 compute precision on a synthetic
//! 512×512 layer, plus the PR 4 concurrency sections.
//!
//! Acceptance:
//! * ISSUE 2 — ≥2x weights/sec at 4 threads vs 1 thread (per precision)
//!   on the 512×512 layer, with bitwise-identical quantized weights
//!   across every thread count; the bench asserts the parity, so a
//!   determinism regression fails loudly here before it can corrupt an
//!   experiment.
//! * ISSUE 3 — ≥2x weights/sec for `--precision f32` over f64 at equal
//!   thread count (4), with the f32 final loss inside the
//!   `F32_LOSS_REL_TOL` guardrail of the f64 reference. Both are
//!   asserted/reported below; the accuracy guardrail is a hard assert,
//!   the speed targets print warnings on under-provisioned boxes.
//! * ISSUE 4 — the persistent-pool sections: stage dispatch on the
//!   long-lived `WorkerPool` vs a fresh `std::thread::scope` spawn per
//!   stage (the spawn-overhead win on small layers, measured rather
//!   than asserted), a many-small-layers run on one shared pool vs a
//!   pool per invocation, and the span-pipelining on/off wall time —
//!   each with bitwise output parity asserted.
//!
//! `--smoke` (the CI wiring) shrinks the layer and iteration counts so
//! the bench builds, runs, and keeps asserting parity + guardrail in
//! seconds — it cannot bit-rot even where the full run is too slow. CI
//! uploads the smoke output as a step summary, so the f64-vs-f32 ratio
//! and the pool-vs-spawn / span-pipelining lines are visible per run.

use gptvq::quant::gptvq::{
    gptvq_quantize, gptvq_quantize_on, GptvqConfig, GptvqResult, F32_LOSS_REL_TOL,
};
use gptvq::quant::HessianEstimator;
use gptvq::tensor::{matmul, Matrix, Precision};
use gptvq::util::{parallel_map, parallel_map_scoped, Rng, WorkerPool};

fn setup(rng: &mut Rng, r: usize, c: usize) -> (Matrix, HessianEstimator) {
    let w = Matrix::from_fn(r, c, |_, _| rng.gaussian() * 0.05);
    // mildly correlated activations so the Hessian is non-trivial
    let base = Matrix::from_fn(2 * c, c, |_, _| rng.gaussian());
    let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.05 * rng.gaussian() });
    let x = matmul(&base, &mix);
    let mut est = HessianEstimator::new(c);
    est.update(&x);
    (w, est)
}

/// Run one precision across the thread ladder, asserting cross-thread
/// parity, and return (weights/sec at 1 thread, weights/sec at max
/// threads, the 1-thread result for cross-precision accuracy checks).
fn run_precision(
    w: &Matrix,
    u: &Matrix,
    h: &Matrix,
    cfg: &mut GptvqConfig,
    precision: Precision,
    n_weights: f64,
    smoke: bool,
) -> (f64, f64, GptvqResult) {
    cfg.precision = precision;
    let mut baseline: Option<GptvqResult> = None;
    let mut wps = Vec::new();
    for nt in [1usize, 2, 4] {
        cfg.n_threads = nt;
        let t0 = std::time::Instant::now();
        let res = gptvq_quantize(w, u, h, cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "  {precision} threads {nt}: {secs:.3}s  {:>10.0} weights/s  (em {:.3}s, sweep {:.3}s, update {:.3}s)",
            n_weights / secs,
            res.stats.em_seconds,
            res.stats.sweep_seconds,
            res.stats.update_seconds
        );
        if let Some(b) = &baseline {
            assert_eq!(
                b.qweight, res.qweight,
                "thread count changed the quantized weights — determinism regression ({precision})"
            );
            assert_eq!(b.effective_bpv, res.effective_bpv, "bpv diverged across threads");
        }
        if baseline.is_none() {
            baseline = Some(res);
        }
        wps.push((nt, n_weights / secs));
    }
    let w1 = wps[0].1;
    let (nt_last, w_last) = *wps.last().unwrap();
    let speedup = w_last / w1;
    println!("  {precision} speedup at {nt_last} threads: {speedup:.2}x (target >=2x)");
    if !smoke && speedup < 2.0 {
        // report, don't abort: CI boxes may expose fewer than 4 real cores
        println!("  WARNING: {precision} below the 2x thread-speedup target — check core count / load");
    }
    (w1, w_last, baseline.unwrap())
}

/// Span pipelining on vs off on one layer at `nt` threads: identical
/// bits (asserted), overlapped wall time reported.
fn pipelining_section(w: &Matrix, u: &Matrix, h: &Matrix, base: &GptvqConfig, nt: usize) {
    let mut cfg = base.clone();
    cfg.n_threads = nt;
    cfg.span_pipeline = false;
    let t0 = std::time::Instant::now();
    let off = gptvq_quantize(w, u, h, &cfg).unwrap();
    let t_off = t0.elapsed().as_secs_f64();
    cfg.span_pipeline = true;
    let t1 = std::time::Instant::now();
    let on = gptvq_quantize(w, u, h, &cfg).unwrap();
    let t_on = t1.elapsed().as_secs_f64();
    assert_eq!(
        off.qweight, on.qweight,
        "span pipelining changed the quantized weights — schedule-parity regression"
    );
    println!(
        "  span pipelining at {nt} threads: off {t_off:.3}s, on {t_on:.3}s ({:.2}x)",
        t_off / t_on
    );
}

/// The spawn-overhead measurement the persistent pool exists for: many
/// small dispatches through the pool vs a fresh scoped fork-join each —
/// plus a many-small-layers engine run, shared pool vs per-invocation.
fn small_layer_section(smoke: bool) {
    let nt = 4;
    // (a) stage dispatch: pool vs per-stage spawn on a tiny stage shape
    let dispatches = if smoke { 500 } else { 5_000 };
    let pool = WorkerPool::new(nt);
    let work = |i: usize| -> u64 {
        let mut acc = i as u64;
        for v in 0..200u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(v);
        }
        acc
    };
    let t0 = std::time::Instant::now();
    let mut sink = 0u64;
    for _ in 0..dispatches {
        sink ^= parallel_map(&pool, nt, nt, work).into_iter().fold(0, |a, v| a ^ v);
    }
    let t_pool = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    for _ in 0..dispatches {
        sink ^= parallel_map_scoped(nt, nt, work).into_iter().fold(0, |a, v| a ^ v);
    }
    let t_spawn = t1.elapsed().as_secs_f64();
    std::hint::black_box(sink); // keep the work observable
    println!(
        "  pool vs spawn dispatch ({dispatches} stages of {nt} tasks): pool {:.1}µs/stage, spawn {:.1}µs/stage ({:.1}x)",
        1e6 * t_pool / dispatches as f64,
        1e6 * t_spawn / dispatches as f64,
        t_spawn / t_pool
    );
    if t_pool > t_spawn {
        println!("  WARNING: pool dispatch slower than per-stage spawn — pool regression");
    }

    // (b) many small layers: one shared pool across all layers vs a
    // fresh pool per gptvq_quantize invocation (the pre-PR 4 shape)
    let layers = if smoke { 4 } else { 16 };
    let (r, c) = (128, 128);
    let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
    cfg.em_iters = if smoke { 4 } else { 8 };
    cfg.update_iters = if smoke { 2 } else { 4 };
    cfg.n_threads = nt;
    let inputs: Vec<(Matrix, Matrix, Matrix)> = (0..layers)
        .map(|i| {
            let mut rng = Rng::new(0x5EED + i as u64);
            let (w, est) = setup(&mut rng, r, c);
            (w, est.inverse_factor(0.01).unwrap(), est.dampened(0.01))
        })
        .collect();
    let shared = WorkerPool::new(nt);
    let t2 = std::time::Instant::now();
    let res_shared: Vec<GptvqResult> = inputs
        .iter()
        .map(|(w, u, h)| gptvq_quantize_on(w, u, h, &cfg, &shared).unwrap())
        .collect();
    let t_shared = t2.elapsed().as_secs_f64();
    let t3 = std::time::Instant::now();
    let res_fresh: Vec<GptvqResult> =
        inputs.iter().map(|(w, u, h)| gptvq_quantize(w, u, h, &cfg).unwrap()).collect();
    let t_fresh = t3.elapsed().as_secs_f64();
    for (a, b) in res_shared.iter().zip(&res_fresh) {
        assert_eq!(a.qweight, b.qweight, "shared-pool output diverged from per-invocation");
    }
    println!(
        "  small layers ({layers}x {r}x{c}, {nt} threads): shared pool {t_shared:.3}s, pool per layer {t_fresh:.3}s ({:.2}x)",
        t_fresh / t_shared
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (r, c, em_iters, update_iters) =
        if smoke { (96, 128, 5, 2) } else { (512, 512, 30, 10) };

    let mut rng = Rng::new(0xBE9C);
    let (w, est) = setup(&mut rng, r, c);
    let u = est.inverse_factor(0.01).unwrap();
    let h = est.dampened(0.01);
    let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
    cfg.em_iters = em_iters;
    cfg.update_iters = update_iters;

    let n_weights = (r * c) as f64;
    println!(
        "quantize_throughput: {r}x{c} layer, d={} b={} em_iters={} update_iters={}{}",
        cfg.d,
        cfg.bits_per_dim,
        cfg.em_iters,
        cfg.update_iters,
        if smoke { " (smoke)" } else { "" }
    );

    let (_, w4_f64, res64) =
        run_precision(&w, &u, &h, &mut cfg, Precision::F64, n_weights, smoke);
    println!("  output parity across thread counts: OK (f64)");
    let (_, w4_f32, res32) =
        run_precision(&w, &u, &h, &mut cfg, Precision::F32, n_weights, smoke);
    println!("  output parity across thread counts: OK (f32)");

    // accuracy guardrail: the f32 path must land inside the pinned
    // relative tolerance of the f64 final loss — hard assert, both modes
    let (l64, l32) = (res64.stats.loss_after_update, res32.stats.loss_after_update);
    let rel = (l64 - l32).abs() / (1e-12 + l64.abs());
    println!(
        "  accuracy: f64 loss {l64:.6e}, f32 loss {l32:.6e}, rel diff {rel:.2e} (tol {F32_LOSS_REL_TOL})"
    );
    assert!(
        rel <= F32_LOSS_REL_TOL,
        "f32 loss {l32} outside guardrail of f64 {l64} (rel {rel:.4})"
    );

    // speed target: f32 >= 2x f64 at equal (max) thread count
    let ratio = w4_f32 / w4_f64;
    println!("  f32 over f64 at 4 threads: {ratio:.2}x (target >=2x on the 512x512 layer)");
    if !smoke && ratio < 2.0 {
        // report, don't abort: CI boxes may expose fewer than 4 real cores
        println!("  WARNING: f32/f64 ratio below the 2x target — check core count / load");
    }

    // PR 4 sections: span-pipelining overlap (multi-span geometry so the
    // deferred flush engages) and the persistent-pool wins
    let mut pipe_cfg = cfg.clone();
    pipe_cfg.precision = Precision::F64;
    pipe_cfg.max_group_cols = if smoke { 32 } else { 128 };
    pipelining_section(&w, &u, &h, &pipe_cfg, 4);
    small_layer_section(smoke);
    println!("  guardrail + parity: OK");
}
