//! Quantization-engine throughput: weights/sec of `gptvq_quantize` at
//! 1 vs N threads on a synthetic 512×512 layer.
//!
//! Acceptance (ISSUE 2): ≥2x weights/sec at 4 threads on the 512×512
//! layer, with bitwise-identical quantized weights across every thread
//! count — the bench asserts the parity, so a determinism regression
//! fails loudly here before it can corrupt an experiment.
//!
//! `--smoke` (the CI wiring) shrinks the layer and iteration counts so
//! the bench builds, runs, and keeps asserting parity in under a few
//! seconds — it cannot bit-rot even where the full run is too slow.

use gptvq::quant::gptvq::{gptvq_quantize, GptvqConfig, GptvqResult};
use gptvq::quant::HessianEstimator;
use gptvq::tensor::{matmul, Matrix};
use gptvq::util::Rng;

fn setup(rng: &mut Rng, r: usize, c: usize) -> (Matrix, HessianEstimator) {
    let w = Matrix::from_fn(r, c, |_, _| rng.gaussian() * 0.05);
    // mildly correlated activations so the Hessian is non-trivial
    let base = Matrix::from_fn(2 * c, c, |_, _| rng.gaussian());
    let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.05 * rng.gaussian() });
    let x = matmul(&base, &mix);
    let mut est = HessianEstimator::new(c);
    est.update(&x);
    (w, est)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (r, c, em_iters, update_iters) =
        if smoke { (96, 128, 5, 2) } else { (512, 512, 30, 10) };

    let mut rng = Rng::new(0xBE9C);
    let (w, est) = setup(&mut rng, r, c);
    let u = est.inverse_factor(0.01).unwrap();
    let h = est.dampened(0.01);
    let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
    cfg.em_iters = em_iters;
    cfg.update_iters = update_iters;

    let n_weights = (r * c) as f64;
    println!(
        "quantize_throughput: {r}x{c} layer, d={} b={} em_iters={} update_iters={}{}",
        cfg.d,
        cfg.bits_per_dim,
        cfg.em_iters,
        cfg.update_iters,
        if smoke { " (smoke)" } else { "" }
    );

    let mut baseline: Option<GptvqResult> = None;
    let mut wps = Vec::new();
    for nt in [1usize, 2, 4] {
        cfg.n_threads = nt;
        let t0 = std::time::Instant::now();
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "  threads {nt}: {secs:.3}s  {:>10.0} weights/s  (em {:.3}s, sweep {:.3}s, update {:.3}s)",
            n_weights / secs,
            res.stats.em_seconds,
            res.stats.sweep_seconds,
            res.stats.update_seconds
        );
        match &baseline {
            Some(b) => {
                assert_eq!(
                    b.qweight, res.qweight,
                    "thread count changed the quantized weights — determinism regression"
                );
                assert_eq!(b.effective_bpv, res.effective_bpv, "bpv diverged across threads");
            }
            None => {}
        }
        if baseline.is_none() {
            baseline = Some(res);
        }
        wps.push((nt, n_weights / secs));
    }

    let w1 = wps[0].1;
    let (nt_last, w_last) = *wps.last().unwrap();
    let speedup = w_last / w1;
    println!("  speedup at {nt_last} threads: {speedup:.2}x (target >=2x on the 512x512 layer)");
    println!("  output parity across thread counts: OK");
    if !smoke && speedup < 2.0 {
        // report, don't abort: CI boxes may expose fewer than 4 real cores
        println!("  WARNING: below the 2x target — check core count / load");
    }
}
