//! Quantization-engine throughput: weights/sec of `gptvq_quantize` at
//! 1 vs N threads and f64 vs f32 compute precision on a synthetic
//! 512×512 layer.
//!
//! Acceptance:
//! * ISSUE 2 — ≥2x weights/sec at 4 threads vs 1 thread (per precision)
//!   on the 512×512 layer, with bitwise-identical quantized weights
//!   across every thread count; the bench asserts the parity, so a
//!   determinism regression fails loudly here before it can corrupt an
//!   experiment.
//! * ISSUE 3 — ≥2x weights/sec for `--precision f32` over f64 at equal
//!   thread count (4), with the f32 final loss inside the
//!   `F32_LOSS_REL_TOL` guardrail of the f64 reference. Both are
//!   asserted/reported below; the accuracy guardrail is a hard assert,
//!   the speed targets print warnings on under-provisioned boxes.
//!
//! `--smoke` (the CI wiring) shrinks the layer and iteration counts so
//! the bench builds, runs, and keeps asserting parity + guardrail in
//! seconds — it cannot bit-rot even where the full run is too slow. CI
//! uploads the smoke output as a step summary, so the f64-vs-f32 ratio
//! is visible per run.

use gptvq::quant::gptvq::{gptvq_quantize, GptvqConfig, GptvqResult, F32_LOSS_REL_TOL};
use gptvq::quant::HessianEstimator;
use gptvq::tensor::{matmul, Matrix, Precision};
use gptvq::util::Rng;

fn setup(rng: &mut Rng, r: usize, c: usize) -> (Matrix, HessianEstimator) {
    let w = Matrix::from_fn(r, c, |_, _| rng.gaussian() * 0.05);
    // mildly correlated activations so the Hessian is non-trivial
    let base = Matrix::from_fn(2 * c, c, |_, _| rng.gaussian());
    let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.05 * rng.gaussian() });
    let x = matmul(&base, &mix);
    let mut est = HessianEstimator::new(c);
    est.update(&x);
    (w, est)
}

/// Run one precision across the thread ladder, asserting cross-thread
/// parity, and return (weights/sec at 1 thread, weights/sec at max
/// threads, the 1-thread result for cross-precision accuracy checks).
fn run_precision(
    w: &Matrix,
    u: &Matrix,
    h: &Matrix,
    cfg: &mut GptvqConfig,
    precision: Precision,
    n_weights: f64,
    smoke: bool,
) -> (f64, f64, GptvqResult) {
    cfg.precision = precision;
    let mut baseline: Option<GptvqResult> = None;
    let mut wps = Vec::new();
    for nt in [1usize, 2, 4] {
        cfg.n_threads = nt;
        let t0 = std::time::Instant::now();
        let res = gptvq_quantize(w, u, h, cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "  {precision} threads {nt}: {secs:.3}s  {:>10.0} weights/s  (em {:.3}s, sweep {:.3}s, update {:.3}s)",
            n_weights / secs,
            res.stats.em_seconds,
            res.stats.sweep_seconds,
            res.stats.update_seconds
        );
        if let Some(b) = &baseline {
            assert_eq!(
                b.qweight, res.qweight,
                "thread count changed the quantized weights — determinism regression ({precision})"
            );
            assert_eq!(b.effective_bpv, res.effective_bpv, "bpv diverged across threads");
        }
        if baseline.is_none() {
            baseline = Some(res);
        }
        wps.push((nt, n_weights / secs));
    }
    let w1 = wps[0].1;
    let (nt_last, w_last) = *wps.last().unwrap();
    let speedup = w_last / w1;
    println!("  {precision} speedup at {nt_last} threads: {speedup:.2}x (target >=2x)");
    if !smoke && speedup < 2.0 {
        // report, don't abort: CI boxes may expose fewer than 4 real cores
        println!("  WARNING: {precision} below the 2x thread-speedup target — check core count / load");
    }
    (w1, w_last, baseline.unwrap())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (r, c, em_iters, update_iters) =
        if smoke { (96, 128, 5, 2) } else { (512, 512, 30, 10) };

    let mut rng = Rng::new(0xBE9C);
    let (w, est) = setup(&mut rng, r, c);
    let u = est.inverse_factor(0.01).unwrap();
    let h = est.dampened(0.01);
    let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
    cfg.em_iters = em_iters;
    cfg.update_iters = update_iters;

    let n_weights = (r * c) as f64;
    println!(
        "quantize_throughput: {r}x{c} layer, d={} b={} em_iters={} update_iters={}{}",
        cfg.d,
        cfg.bits_per_dim,
        cfg.em_iters,
        cfg.update_iters,
        if smoke { " (smoke)" } else { "" }
    );

    let (_, w4_f64, res64) =
        run_precision(&w, &u, &h, &mut cfg, Precision::F64, n_weights, smoke);
    println!("  output parity across thread counts: OK (f64)");
    let (_, w4_f32, res32) =
        run_precision(&w, &u, &h, &mut cfg, Precision::F32, n_weights, smoke);
    println!("  output parity across thread counts: OK (f32)");

    // accuracy guardrail: the f32 path must land inside the pinned
    // relative tolerance of the f64 final loss — hard assert, both modes
    let (l64, l32) = (res64.stats.loss_after_update, res32.stats.loss_after_update);
    let rel = (l64 - l32).abs() / (1e-12 + l64.abs());
    println!(
        "  accuracy: f64 loss {l64:.6e}, f32 loss {l32:.6e}, rel diff {rel:.2e} (tol {F32_LOSS_REL_TOL})"
    );
    assert!(
        rel <= F32_LOSS_REL_TOL,
        "f32 loss {l32} outside guardrail of f64 {l64} (rel {rel:.4})"
    );

    // speed target: f32 >= 2x f64 at equal (max) thread count
    let ratio = w4_f32 / w4_f64;
    println!("  f32 over f64 at 4 threads: {ratio:.2}x (target >=2x on the 512x512 layer)");
    if !smoke && ratio < 2.0 {
        // report, don't abort: CI boxes may expose fewer than 4 real cores
        println!("  WARNING: f32/f64 ratio below the 2x target — check core count / load");
    }
    println!("  guardrail + parity: OK");
}
