//! Table 6: EM seeding ablation — Mahalanobis initialization vs k-means++
//! (final perplexity and quantization wall time).

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::quant::vq::seed::SeedMethod;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table6_seeding: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 6: EM seeding method, preset {preset}"),
        &["lookup", "seeding", "bpv", "ppl", "quant s"],
    );

    for (label, d, bits, overhead) in [
        ("1D 3B", 1usize, 3u32, 0.125),
        ("2D 3B", 2, 3, 0.125),
        ("1D 4B", 1, 4, 0.125),
        ("2D 4B", 2, 4, 0.125),
    ] {
        for (sname, seed) in [("Mahalanobis", SeedMethod::Mahalanobis), ("K++", SeedMethod::KmeansPlusPlus)] {
            let mut cfg = GptvqConfig::for_setting(d, bits, overhead);
            cfg.seed_method = seed;
            let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
            t.row(&[
                label.into(),
                sname.into(),
                fmt_f(run.bpv),
                fmt_f(run.ppl),
                fmt_f(run.quantize_seconds),
            ]);
        }
    }
    t.emit("table6_seeding");
    println!("paper shape: Mahalanobis matches K++ quality at lower seed cost");
}
