//! Table 10: blockwise data-normalization block-size sweep (none, 128,
//! 64, 32, 16, 8) for 1D/2D x 2/3-bit settings.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table10_scaling: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 10: scaling block size, preset {preset}"),
        &["d", "b", "scaling BS", "ppl"],
    );

    let blocks: [Option<usize>; 6] = [None, Some(128), Some(64), Some(32), Some(16), Some(8)];
    for (d, b) in [(1usize, 2u32), (1, 3), (2, 2), (2, 3)] {
        for sb in blocks {
            let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
            cfg.scale_block = sb;
            let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
            let label = sb.map(|v| v.to_string()).unwrap_or_else(|| "None".into());
            t.row(&[format!("{d}"), format!("{b}"), label, fmt_f(run.ppl)]);
        }
    }
    t.emit("table10_scaling");
    println!("paper shape: smaller blocks generally help (except 1D 2-bit)");
}
