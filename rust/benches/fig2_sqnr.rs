//! Figure 2: SQNR vs quantization dimensionality on the trained model's
//! weights at equal (0.25 bpv) codebook/scale overhead.
//!
//! This measures the *representational accuracy of the grid itself* —
//! pure quantizer fits (uniform RTN vs plain k-means VQ), no error
//! feedback, exactly like the paper's figure (error feedback would trade
//! weight-SQNR for output error and muddy the comparison).

use gptvq::eval::sqnr_model;
use gptvq::quant::bpv::{centroids_for, group_size_for_overhead};
use gptvq::quant::kmeans::kmeans_vq_quantize;
use gptvq::quant::uniform::rtn_quantize;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("fig2_sqnr: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let subset: Vec<_> = ctx.model.quant_targets();
    let bits = 2u32;
    let originals: Vec<_> = subset.iter().map(|&(l, k)| ctx.model.linear(l, k).transpose()).collect();

    let mut t = Table::new(
        format!("Fig 2: SQNR vs quantizer dimensionality, {bits} bits/dim, preset {preset}"),
        &["quantizer", "sqnr dB"],
    );

    // uniform at the same index bits; 16-bit scales per g64 = 0.25 bpv
    let uni: Vec<_> = originals.iter().map(|w| rtn_quantize(w, bits, 64).dequantize()).collect();
    let pairs: Vec<(&_, &_)> = originals.iter().zip(uni.iter()).collect();
    let mut prev = sqnr_model(&pairs);
    t.row(&["uniform".into(), fmt_f(prev)]);

    let mut monotone = true;
    for d in [1usize, 2, 4] {
        let k = centroids_for(d, bits);
        let gs = group_size_for_overhead(d, k, 8, None, 0.25).unwrap();
        let quantized: Vec<_> = originals
            .iter()
            .map(|w| kmeans_vq_quantize(w, d, k, gs, 256, None, 40, 0))
            .collect();
        let pairs: Vec<(&_, &_)> = originals.iter().zip(quantized.iter()).collect();
        let s = sqnr_model(&pairs);
        t.row(&[format!("VQ {d}D"), fmt_f(s)]);
        println!("d={d}: sqnr {s:.2} dB (prev {prev:.2})");
        if s < prev {
            monotone = false;
        }
        prev = s;
    }
    t.emit("fig2_sqnr");
    println!(
        "paper shape (SQNR increases with dimensionality): {}",
        if monotone { "reproduced" } else { "partially reproduced" }
    );
}
