//! Table 9: effect of the post-hoc codebook update (GD on the layer loss)
//! — perplexity gain vs added runtime.

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table9_update: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 9: codebook update ablation, preset {preset}"),
        &["d", "b", "update", "ppl", "quant s"],
    );

    for (d, b) in [(1usize, 2u32), (1, 3), (2, 2), (2, 3)] {
        let mut worse_without = 0;
        let mut ppl_with = 0.0;
        for update in [false, true] {
            let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
            cfg.update_iters = if update { 25 } else { 0 };
            let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
            t.row(&[
                format!("{d}"),
                format!("{b}"),
                if update { "Y" } else { "N" }.into(),
                fmt_f(run.ppl),
                fmt_f(run.quantize_seconds),
            ]);
            if update {
                ppl_with = run.ppl;
            } else if run.ppl > ppl_with {
                worse_without += 1;
            }
            let _ = worse_without;
        }
    }
    t.emit("table9_update");
    println!("paper shape: update never hurts, helps most at 2 bits");
}
