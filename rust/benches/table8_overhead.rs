//! Table 8: equal-overhead choices — fp16 codebooks vs int8 codebooks at
//! half the group size vs SVD-compressed codebooks (1D only).

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table8_overhead: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 8: codebook storage choices at equal overhead, preset {preset}"),
        &["d", "b", "gs", "Q", "SVD", "nominal bpv", "ppl"],
    );

    // (d, b, fp16 group, int8 group) pairs at equal overhead, as in the paper
    let rows: &[(usize, u32, usize, usize)] =
        &[(1, 2, 512, 256), (1, 3, 1024, 512), (2, 2, 4096, 2048), (2, 3, 16384, 8192)];

    for &(d, b, gs_fp16, gs_int8) in rows {
        // fp16 codebook, larger group
        let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
        cfg.codebook_bits = 16;
        cfg.group_size = gs_fp16;
        let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
        let nominal = b as f64 + (run.bpv - b as f64);
        t.row(&[
            format!("{d}"),
            format!("{b}"),
            format!("{gs_fp16}"),
            "N".into(),
            "N".into(),
            fmt_f(nominal),
            fmt_f(run.ppl),
        ]);

        // int8 codebook, half group
        let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
        cfg.codebook_bits = 8;
        cfg.group_size = gs_int8;
        let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
        t.row(&[
            format!("{d}"),
            format!("{b}"),
            format!("{gs_int8}"),
            "Y".into(),
            "N".into(),
            fmt_f(b as f64 + (run.bpv - b as f64)),
            fmt_f(run.ppl),
        ]);

        // SVD halved-rank codebooks (1D only, per the paper)
        if d == 1 {
            let mut cfg = GptvqConfig::for_setting(d, b, 0.125);
            cfg.codebook_bits = 16;
            cfg.group_size = gs_int8;
            cfg.svd_rank_frac = Some(0.5);
            let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
            t.row(&[
                format!("{d}"),
                format!("{b}"),
                format!("{gs_int8}"),
                "N".into(),
                "Y".into(),
                fmt_f(b as f64 + (run.bpv - b as f64)),
                fmt_f(run.ppl),
            ]);
        }
    }
    t.emit("table8_overhead");
    println!("paper shape: int8 codebooks + halved groups generally win");
}
