//! Table 1: k-means VQ (with and without input data) vs uniform
//! quantization vs GPTVQ — the motivation table showing clustering alone
//! is not enough at low bitwidths.

use gptvq::coordinator::Method;
use gptvq::quant::bpv::centroids_for;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table1_kmeans: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 1: 2D VQ k-means vs uniform vs GPTVQ on preset {preset}"),
        &["setting", "with data", "ppl"],
    );
    t.row(&["FP32".into(), "n/a".into(), fmt_f(ctx.fp_perplexity())]);

    for bits in [2u32, 3, 4] {
        let k = centroids_for(2, bits);
        // group size for 0.25 bpv overhead with int8 codebooks
        let gs = gptvq::quant::bpv::group_size_for_overhead(2, k, 8, None, 0.25).unwrap();
        for data_aware in [false, true] {
            let run = ctx
                .run_method(Method::Kmeans { d: 2, k, group_size: gs, data_aware, iters: 60 })
                .unwrap();
            t.row(&[
                format!("{bits} bits per dim"),
                if data_aware { "Yes" } else { "No" }.into(),
                fmt_f(run.ppl),
            ]);
        }
        // GPTVQ row for contrast (the paper's fix)
        let mut cfg = GptvqConfig::for_setting(2, bits, 0.25);
        cfg.em_iters = 50;
        let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
        t.row(&[format!("{bits} bits per dim (GPTVQ)"), "Yes".into(), fmt_f(run.ppl)]);
    }
    for bits in [3u32, 4] {
        let run = ctx.run_method(Method::Gptq { bits, group_size: 128 }).unwrap();
        t.row(&[format!("Uniform {bits} bit"), "Yes".into(), fmt_f(run.ppl)]);
    }
    t.emit("table1_kmeans");
}
