//! Table 7: effect of the number of EM initialization iterations on final
//! perplexity (paper: monotone small gains up to 100).

use gptvq::coordinator::Method;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, ExpContext};
use gptvq::report::{fmt_f, Table};

fn main() {
    let preset = std::env::var("GPTVQ_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    if !artifacts_available(&preset) {
        println!("table7_em_iters: artifacts not built, skipping");
        return;
    }
    let ctx = ExpContext::load(&preset).unwrap();
    let mut t = Table::new(
        format!("Table 7: EM iterations, 2D 3-bit VQ, preset {preset}"),
        &["EM iterations", "ppl", "quant s"],
    );
    for iters in [10usize, 30, 50, 75, 100] {
        let mut cfg = GptvqConfig::for_setting(2, 3, 0.125);
        cfg.em_iters = iters;
        // isolate init quality: no codebook update pass
        cfg.update_iters = 0;
        let run = ctx.run_method(Method::Gptvq(cfg)).unwrap();
        t.row(&[format!("{iters}"), fmt_f(run.ppl), fmt_f(run.quantize_seconds)]);
    }
    t.emit("table7_em_iters");
}
