//! GVQMODL1 — the packed vector-quantized model container.
//!
//! What a deployment actually ships (paper §4.2): per quantized linear, the
//! packed index bitstream, int8 codebooks with per-group scales, and the
//! 4-bit block-scale codes; plus the unquantized tensors (norms, embedding,
//! head) in f32. Readable back into either a dense `Model` (for eval) or a
//! streaming decode path (for `serve`).
//!
//! Layout (LE): magic `GVQMODL1`, u32 n_records, then records tagged by a
//! u8 kind: 0 = dense f32 tensor, 1 = VQ linear.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::decode::pack::PackedIndices;
use crate::error::{Error, Result};
use crate::quant::vq::scales::{unit_scales, BlockScales};
use crate::quant::vq::{Codebook, VqGroup};
use crate::tensor::Matrix;

const MAGIC: &[u8; 8] = b"GVQMODL1";

/// Serialized form of one quantized linear layer (paper layout [out, in]).
#[derive(Debug, Clone)]
pub struct VqLinear {
    pub rows: usize,
    pub cols: usize,
    pub d: usize,
    pub k: usize,
    pub groups: Vec<VqGroupPacked>,
}

/// One group: geometry + int8 codebook + packed assignments + scale codes.
#[derive(Debug, Clone)]
pub struct VqGroupPacked {
    pub row0: u32,
    pub row1: u32,
    pub col0: u32,
    pub col1: u32,
    /// int8 codebook values (k*d) with one f32 scale
    pub codebook_q: Vec<i8>,
    pub codebook_scale: f32,
    pub assignments: PackedIndices,
    /// 4-bit block-scale codes + grid (a, z); block_size == cols span when
    /// scaling is off (single unit block)
    pub scale_block: u32,
    pub scale_codes: Vec<u8>,
    pub scale_a: f32,
    pub scale_z: f32,
}

/// A full packed model: VQ linears + dense residual tensors.
#[derive(Debug, Clone, Default)]
pub struct VqModel {
    pub dense: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    pub linears: BTreeMap<String, VqLinear>,
}

/// Convert a quantized group set into packed form.
pub fn pack_groups(rows: usize, cols: usize, d: usize, k: usize, groups: &[VqGroup]) -> VqLinear {
    let bits = (k as f64).log2().ceil() as u32;
    let packed_groups = groups
        .iter()
        .map(|g| {
            // int8-quantize the codebook (idempotent if already int8-gridded)
            let mx = g.codebook.centroids.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let scale = if mx > 0.0 { mx / 127.0 } else { 1.0 };
            let codebook_q: Vec<i8> = g
                .codebook
                .centroids
                .iter()
                .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
                .collect();
            let idx: Vec<u16> = g.assignments.iter().map(|&a| a as u16).collect();
            VqGroupPacked {
                row0: g.row0 as u32,
                row1: g.row1 as u32,
                col0: g.col0 as u32,
                col1: g.col1 as u32,
                codebook_q,
                codebook_scale: scale as f32,
                assignments: PackedIndices::pack(&idx, bits.max(1)),
                scale_block: g.scales.block_size as u32,
                scale_codes: g.scales.codes.clone(),
                scale_a: g.scales.a as f32,
                scale_z: g.scales.z as f32,
            }
        })
        .collect();
    VqLinear { rows, cols, d, k, groups: packed_groups }
}

/// Build a synthetic single-group packed linear with uniform random
/// assignments and unit scales — the shared workload generator for the
/// decode benches and examples.
pub fn demo_linear(rows: usize, cols: usize, d: usize, k: usize, rng: &mut crate::util::Rng) -> VqLinear {
    let strips = cols / d;
    let group = VqGroup {
        row0: 0,
        row1: rows,
        col0: 0,
        col1: cols,
        codebook: Codebook::from_centroids(d, rng.gaussian_vec(k * d)),
        assignments: (0..rows * strips).map(|_| rng.below(k) as u32).collect(),
        scales: unit_scales(rows, cols),
    };
    pack_groups(rows, cols, d, k, &[group])
}

impl VqLinear {
    /// Decode to a dense matrix (paper layout).
    pub fn decode(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for g in &self.groups {
            let gr = (g.row1 - g.row0) as usize;
            let span = (g.col1 - g.col0) as usize;
            let strips = span / self.d;
            let scales = BlockScales {
                block_size: g.scale_block as usize,
                rows: gr,
                cols: span,
                codes: g.scale_codes.clone(),
                a: g.scale_a as f64,
                z: g.scale_z as f64,
            };
            for lr in 0..gr {
                for j in 0..strips {
                    let a = g.assignments.get(lr * strips + j) as usize;
                    for t in 0..self.d {
                        let lc = j * self.d + t;
                        let val = g.codebook_q[a * self.d + t] as f64
                            * g.codebook_scale as f64
                            * scales.scale_at(lr, lc);
                        out.set(g.row0 as usize + lr, g.col0 as usize + lc, val);
                    }
                }
            }
        }
        out
    }

    /// Rebuild the in-memory group representation (for decode kernels).
    pub fn unpack_groups(&self) -> Vec<VqGroup> {
        self.groups
            .iter()
            .map(|g| {
                let gr = (g.row1 - g.row0) as usize;
                let span = (g.col1 - g.col0) as usize;
                let centroids: Vec<f64> = g
                    .codebook_q
                    .iter()
                    .map(|&q| q as f64 * g.codebook_scale as f64)
                    .collect();
                VqGroup {
                    row0: g.row0 as usize,
                    row1: g.row1 as usize,
                    col0: g.col0 as usize,
                    col1: g.col1 as usize,
                    codebook: Codebook::from_centroids(self.d, centroids),
                    assignments: g.assignments.iter().map(|v| v as u32).collect(),
                    scales: BlockScales {
                        block_size: g.scale_block as usize,
                        rows: gr,
                        cols: span,
                        codes: g.scale_codes.clone(),
                        a: g.scale_a as f64,
                        z: g.scale_z as f64,
                    },
                }
            })
            .collect()
    }

    /// Fused LUT decode + mat-vec: `y = W·x` with `W [rows, cols]` in
    /// paper layout, computed straight from packed indices and int8
    /// codebooks — the scalar analog of the Pallas `vq_decode_matmul`
    /// kernel. Per (group, strip) a k-entry table of centroid partial
    /// dots `Σ_t cb[a,t]·x[col+t]` is built once, so every weight strip
    /// costs one packed-index read plus one table lookup, and the dense
    /// matrix is never materialized.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec input dim");
        let d = self.d;
        let mut y = vec![0.0f64; self.rows];
        for g in &self.groups {
            let gr = (g.row1 - g.row0) as usize;
            let span = (g.col1 - g.col0) as usize;
            let strips = span / d;
            let kk = g.codebook_q.len() / d;
            let cb_scale = g.codebook_scale as f64;
            // per-strip partial-dot tables over the centroids
            let mut table = vec![0.0f64; strips * kk];
            for j in 0..strips {
                let xoff = g.col0 as usize + j * d;
                let trow = &mut table[j * kk..(j + 1) * kk];
                for (a, tv) in trow.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += g.codebook_q[a * d + t] as f64 * x[xoff + t];
                    }
                    *tv = acc * cb_scale;
                }
            }
            // 4-bit block-scale codes decode through a 16-entry LUT
            let mut scale_lut = [0.0f64; 16];
            for (code, s) in scale_lut.iter_mut().enumerate() {
                *s = (g.scale_z as f64 + code as f64 * g.scale_a as f64).exp2();
            }
            let block = g.scale_block as usize;
            let bpr = span.div_ceil(block);
            // detlint: hot(fused-matvec) — per-row LUT accumulation, the serving
            // decode inner loop; one table read per strip, no allocation
            for lr in 0..gr {
                let codes_row = &g.scale_codes[lr * bpr..(lr + 1) * bpr];
                let mut acc = 0.0;
                for j in 0..strips {
                    let a = g.assignments.get(lr * strips + j) as usize;
                    let c0 = j * d;
                    if c0 / block == (c0 + d - 1) / block {
                        // strip lies inside one scale block: fused lookup
                        acc += scale_lut[codes_row[c0 / block] as usize] * table[j * kk + a];
                    } else {
                        // strip crosses a scale-block boundary: per-element
                        for t in 0..d {
                            acc += g.codebook_q[a * d + t] as f64
                                * cb_scale
                                * scale_lut[codes_row[(c0 + t) / block] as usize]
                                * x[g.col0 as usize + c0 + t];
                        }
                    }
                }
                y[g.row0 as usize + lr] += acc;
            }
            // detlint: endhot
        }
        y
    }

    /// Fused decode-matmul: `x [m, cols] -> x·Wᵀ [m, rows]` without
    /// materializing `W`. The multi-row generalization of
    /// [`Self::matvec`]: partial-dot tables are built per activation row
    /// (they depend on `x`), but the packed-index extraction and the
    /// scale-LUT lookup per weight strip happen **once per strip for the
    /// whole batch** instead of once per activation row — the win that
    /// makes batched speculative verification on the incremental path
    /// cheaper than row-at-a-time decode, and that the engine's
    /// cross-slot batched step rides: a ragged batch stacks rows from
    /// MANY sessions, so the fused backend decodes each linear once per
    /// engine step instead of once per slot. Because every output row is
    /// computed independently, batch composition cannot change any row's
    /// result. Bitwise identical to calling
    /// [`Self::matvec`] per row (same per-row accumulation order; tested).
    pub fn matmul_decoded(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.cols, "matmul_decoded inner dim");
        let m = x.rows();
        let d = self.d;
        let mut out = Matrix::zeros(m, self.rows);
        for g in &self.groups {
            let gr = (g.row1 - g.row0) as usize;
            let span = (g.col1 - g.col0) as usize;
            let strips = span / d;
            let kk = g.codebook_q.len() / d;
            let cb_scale = g.codebook_scale as f64;
            // per (activation row, strip) partial-dot tables over the
            // centroids — identical values to the matvec tables
            let skk = strips * kk;
            if skk == 0 {
                continue; // degenerate group narrower than one strip
            }
            let mut table = vec![0.0f64; m * skk];
            for (r, trows) in table.chunks_exact_mut(skk).enumerate() {
                let xr = x.row(r);
                for j in 0..strips {
                    let xoff = g.col0 as usize + j * d;
                    let trow = &mut trows[j * kk..(j + 1) * kk];
                    for (a, tv) in trow.iter_mut().enumerate() {
                        let mut acc = 0.0;
                        for t in 0..d {
                            acc += g.codebook_q[a * d + t] as f64 * xr[xoff + t];
                        }
                        *tv = acc * cb_scale;
                    }
                }
            }
            // 4-bit block-scale codes decode through a 16-entry LUT
            let mut scale_lut = [0.0f64; 16];
            for (code, s) in scale_lut.iter_mut().enumerate() {
                *s = (g.scale_z as f64 + code as f64 * g.scale_a as f64).exp2();
            }
            let block = g.scale_block as usize;
            let bpr = span.div_ceil(block);
            let mut acc = vec![0.0f64; m];
            // detlint: hot(fused-matmul) — multi-row LUT accumulation; scratch
            // `acc` is allocated once per group above and reused per row
            for lr in 0..gr {
                let codes_row = &g.scale_codes[lr * bpr..(lr + 1) * bpr];
                for j in 0..strips {
                    // one packed-index read + one scale lookup per strip,
                    // amortized across all m activation rows
                    let a = g.assignments.get(lr * strips + j) as usize;
                    let c0 = j * d;
                    if c0 / block == (c0 + d - 1) / block {
                        // strip lies inside one scale block: fused lookup
                        let s = scale_lut[codes_row[c0 / block] as usize];
                        for (r, av) in acc.iter_mut().enumerate() {
                            *av += s * table[r * skk + j * kk + a];
                        }
                    } else {
                        // strip crosses a scale-block boundary: per-element
                        for t in 0..d {
                            let w = g.codebook_q[a * d + t] as f64
                                * cb_scale
                                * scale_lut[codes_row[(c0 + t) / block] as usize];
                            let col = g.col0 as usize + c0 + t;
                            for (r, av) in acc.iter_mut().enumerate() {
                                *av += w * x.get(r, col);
                            }
                        }
                    }
                }
                let oc = g.row0 as usize + lr;
                for (r, av) in acc.iter_mut().enumerate() {
                    out.row_mut(r)[oc] += *av;
                    *av = 0.0;
                }
            }
            // detlint: endhot
        }
        out
    }

    /// Total packed bytes (indices + codebooks + scale codes).
    pub fn packed_bytes(&self) -> usize {
        self.groups
            .iter()
            .map(|g| g.assignments.byte_len() + g.codebook_q.len() + g.scale_codes.len() + 12)
            .sum()
    }

    /// Effective bits per value of the packed representation.
    pub fn bits_per_value(&self) -> f64 {
        8.0 * self.packed_bytes() as f64 / (self.rows * self.cols) as f64
    }
}

// ---------------------------------------------------------------------------
// serialization

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_str(w: &mut impl Write, s: &str) -> Result<()> {
    w_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    path: String,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::format(&self.path, "truncated"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|e| Error::format(&self.path, format!("bad utf8: {e}")))
    }
}

impl VqModel {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        f.write_all(MAGIC)?;
        w_u32(&mut f, (self.dense.len() + self.linears.len()) as u32)?;
        for (name, (shape, data)) in &self.dense {
            f.write_all(&[0u8])?;
            w_str(&mut f, name)?;
            w_u32(&mut f, shape.len() as u32)?;
            for &d in shape {
                w_u32(&mut f, d as u32)?;
            }
            for v in data {
                w_f32(&mut f, *v)?;
            }
        }
        for (name, lin) in &self.linears {
            f.write_all(&[1u8])?;
            w_str(&mut f, name)?;
            w_u32(&mut f, lin.rows as u32)?;
            w_u32(&mut f, lin.cols as u32)?;
            w_u32(&mut f, lin.d as u32)?;
            w_u32(&mut f, lin.k as u32)?;
            w_u32(&mut f, lin.groups.len() as u32)?;
            for g in &lin.groups {
                for v in [g.row0, g.row1, g.col0, g.col1, g.scale_block] {
                    w_u32(&mut f, v)?;
                }
                w_f32(&mut f, g.codebook_scale)?;
                w_f32(&mut f, g.scale_a)?;
                w_f32(&mut f, g.scale_z)?;
                w_u32(&mut f, g.codebook_q.len() as u32)?;
                f.write_all(&g.codebook_q.iter().map(|&v| v as u8).collect::<Vec<u8>>())?;
                w_u32(&mut f, g.assignments.bits)?;
                w_u32(&mut f, g.assignments.n as u32)?;
                w_u32(&mut f, g.assignments.data.len() as u32)?;
                f.write_all(&g.assignments.data)?;
                w_u32(&mut f, g.scale_codes.len() as u32)?;
                f.write_all(&g.scale_codes)?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<VqModel> {
        let path_str = path.as_ref().display().to_string();
        let buf = std::fs::read(path.as_ref())?;
        if buf.len() < 12 || &buf[..8] != MAGIC {
            return Err(Error::format(&path_str, "bad GVQMODL1 magic"));
        }
        let mut r = Reader { buf: &buf, pos: 8, path: path_str };
        let count = r.u32()?;
        let mut model = VqModel::default();
        for _ in 0..count {
            let kind = r.take(1)?[0];
            let name = r.string()?;
            match kind {
                0 => {
                    let ndim = r.u32()? as usize;
                    let mut shape = Vec::with_capacity(ndim);
                    for _ in 0..ndim {
                        shape.push(r.u32()? as usize);
                    }
                    let numel: usize = shape.iter().product();
                    let raw = r.take(numel * 4)?;
                    let data = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    model.dense.insert(name, (shape, data));
                }
                1 => {
                    let rows = r.u32()? as usize;
                    let cols = r.u32()? as usize;
                    let d = r.u32()? as usize;
                    let k = r.u32()? as usize;
                    let ngroups = r.u32()? as usize;
                    let mut groups = Vec::with_capacity(ngroups);
                    for _ in 0..ngroups {
                        let row0 = r.u32()?;
                        let row1 = r.u32()?;
                        let col0 = r.u32()?;
                        let col1 = r.u32()?;
                        let scale_block = r.u32()?;
                        let codebook_scale = r.f32()?;
                        let scale_a = r.f32()?;
                        let scale_z = r.f32()?;
                        let cb_len = r.u32()? as usize;
                        let codebook_q = r.take(cb_len)?.iter().map(|&b| b as i8).collect();
                        let bits = r.u32()?;
                        let n = r.u32()? as usize;
                        let dlen = r.u32()? as usize;
                        let data = r.take(dlen)?.to_vec();
                        let slen = r.u32()? as usize;
                        let scale_codes = r.take(slen)?.to_vec();
                        groups.push(VqGroupPacked {
                            row0,
                            row1,
                            col0,
                            col1,
                            codebook_q,
                            codebook_scale,
                            assignments: PackedIndices { bits, n, data },
                            scale_block,
                            scale_codes,
                            scale_a,
                            scale_z,
                        });
                    }
                    model.linears.insert(name, VqLinear { rows, cols, d, k, groups });
                }
                other => return Err(Error::format(&r.path, format!("unknown record kind {other}"))),
            }
        }
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::scales::unit_scales;
    use crate::quant::vq::{assign_diag, decode_groups};
    use crate::util::Rng;

    fn sample_groups(rng: &mut Rng, rows: usize, cols: usize, d: usize, k: usize) -> Vec<VqGroup> {
        // two row strips, one span
        let half = rows / 2;
        let mut out = Vec::new();
        for (r0, r1) in [(0, half), (half, rows)] {
            let strips = cols / d;
            let n = (r1 - r0) * strips;
            let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
            let h = Matrix::from_fn(n, d, |_, _| 1.0);
            let cb = Codebook::from_centroids(d, rng.gaussian_vec(k * d));
            let assignments = assign_diag(&pts, &cb, &h);
            out.push(VqGroup {
                row0: r0,
                row1: r1,
                col0: 0,
                col1: cols,
                codebook: cb,
                assignments,
                scales: unit_scales(r1 - r0, cols),
            });
        }
        out
    }

    #[test]
    fn pack_decode_matches_group_decode_within_int8() {
        let mut rng = Rng::new(1);
        let (rows, cols, d, k) = (8, 16, 2, 16);
        let groups = sample_groups(&mut rng, rows, cols, d, k);
        let dense = decode_groups(rows, cols, &groups);
        let lin = pack_groups(rows, cols, d, k, &groups);
        let decoded = lin.decode();
        // difference bounded by int8 codebook rounding
        let max_c = groups
            .iter()
            .flat_map(|g| g.codebook.centroids.iter())
            .fold(0.0f64, |m, v| m.max(v.abs()));
        let tol = max_c / 127.0 * 0.51;
        assert!(dense.sub(&decoded).max_abs() <= tol + 1e-9);
    }

    #[test]
    fn unpack_groups_roundtrip_decode() {
        let mut rng = Rng::new(2);
        let (rows, cols, d, k) = (6, 12, 2, 8);
        let groups = sample_groups(&mut rng, rows, cols, d, k);
        let lin = pack_groups(rows, cols, d, k, &groups);
        let unpacked = lin.unpack_groups();
        let a = lin.decode();
        let b = decode_groups(rows, cols, &unpacked);
        crate::util::prop::assert_close(a.as_slice(), b.as_slice(), 1e-6, 1e-6, "unpack").unwrap();
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(3);
        let groups = sample_groups(&mut rng, 8, 16, 2, 16);
        let lin = pack_groups(8, 16, 2, 16, &groups);
        let mut model = VqModel::default();
        model.linears.insert("layers.0.attn.wq".into(), lin);
        model
            .dense
            .insert("final_norm".into(), (vec![4], vec![1.0, 2.0, 3.0, 4.0]));
        let p = std::env::temp_dir().join(format!("gvq_model_{}", std::process::id()));
        model.save(&p).unwrap();
        let back = VqModel::load(&p).unwrap();
        assert_eq!(back.dense["final_norm"].1, vec![1.0, 2.0, 3.0, 4.0]);
        let a = model.linears["layers.0.attn.wq"].decode();
        let b = back.linears["layers.0.attn.wq"].decode();
        crate::util::prop::assert_close(a.as_slice(), b.as_slice(), 1e-7, 1e-7, "file").unwrap();
        std::fs::remove_file(p).ok();
    }

    /// Like `sample_groups` but with nontrivial 4-bit block scales; a
    /// `block` that does not divide `d` exercises the boundary-crossing
    /// slow path of the fused matvec.
    fn sample_groups_scaled(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        d: usize,
        k: usize,
        block: usize,
    ) -> Vec<VqGroup> {
        let mut groups = sample_groups(rng, rows, cols, d, k);
        for g in &mut groups {
            let gr = g.row1 - g.row0;
            let bpr = cols.div_ceil(block);
            let codes: Vec<u8> = (0..gr * bpr).map(|_| rng.below(16) as u8).collect();
            g.scales = BlockScales { block_size: block, rows: gr, cols, codes, a: 0.13, z: -1.5 };
        }
        groups
    }

    #[test]
    fn fused_matvec_matches_decode_then_matvec() {
        let mut rng = Rng::new(11);
        let (rows, cols, d, k) = (10, 16, 2, 16);
        let groups = sample_groups(&mut rng, rows, cols, d, k);
        let lin = pack_groups(rows, cols, d, k, &groups);
        let x: Vec<f64> = rng.gaussian_vec(cols);
        let fused = lin.matvec(&x);
        let dense = lin.decode().matvec(&x);
        crate::util::prop::assert_close(&fused, &dense, 1e-9, 1e-9, "fused matvec").unwrap();
    }

    #[test]
    fn fused_matvec_matches_decode_with_block_scales() {
        let mut rng = Rng::new(12);
        let (rows, cols, d, k) = (8, 24, 2, 16);
        // block 4 (strip-aligned fast path) and block 3 (crossing slow path)
        for block in [4usize, 3] {
            let groups = sample_groups_scaled(&mut rng, rows, cols, d, k, block);
            let lin = pack_groups(rows, cols, d, k, &groups);
            let x: Vec<f64> = rng.gaussian_vec(cols);
            let fused = lin.matvec(&x);
            let dense = lin.decode().matvec(&x);
            crate::util::prop::assert_close(&fused, &dense, 1e-9, 1e-9, "scaled matvec").unwrap();
        }
    }

    #[test]
    fn fused_matmul_decoded_matches_dense_matmul() {
        use crate::tensor::matmul;
        let mut rng = Rng::new(13);
        let (rows, cols, d, k) = (12, 16, 1, 8);
        let groups = sample_groups_scaled(&mut rng, rows, cols, d, k, 8);
        let lin = pack_groups(rows, cols, d, k, &groups);
        let x = Matrix::from_fn(5, cols, |_, _| rng.gaussian());
        let fused = lin.matmul_decoded(&x);
        let dense = matmul(&x, &lin.decode().transpose());
        assert_eq!((fused.rows(), fused.cols()), (5, rows));
        crate::util::prop::assert_close(fused.as_slice(), dense.as_slice(), 1e-9, 1e-9, "fused mm")
            .unwrap();
    }

    #[test]
    fn multi_row_matmul_decoded_is_bitwise_identical_to_matvec_rows() {
        // the batched kernel amortizes packed-index reads across rows but
        // must keep each row's accumulation order — exact f64 equality,
        // covering strip-aligned scales (block 4), the boundary-crossing
        // slow path (block 3), and multi-group geometry
        let mut rng = Rng::new(14);
        let (rows, cols, d, k) = (10, 24, 2, 16);
        for block in [4usize, 3] {
            let groups = sample_groups_scaled(&mut rng, rows, cols, d, k, block);
            let lin = pack_groups(rows, cols, d, k, &groups);
            for m in [1usize, 3, 6] {
                let x = Matrix::from_fn(m, cols, |_, _| rng.gaussian());
                let batched = lin.matmul_decoded(&x);
                assert_eq!((batched.rows(), batched.cols()), (m, rows));
                for r in 0..m {
                    let per_row = lin.matvec(x.row(r));
                    assert_eq!(
                        batched.row(r),
                        &per_row[..],
                        "row {r} diverged (block {block}, batch {m})"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_size_reflects_bitwidth() {
        let mut rng = Rng::new(4);
        let g16 = sample_groups(&mut rng, 8, 32, 2, 16); // 4-bit indices
        let g4 = sample_groups(&mut rng, 8, 32, 2, 4); // 2-bit indices
        let l16 = pack_groups(8, 32, 2, 16, &g16);
        let l4 = pack_groups(8, 32, 2, 4, &g4);
        assert!(l4.packed_bytes() < l16.packed_bytes());
        assert!(l16.bits_per_value() < 16.0);
    }

    #[test]
    fn load_rejects_garbage() {
        let p = std::env::temp_dir().join(format!("gvq_model_bad_{}", std::process::id()));
        std::fs::write(&p, b"JUNKJUNKJUNK").unwrap();
        assert!(VqModel::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
