//! Zero-shot probe tasks (GVQTASK1) scored by likelihood ranking — the
//! LM-eval-harness substitute (paper Table 5). Each item has a prompt and
//! N candidate completions; the model's pick is the completion with the
//! highest total log-probability given the prompt.

use std::path::Path;

use crate::error::{Error, Result};
use crate::model::forward::completion_logprob;
use crate::model::Model;

/// One ranked-choice item.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskItem {
    pub prompt: Vec<u8>,
    pub choices: Vec<Vec<u8>>,
    pub correct: usize,
}

/// A named probe task.
#[derive(Debug, Clone)]
pub struct TaskSet {
    pub name: String,
    pub items: Vec<TaskItem>,
}

/// Read a GVQTASK1 file (mirror of `python/compile/tasks.py`).
pub fn load_task(path: impl AsRef<Path>) -> Result<TaskSet> {
    let path_str = path.as_ref().display().to_string();
    let bytes = std::fs::read(path.as_ref())?;
    if bytes.len() < 13 || &bytes[..8] != b"GVQTASK1" {
        return Err(Error::format(&path_str, "bad GVQTASK1 header"));
    }
    let n_items = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let n_choices = bytes[12] as usize;
    let mut pos = 13;
    let mut take = |n: usize| -> Result<&[u8]> {
        if pos + n > bytes.len() {
            return Err(Error::format(&path_str, "truncated task file"));
        }
        let s = &bytes[pos..pos + n];
        pos += n;
        Ok(s)
    };
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        let correct = take(1)?[0] as usize;
        let plen = u16::from_le_bytes(take(2)?.try_into().unwrap()) as usize;
        let prompt = take(plen)?.to_vec();
        let mut choices = Vec::with_capacity(n_choices);
        for _ in 0..n_choices {
            let clen = u16::from_le_bytes(take(2)?.try_into().unwrap()) as usize;
            choices.push(take(clen)?.to_vec());
        }
        if correct >= n_choices {
            return Err(Error::format(&path_str, format!("correct index {correct} out of range")));
        }
        items.push(TaskItem { prompt, choices, correct });
    }
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "task".into());
    Ok(TaskSet { name, items })
}

/// Accuracy of the model on a task (fraction of items where the
/// highest-likelihood choice is the labeled one). `max_items` bounds cost.
pub fn evaluate_task(model: &Model, task: &TaskSet, max_items: usize) -> f64 {
    let n = task.items.len().min(max_items);
    if n == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for item in &task.items[..n] {
        let mut best = 0usize;
        let mut best_lp = f64::NEG_INFINITY;
        for (ci, choice) in item.choices.iter().enumerate() {
            let lp = completion_logprob(model, &item.prompt, choice);
            if lp > best_lp {
                best_lp = lp;
                best = ci;
            }
        }
        if best == item.correct {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_task_file(items: &[TaskItem]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "gvq_task_{}_{}",
            std::process::id(),
            items.len()
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(b"GVQTASK1").unwrap();
        f.write_all(&(items.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&[items[0].choices.len() as u8]).unwrap();
        for it in items {
            f.write_all(&[it.correct as u8]).unwrap();
            f.write_all(&(it.prompt.len() as u16).to_le_bytes()).unwrap();
            f.write_all(&it.prompt).unwrap();
            for c in &it.choices {
                f.write_all(&(c.len() as u16).to_le_bytes()).unwrap();
                f.write_all(c).unwrap();
            }
        }
        p
    }

    #[test]
    fn roundtrip() {
        let items = vec![
            TaskItem {
                prompt: b"the cat ".to_vec(),
                choices: vec![b"sat.".to_vec(), b"xyz.".to_vec()],
                correct: 0,
            },
            TaskItem {
                prompt: b"a dog ".to_vec(),
                choices: vec![b"qq.".to_vec(), b"ran.".to_vec()],
                correct: 1,
            },
        ];
        let p = write_task_file(&items);
        let task = load_task(&p).unwrap();
        assert_eq!(task.items, items);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_header() {
        let p = std::env::temp_dir().join(format!("gvq_task_bad_{}", std::process::id()));
        std::fs::write(&p, b"WRONG").unwrap();
        assert!(load_task(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn accuracy_bounds() {
        use crate::model::forward::tests::tiny_model;
        let m = tiny_model(20);
        let items = vec![TaskItem {
            prompt: b"hello ".to_vec(),
            choices: vec![b"aa".to_vec(), b"bb".to_vec(), b"cc".to_vec(), b"dd".to_vec()],
            correct: 2,
        }];
        let task = TaskSet { name: "t".into(), items };
        let acc = evaluate_task(&m, &task, 10);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn reads_artifact_tasks_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        for name in ["task_cloze.bin", "task_pair.bin", "task_induction.bin"] {
            let p = dir.join(name);
            if !p.exists() {
                eprintln!("skipping {name}: not built");
                continue;
            }
            let t = load_task(&p).unwrap();
            assert!(!t.items.is_empty());
            assert!(t.items.iter().all(|i| i.choices.len() == 4));
        }
    }
}
