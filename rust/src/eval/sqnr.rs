//! Signal-to-quantization-noise analysis (paper Figure 2): SQNR of
//! uniform vs 1D/2D/4D VQ at matched overhead, computed on real trained
//! weights.

use crate::tensor::Matrix;

/// SQNR in dB between original and quantized values:
/// `10 log10( sum x^2 / sum (x - xq)^2 )`.
pub fn sqnr_db(original: &Matrix, quantized: &Matrix) -> f64 {
    assert_eq!(original.rows(), quantized.rows());
    assert_eq!(original.cols(), quantized.cols());
    let signal = original.frob_norm_sq();
    let noise = original.sub(quantized).frob_norm_sq();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (signal / noise).log10()
}

/// Weighted aggregate SQNR over a set of (original, quantized) matrices —
/// pools signal and noise energy like the paper's per-model number.
pub fn sqnr_model(pairs: &[(&Matrix, &Matrix)]) -> f64 {
    let mut signal = 0.0;
    let mut noise = 0.0;
    for (o, q) in pairs {
        signal += o.frob_norm_sq();
        noise += o.sub(q).frob_norm_sq();
    }
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (signal / noise).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_reconstruction_is_infinite() {
        let m = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]).unwrap();
        assert!(sqnr_db(&m, &m).is_infinite());
    }

    #[test]
    fn known_ratio() {
        // signal 100, noise 1 -> 20 dB
        let o = Matrix::from_vec(1, 1, vec![10.0]).unwrap();
        let q = Matrix::from_vec(1, 1, vec![9.0]).unwrap();
        assert!((sqnr_db(&o, &q) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn smaller_noise_higher_sqnr() {
        let mut rng = Rng::new(1);
        let o = Matrix::from_fn(8, 8, |_, _| rng.gaussian());
        let q1 = Matrix::from_fn(8, 8, |r, c| o.get(r, c) + 0.1 * rng.gaussian());
        let q2 = Matrix::from_fn(8, 8, |r, c| o.get(r, c) + 0.01 * rng.gaussian());
        assert!(sqnr_db(&o, &q2) > sqnr_db(&o, &q1));
    }

    #[test]
    fn model_aggregate_pools_energy() {
        let o1 = Matrix::from_vec(1, 1, vec![10.0]).unwrap();
        let q1 = Matrix::from_vec(1, 1, vec![9.0]).unwrap();
        let o2 = Matrix::from_vec(1, 1, vec![0.0]).unwrap();
        let q2 = Matrix::from_vec(1, 1, vec![0.0]).unwrap();
        let agg = sqnr_model(&[(&o1, &q1), (&o2, &q2)]);
        assert!((agg - 20.0).abs() < 1e-9);
    }
}
