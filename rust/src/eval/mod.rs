//! Evaluation harness: WikiText2-substitute perplexity, zero-shot probe
//! tasks (LM-eval-harness substitute), and SQNR analysis (Figure 2).

pub mod perplexity;
pub mod sqnr;
pub mod tasks;

pub use perplexity::{perplexity, PerplexityReport};
pub use sqnr::{sqnr_db, sqnr_model};
pub use tasks::{evaluate_task, load_task, TaskSet};
