//! Token perplexity on the validation corpus (paper metric for Tables
//! 1, 2, 4 and every ablation).

use crate::data::tokens::{eval_sequences, TokenStream};
use crate::model::forward::nll_per_token;
use crate::model::Model;

/// Perplexity evaluation summary.
#[derive(Debug, Clone)]
pub struct PerplexityReport {
    pub mean_nll: f64,
    pub ppl: f64,
    pub tokens_scored: usize,
    pub sequences: usize,
}

/// Evaluate perplexity over `n_seq` evenly spaced sequences of `seq_len`
/// tokens. Deterministic: no sampling noise between method comparisons.
pub fn perplexity(model: &Model, stream: &TokenStream, n_seq: usize, seq_len: usize) -> PerplexityReport {
    let seqs = eval_sequences(stream, n_seq, seq_len);
    let mut total_nll = 0.0;
    let mut count = 0usize;
    for seq in &seqs {
        let nll = nll_per_token(model, seq);
        total_nll += nll.iter().sum::<f64>();
        count += nll.len();
    }
    let mean = total_nll / count as f64;
    PerplexityReport { mean_nll: mean, ppl: mean.exp(), tokens_scored: count, sequences: seqs.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokens::synthetic_stream;
    use crate::model::forward::tests::tiny_model;

    #[test]
    fn random_model_near_uniform_ppl() {
        let m = tiny_model(11);
        let s = synthetic_stream(4_000, 1);
        let rep = perplexity(&m, &s, 4, 32);
        // near-random logits: ppl within a factor ~2 of vocab size
        assert!(rep.ppl > 100.0 && rep.ppl < 600.0, "ppl {}", rep.ppl);
        assert_eq!(rep.sequences, 4);
        assert_eq!(rep.tokens_scored, 4 * 31);
    }

    #[test]
    fn deterministic() {
        let m = tiny_model(12);
        let s = synthetic_stream(4_000, 2);
        let a = perplexity(&m, &s, 3, 24);
        let b = perplexity(&m, &s, 3, 24);
        assert_eq!(a.mean_nll, b.mean_nll);
    }
}
