//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`):
//! one line per artifact, `name=file;key=value;...`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// One artifact record.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub meta: BTreeMap<String, String>,
}

impl ManifestEntry {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .ok_or_else(|| Error::msg(format!("manifest entry {} missing {key}", self.name)))?
            .parse()
            .map_err(|e| Error::msg(format!("bad {key}: {e}")))
    }
}

/// Parse the manifest file into name-keyed entries.
pub fn load_manifest(path: impl AsRef<Path>) -> Result<BTreeMap<String, ManifestEntry>> {
    let path_str = path.as_ref().display().to_string();
    let text = std::fs::read_to_string(path.as_ref())?;
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split(';');
        let head = parts
            .next()
            .ok_or_else(|| Error::format(&path_str, format!("line {lineno}: empty")))?;
        let (name, file) = head
            .split_once('=')
            .ok_or_else(|| Error::format(&path_str, format!("line {lineno}: no name=file")))?;
        let mut meta = BTreeMap::new();
        for kv in parts {
            if let Some((k, v)) = kv.split_once('=') {
                meta.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        out.insert(
            name.trim().to_string(),
            ManifestEntry { name: name.trim().into(), file: file.trim().into(), meta },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_lines() {
        let p = std::env::temp_dir().join(format!("gvq_manifest_{}", std::process::id()));
        std::fs::write(&p, "a=a.hlo.txt;batch=4;seq=128\nb=b.hlo.txt;d=2;k=16\n").unwrap();
        let m = load_manifest(&p).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["a"].file, "a.hlo.txt");
        assert_eq!(m["a"].meta_usize("batch").unwrap(), 4);
        assert_eq!(m["b"].meta_usize("k").unwrap(), 16);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn reads_built_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt");
        if !p.exists() {
            return;
        }
        let m = load_manifest(&p).unwrap();
        assert!(m.contains_key("model_nll_small"));
        assert!(m.contains_key("vq_assign_d2_k16_n4096"));
        assert_eq!(m["vq_assign_d2_k16_n4096"].meta_usize("d").unwrap(), 2);
    }
}
