//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the request-path bridge to the L2/L1 layers: the HLO was
//! lowered once at build time (HLO *text*, not serialized proto — see
//! DESIGN notes and /opt/xla-example/README.md for the 64-bit-id gotcha);
//! at runtime we compile each module once, cache the executable, and feed
//! it f32/i32 literals.
//!
//! The `xla` bindings are not available in every build environment, so the
//! execution half is gated behind the `pjrt` feature. Without it, [`Arg`],
//! [`OutBuf`] and the manifest reader still compile (they are plain data),
//! and [`Runtime::cpu`] returns a clean [`Error::Runtime`] so callers can
//! skip gracefully.

pub mod manifest;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::tensor::Matrix;

pub use manifest::{load_manifest, ManifestEntry};

/// Argument to an HLO executable.
#[derive(Debug, Clone)]
pub enum Arg {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Arg {
    pub fn from_matrix(m: &Matrix) -> Arg {
        Arg::F32 { data: m.to_f32(), dims: vec![m.rows(), m.cols()] }
    }

    pub fn from_vec_f64(v: &[f64]) -> Arg {
        // detlint: allow(precision-cast, PJRT host buffers are f32 by backend ABI)
        Arg::F32 { data: v.iter().map(|&x| x as f32).collect(), dims: vec![v.len()] }
    }

    /// Pack a token batch into an i32 [b, s] literal. All sequences must
    /// share one length — a ragged batch is a caller error, reported as
    /// [`Error::Shape`] rather than a panic inside library code.
    pub fn tokens_2d(batches: &[Vec<u8>]) -> Result<Arg> {
        let b = batches.len();
        let s = batches.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(b * s);
        for (i, row) in batches.iter().enumerate() {
            if row.len() != s {
                return Err(Error::Shape(format!(
                    "ragged token batch: sequence {i} has {} tokens, expected {s}",
                    row.len()
                )));
            }
            data.extend(row.iter().map(|&t| t as i32));
        }
        Ok(Arg::I32 { data, dims: vec![b, s] })
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Arg::I32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// One output buffer (always f32 on our artifacts).
#[derive(Debug, Clone)]
pub struct OutBuf {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl OutBuf {
    pub fn to_matrix(&self) -> Result<Matrix> {
        match self.dims.len() {
            2 => Matrix::from_f32(self.dims[0], self.dims[1], &self.data),
            3 => Matrix::from_f32(self.dims[0] * self.dims[1], self.dims[2], &self.data),
            n => Err(Error::Shape(format!("OutBuf: can't matrix-ify {n}-d"))),
        }
    }
}

/// The PJRT CPU runtime with an executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    artifacts_dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, cache: HashMap::new(), artifacts_dir: artifacts_dir.as_ref().into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO-text artifact `file`.
    pub fn load(&mut self, file: &str) -> Result<()> {
        if self.cache.contains_key(file) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(file);
        if !path.exists() {
            return Err(Error::Runtime(format!("artifact not found: {}", path.display())));
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(file.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, file: &str) -> bool {
        self.cache.contains_key(file)
    }

    /// Execute a loaded artifact. Our AOT path lowers with
    /// `return_tuple=True`, so the (single) on-device result is a tuple;
    /// we unpack every element to host f32 buffers.
    pub fn execute(&mut self, file: &str, args: &[Arg]) -> Result<Vec<OutBuf>> {
        self.load(file)?;
        let exe = self.cache.get(file).unwrap();
        let literals: Vec<xla::Literal> = args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let elems = tuple.decompose_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for lit in elems {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            // detlint: allow(precision-cast, xla Literal::convert is a backend call, not an Element cast)
            let lit = lit.convert(xla::PrimitiveType::F32)?;
            let data = lit.to_vec::<f32>()?;
            out.push(OutBuf { data, dims });
        }
        Ok(out)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

/// Stub runtime for builds without the `pjrt` feature: construction fails
/// with a descriptive error so every caller can skip the PJRT path with a
/// single `match`/`let Ok(..) else`.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime;

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    fn unavailable() -> Error {
        Error::Runtime(
            "built without the `pjrt` feature — HLO artifacts cannot be executed \
             (rebuild with `--features pjrt` where the xla bindings are available)"
                .into(),
        )
    }

    pub fn cpu(_artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(Self::unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load(&mut self, _file: &str) -> Result<()> {
        Err(Self::unavailable())
    }

    pub fn is_loaded(&self, _file: &str) -> bool {
        false
    }

    pub fn execute(&mut self, _file: &str, _args: &[Arg]) -> Result<Vec<OutBuf>> {
        Err(Self::unavailable())
    }

    pub fn loaded_count(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn tokens_2d_packs_rectangular_batches() {
        let arg = Arg::tokens_2d(&[vec![1, 2, 3], vec![4, 5, 6]]).unwrap();
        match arg {
            Arg::I32 { data, dims } => {
                assert_eq!(dims, vec![2, 3]);
                assert_eq!(data, vec![1, 2, 3, 4, 5, 6]);
            }
            _ => panic!("expected I32"),
        }
    }

    #[test]
    fn tokens_2d_rejects_ragged_batches() {
        let err = Arg::tokens_2d(&[vec![1, 2, 3], vec![4, 5]]);
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("ragged"), "{msg}");
    }

    #[test]
    fn tokens_2d_empty_batch_is_ok() {
        let arg = Arg::tokens_2d(&[]).unwrap();
        match arg {
            Arg::I32 { data, dims } => {
                assert!(data.is_empty());
                assert_eq!(dims, vec![0, 0]);
            }
            _ => panic!("expected I32"),
        }
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let dir = artifacts_dir();
        if !dir.exists() {
            return;
        }
        let Ok(mut rt) = Runtime::cpu(&dir) else {
            eprintln!("skipping: pjrt runtime unavailable");
            return;
        };
        let err = rt.load("does_not_exist.hlo.txt");
        assert!(err.is_err());
    }

    #[test]
    fn executes_assign_kernel_artifact() {
        let dir = artifacts_dir();
        let file = "vq_assign_d2_k16_n4096.hlo.txt";
        if !dir.join(file).exists() {
            eprintln!("skipping: {file} not built");
            return;
        }
        let Ok(mut rt) = Runtime::cpu(&dir) else {
            eprintln!("skipping: pjrt runtime unavailable");
            return;
        };
        // points on known centroids -> argmin must hit them
        let mut pts = vec![0f32; 4096 * 2];
        let mut cbs = vec![0f32; 16 * 2];
        for m in 0..16 {
            cbs[m * 2] = m as f32;
            cbs[m * 2 + 1] = -(m as f32);
        }
        for i in 0..4096 {
            let m = i % 16;
            pts[i * 2] = m as f32 + 0.01;
            pts[i * 2 + 1] = -(m as f32) - 0.01;
        }
        let hdg = vec![1f32; 4096 * 2];
        let out = rt
            .execute(
                file,
                &[
                    Arg::F32 { data: pts, dims: vec![4096, 2] },
                    Arg::F32 { data: cbs, dims: vec![16, 2] },
                    Arg::F32 { data: hdg, dims: vec![4096, 2] },
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![4096]);
        for i in 0..4096 {
            assert_eq!(out[0].data[i] as usize, i % 16, "point {i}");
        }
        assert!(rt.is_loaded(file));
    }
}
