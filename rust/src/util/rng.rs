//! Deterministic RNG (xoshiro256++) — no `rand` crate available offline.
//!
//! Used everywhere randomness is needed: codebook seeding fallbacks,
//! synthetic workloads, property tests. Seeded explicitly so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// xoshiro256++ by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Weighted choice: returns i with probability weights[i]/sum.
    /// Zero-total weights fall back to uniform.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.below(weights.len());
        }
        let mut target = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let xs = r.gaussian_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn weighted_choice_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        assert!(counts[1] > 1_500);
    }

    #[test]
    fn weighted_choice_zero_weights_uniform() {
        let mut r = Rng::new(10);
        let w = [0.0, 0.0, 0.0];
        let mut counts = [0usize; 3];
        for _ in 0..3_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 700));
    }
}
