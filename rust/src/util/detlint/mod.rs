//! detlint — the crate's determinism/robustness linter.
//!
//! The repo's central contract is that every parallel schedule is
//! *bitwise identical* to the serial one (see `docs/ARCHITECTURE.md`).
//! The parity tests sample that contract; detlint mechanically blocks
//! the hazard patterns that have historically broken it:
//!
//! | rule | hazard |
//! |------|--------|
//! | `partial-cmp-unwrap` | NaN panic + unspecified order in comparators |
//! | `hash-iter` | hash-order iteration in `quant/`/`coordinator/`/`serve/` |
//! | `wall-clock` | `Instant::now`/`SystemTime` in compute modules |
//! | `unwrap-budget` | bare `unwrap()`/`expect()` density in library code |
//! | `unsafe-no-safety` | `unsafe` without a `// SAFETY:` argument |
//! | `precision-cast` | f32/f64 boundary crossings outside sanctioned modules |
//! | `hot-alloc` | heap allocation inside `// detlint: hot` regions |
//! | `layer-violation` | module edges outside the layering manifest |
//! | `module-cycle` | dependency cycles, observed or manifest-allowed |
//! | `bad-waiver` | malformed or reasonless waiver comments |
//!
//! The first eight are per-line rules over the lexed [`source`] view;
//! `layer-violation`/`module-cycle` come from the whole-crate
//! [`graph`] pass, which checks the extracted `crate::…` edge set
//! against `rust/detlint_layers.toml`.
//!
//! Violations are suppressed inline with
//! `// detlint: allow(<rule>, <reason>)` on the offending line or the
//! line above — the reason is mandatory and audited (a reasonless
//! waiver is a `bad-waiver` violation, not a suppression). Graph
//! findings are not inline-waivable; the manifest is their policy
//! mechanism. Test, bench, and example trees are scanned with the
//! unwrap-budget, wall-clock, and precision-cast rules relaxed
//! ([`FileKind`]). The scanner is deliberately `syn`-free (plain
//! source scanning over a lexed line view, [`source`]) so it builds in
//! the offline, zero-dependency configuration and runs in milliseconds
//! as `cargo run --bin detlint`.

pub mod graph;
pub mod rules;
pub mod source;

use std::fs;
use std::io;
use std::path::Path;

pub use source::SourceFile;

/// All rule ids, in reporting order.
pub const RULE_IDS: [&str; 10] = [
    rules::partial_cmp::RULE,
    rules::hash_iter::RULE,
    rules::wall_clock::RULE,
    rules::unwrap_budget::RULE,
    rules::unsafe_safety::RULE,
    rules::precision_cast::RULE,
    rules::hot_alloc::RULE,
    graph::RULE_LAYER,
    graph::RULE_CYCLE,
    "bad-waiver",
];

/// What kind of tree a scanned file belongs to. Library code gets the
/// full rule set; test/bench/example code keeps the correctness rules
/// (comparators, hash order, SAFETY, hot regions) but drops the
/// budget/measurement/precision rules — an `unwrap()` or an
/// `Instant::now()` in a test is idiomatic, not a hazard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FileKind {
    /// `src/` — full rule set.
    #[default]
    Lib,
    /// `tests/` — relaxed.
    Test,
    /// `benches/` — relaxed (benches *exist* to read the clock).
    Bench,
    /// `examples/` — relaxed.
    Example,
}

impl FileKind {
    /// Whether the budget/measurement/precision rules are off.
    pub fn relaxed(self) -> bool {
        !matches!(self, FileKind::Lib)
    }
}

/// Per-scan configuration threaded through to the rules.
#[derive(Debug, Clone, Default)]
pub struct LintOptions {
    /// Tree kind of the files being scanned.
    pub kind: FileKind,
    /// Also flag widening `as f64` casts (audit mode).
    pub strict_precision: bool,
    /// Extra precision-sanctioned path suffixes (from the manifest's
    /// `[precision]` section, each validated to carry a reason).
    pub sanctioned: Vec<String>,
}

/// One finding: a rule violated at a file/line.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id (one of [`RULE_IDS`]).
    pub rule: &'static str,
    /// Human-readable description of the hazard.
    pub message: String,
}

/// Violation collector for one file; resolves waivers on emit.
pub struct Sink<'a> {
    /// Path relative to the scan root.
    pub file: &'a str,
    /// The lexed file the rules read.
    pub src: &'a SourceFile,
    /// Violations recorded so far.
    pub violations: Vec<Violation>,
    /// Rule ids of waivers consumed so far (one entry per suppression).
    pub waived: Vec<&'static str>,
}

impl<'a> Sink<'a> {
    /// Record a violation of `rule` at 0-based `line`, unless a
    /// reasoned waiver covers it.
    pub fn emit(&mut self, line: usize, rule: &'static str, message: String) {
        if self.src.waived(line, rule) {
            self.waived.push(rule);
        } else {
            self.violations.push(Violation {
                file: self.file.to_string(),
                line: line + 1,
                rule,
                message,
            });
        }
    }
}

/// Lint one already-lexed file under `opts`. `file` is the path
/// relative to the scan root (`/`-separated); the path-scoped rules
/// (`hash-iter`, `wall-clock`, `precision-cast`) read it. Returns the
/// violations and the rule ids of consumed waivers.
pub fn lint_parsed(
    file: &str,
    src: &SourceFile,
    opts: &LintOptions,
) -> (Vec<Violation>, Vec<&'static str>) {
    let mut sink = Sink { file, src, violations: Vec::new(), waived: Vec::new() };
    // bad-waiver first: a waiver that cannot apply must be visible
    for w in &src.waivers {
        if !RULE_IDS.contains(&w.rule.as_str()) {
            let msg = format!("unknown rule '{}' in waiver", w.rule);
            sink.violations.push(Violation {
                file: file.to_string(),
                line: w.line + 1,
                rule: "bad-waiver",
                message: msg,
            });
        } else if w.reason.is_none() {
            sink.violations.push(Violation {
                file: file.to_string(),
                line: w.line + 1,
                rule: "bad-waiver",
                message: "waiver missing a reason".to_string(),
            });
        }
    }
    rules::partial_cmp::check(&mut sink);
    rules::hash_iter::check(file, &mut sink);
    rules::unsafe_safety::check(&mut sink);
    rules::hot_alloc::check(&mut sink);
    if !opts.kind.relaxed() {
        rules::wall_clock::check(file, &mut sink);
        rules::unwrap_budget::check(&mut sink);
        rules::precision_cast::check(file, &mut sink, &opts.sanctioned, opts.strict_precision);
    }
    (sink.violations, sink.waived)
}

/// Lint one file's source text under `opts`.
pub fn lint_source_with(
    file: &str,
    text: &str,
    opts: &LintOptions,
) -> (Vec<Violation>, Vec<&'static str>) {
    let src = SourceFile::parse(text);
    lint_parsed(file, &src, opts)
}

/// Lint one file's source text with default (library-code) options.
/// Returns the violations and the number of waivers consumed.
pub fn lint_source(file: &str, text: &str) -> (Vec<Violation>, usize) {
    let (violations, waived) = lint_source_with(file, text, &LintOptions::default());
    (violations, waived.len())
}

/// Aggregate result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    /// All violations, in deterministic (path, line) order.
    pub violations: Vec<Violation>,
    /// Rule ids of all waivers consumed (one entry per suppression).
    pub waived_rules: Vec<&'static str>,
    /// Files scanned.
    pub files: usize,
}

impl LintReport {
    /// Total waivers consumed.
    pub fn waivers(&self) -> usize {
        self.waived_rules.len()
    }

    /// Fold another report (e.g. from a second scan root) into this one.
    pub fn merge(&mut self, other: LintReport) {
        self.violations.extend(other.violations);
        self.waived_rules.extend(other.waived_rules);
        self.files += other.files;
    }

    /// Sort violations by (file, line, rule) so multi-root runs render
    /// deterministically regardless of scan order.
    pub fn sort(&mut self) {
        self.violations.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
    }

    /// Per-rule (violations, waivers) counts in [`RULE_IDS`] order.
    pub fn rule_counts(&self) -> Vec<(&'static str, usize, usize)> {
        RULE_IDS
            .iter()
            .map(|&id| {
                let v = self.violations.iter().filter(|x| x.rule == id).count();
                let w = self.waived_rules.iter().filter(|&&r| r == id).count();
                (id, v, w)
            })
            .collect()
    }

    /// Process exit code: 0 clean, 1 when any violation remains.
    pub fn exit_code(&self) -> i32 {
        i32::from(!self.violations.is_empty())
    }

    /// `path:line: rule: message` lines, per-rule counts for every rule
    /// with activity, plus a final greppable summary (always last).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{}:{}: {}: {}\n", v.file, v.line, v.rule, v.message));
        }
        for (id, v, w) in self.rule_counts() {
            if v + w > 0 {
                out.push_str(&format!("detlint: rule {id}: {v} violation(s), {w} waiver(s)\n"));
            }
        }
        out.push_str(&format!(
            "detlint: {} violation(s), {} waiver(s), {} file(s) scanned\n",
            self.violations.len(),
            self.waivers(),
            self.files
        ));
        out
    }

    /// Machine-readable JSON (hand-rolled; the build has no serde).
    /// Control characters in paths/messages are escaped (`\n`, `\t`,
    /// `\r` short forms, `\u00XX` otherwise) so the output is always
    /// valid JSON; the `rules` object always lists every rule so CI can
    /// diff per-rule counts PR-over-PR.
    pub fn render_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.chars()
                .flat_map(|c| match c {
                    '"' => "\\\"".chars().collect::<Vec<_>>(),
                    '\\' => "\\\\".chars().collect(),
                    '\n' => "\\n".chars().collect(),
                    '\t' => "\\t".chars().collect(),
                    '\r' => "\\r".chars().collect(),
                    c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                    c => vec![c],
                })
                .collect()
        }
        let items: Vec<String> = self
            .violations
            .iter()
            .map(|v| {
                format!(
                    "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\"}}",
                    esc(&v.file),
                    v.line,
                    v.rule,
                    esc(&v.message)
                )
            })
            .collect();
        let rules: Vec<String> = self
            .rule_counts()
            .into_iter()
            .map(|(id, v, w)| format!("\"{id}\":{{\"violations\":{v},\"waivers\":{w}}}"))
            .collect();
        format!(
            "{{\"violations\":[{}],\"rules\":{{{}}},\"n_violations\":{},\"n_waivers\":{},\"n_files\":{}}}\n",
            items.join(","),
            rules.join(","),
            self.violations.len(),
            self.waivers(),
            self.files
        )
    }
}

/// Recursively collect `*.rs` files under `dir`, sorted, as (absolute,
/// root-relative `/`-separated) path pairs — sorted so reports and exit
/// codes are themselves deterministic. Directories named
/// `detlint_fixtures` are skipped: they hold deliberately-violating
/// lint *data*, scanned only by the self-tests.
fn walk(root: &Path, dir: &Path, out: &mut Vec<(std::path::PathBuf, String)>) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?.into_iter().map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "detlint_fixtures") {
                continue;
            }
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((path, rel));
        }
    }
    Ok(())
}

/// Lint every `*.rs` file under `root` with `opts`, returning the
/// report plus the lexed files (root-relative path, [`SourceFile`]) so
/// the caller can feed them to the [`graph`] pass without re-reading.
pub fn lint_tree_with(
    root: &Path,
    opts: &LintOptions,
) -> io::Result<(LintReport, Vec<(String, SourceFile)>)> {
    let mut paths = Vec::new();
    walk(root, root, &mut paths)?;
    let mut report = LintReport::default();
    let mut files = Vec::new();
    for (path, rel) in paths {
        let text = fs::read_to_string(&path)?;
        let src = SourceFile::parse(&text);
        let (violations, waived) = lint_parsed(&rel, &src, opts);
        report.violations.extend(violations);
        report.waived_rules.extend(waived);
        report.files += 1;
        files.push((rel, src));
    }
    Ok((report, files))
}

/// Lint every `*.rs` file under `root` with default (library-code)
/// options and aggregate the findings.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    lint_tree_with(root, &LintOptions::default()).map(|(report, _)| report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_produces_no_violations() {
        let src = "pub fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
        let (vs, waived) = lint_source("quant/clean.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(waived, 0);
    }

    #[test]
    fn own_crate_patterns_in_strings_do_not_fire() {
        // the scanner must not flag its own rule definitions: patterns
        // live in string literals, which the code view blanks
        let src = "const P: &str = \"partial_cmp(x).unwrap()\";\n";
        let (vs, _) = lint_source("util/x.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn waiver_suppresses_and_is_counted() {
        let src = "let t = Instant::now(); // detlint: allow(wall-clock, metrics annotation only)\n";
        let (vs, waived) = lint_source("serve/engine.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(waived, 1);
    }

    #[test]
    fn reasonless_waiver_is_a_bad_waiver_and_does_not_suppress() {
        let src = "let t = Instant::now(); // detlint: allow(wall-clock)\n";
        let (vs, waived) = lint_source("serve/engine.rs", src);
        assert_eq!(waived, 0);
        let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"bad-waiver"), "{vs:?}");
        assert!(rules.contains(&"wall-clock"), "{vs:?}");
    }

    #[test]
    fn relaxed_kinds_drop_budget_and_clock_rules() {
        let src = "fn t() {\n    let t0 = Instant::now();\n    let v = x.unwrap();\n}\n";
        let (vs, _) = lint_source("serve/engine.rs", src);
        assert!(vs.iter().any(|v| v.rule == "wall-clock"), "{vs:?}");
        let opts = LintOptions { kind: FileKind::Bench, ..LintOptions::default() };
        let (vs, _) = lint_source_with("runtime_throughput.rs", src, &opts);
        assert!(vs.is_empty(), "relaxed kind must not flag clock/unwrap: {vs:?}");
    }

    #[test]
    fn precision_cast_respects_sanction_list() {
        let src = "let y = x as f32;\n";
        let (vs, _) = lint_source("quant/gptvq.rs", src);
        assert!(vs.iter().any(|v| v.rule == "precision-cast"), "{vs:?}");
        // the default boundary modules are sanctioned without a manifest
        let (vs, _) = lint_source("tensor/element.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
        // manifest sanctions extend the list
        let opts = LintOptions {
            sanctioned: vec!["quant/gptvq.rs".to_string()],
            ..LintOptions::default()
        };
        let (vs, _) = lint_source_with("quant/gptvq.rs", src, &opts);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn report_renders_machine_readable_json() {
        let src = "let x = a.partial_cmp(&b).unwrap();\n";
        let (violations, _) = lint_source("linalg/x.rs", src);
        let report = LintReport { violations, waived_rules: Vec::new(), files: 1 };
        assert_eq!(report.exit_code(), 1);
        let json = report.render_json();
        assert!(json.contains("\"rule\":\"partial-cmp-unwrap\""), "{json}");
        assert!(json.contains("\"n_violations\":1"), "{json}");
        assert!(json.contains("\"partial-cmp-unwrap\":{\"violations\":1,\"waivers\":0}"), "{json}");
        assert!(report.render_text().contains("linalg/x.rs:1: partial-cmp-unwrap"));
    }

    #[test]
    fn json_escapes_control_characters() {
        let report = LintReport {
            violations: vec![Violation {
                file: "a\tb.rs".to_string(),
                line: 1,
                rule: "bad-waiver",
                message: "line1\nline2\rdone\u{1}".to_string(),
            }],
            waived_rules: Vec::new(),
            files: 1,
        };
        let json = report.render_json();
        assert!(json.contains("a\\tb.rs"), "{json}");
        assert!(json.contains("line1\\nline2\\rdone\\u0001"), "{json}");
        // the payload body must carry no raw control characters at all
        assert!(
            !json.trim_end().chars().any(|c| (c as u32) < 0x20),
            "raw control char leaked: {json:?}"
        );
    }
}
