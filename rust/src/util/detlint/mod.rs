//! detlint — the crate's determinism/robustness linter.
//!
//! The repo's central contract is that every parallel schedule is
//! *bitwise identical* to the serial one (see `docs/ARCHITECTURE.md`).
//! The parity tests sample that contract; detlint mechanically blocks
//! the hazard patterns that have historically broken it:
//!
//! | rule | hazard |
//! |------|--------|
//! | `partial-cmp-unwrap` | NaN panic + unspecified order in comparators |
//! | `hash-iter` | hash-order iteration in `quant/`/`coordinator/`/`serve/` |
//! | `wall-clock` | `Instant::now`/`SystemTime` in compute modules |
//! | `unwrap-budget` | bare `unwrap()`/`expect()` density in library code |
//! | `unsafe-no-safety` | `unsafe` without a `// SAFETY:` argument |
//! | `bad-waiver` | malformed or reasonless waiver comments |
//!
//! Violations are suppressed inline with
//! `// detlint: allow(<rule>, <reason>)` on the offending line or the
//! line above — the reason is mandatory and audited (a reasonless
//! waiver is a `bad-waiver` violation, not a suppression). The scanner
//! is deliberately `syn`-free (plain source scanning over a lexed
//! line view, [`source`]) so it builds in the offline,
//! zero-dependency configuration and runs in milliseconds as
//! `cargo run --bin detlint`.

pub mod rules;
pub mod source;

use std::fs;
use std::io;
use std::path::Path;

pub use source::SourceFile;

/// All rule ids, in reporting order.
pub const RULE_IDS: [&str; 6] = [
    rules::partial_cmp::RULE,
    rules::hash_iter::RULE,
    rules::wall_clock::RULE,
    rules::unwrap_budget::RULE,
    rules::unsafe_safety::RULE,
    "bad-waiver",
];

/// One finding: a rule violated at a file/line.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id (one of [`RULE_IDS`]).
    pub rule: &'static str,
    /// Human-readable description of the hazard.
    pub message: String,
}

/// Violation collector for one file; resolves waivers on emit.
pub struct Sink<'a> {
    /// Path relative to the scan root.
    pub file: &'a str,
    /// The lexed file the rules read.
    pub src: &'a SourceFile,
    /// Violations recorded so far.
    pub violations: Vec<Violation>,
    /// Waivers consumed so far.
    pub waived: usize,
}

impl<'a> Sink<'a> {
    /// Record a violation of `rule` at 0-based `line`, unless a
    /// reasoned waiver covers it.
    pub fn emit(&mut self, line: usize, rule: &'static str, message: String) {
        if self.src.waived(line, rule) {
            self.waived += 1;
        } else {
            self.violations.push(Violation {
                file: self.file.to_string(),
                line: line + 1,
                rule,
                message,
            });
        }
    }
}

/// Lint one file's source text. `file` is the path relative to the scan
/// root (`/`-separated); the `hash-iter` and `wall-clock` rules scope on
/// it. Returns the violations and the number of waivers consumed.
pub fn lint_source(file: &str, text: &str) -> (Vec<Violation>, usize) {
    let src = SourceFile::parse(text);
    let mut sink = Sink { file, src: &src, violations: Vec::new(), waived: 0 };
    // bad-waiver first: a waiver that cannot apply must be visible
    for w in &src.waivers {
        if !RULE_IDS.contains(&w.rule.as_str()) {
            let msg = format!("unknown rule '{}' in waiver", w.rule);
            sink.violations.push(Violation {
                file: file.to_string(),
                line: w.line + 1,
                rule: "bad-waiver",
                message: msg,
            });
        } else if w.reason.is_none() {
            sink.violations.push(Violation {
                file: file.to_string(),
                line: w.line + 1,
                rule: "bad-waiver",
                message: "waiver missing a reason".to_string(),
            });
        }
    }
    rules::partial_cmp::check(&mut sink);
    rules::hash_iter::check(file, &mut sink);
    rules::wall_clock::check(file, &mut sink);
    rules::unwrap_budget::check(&mut sink);
    rules::unsafe_safety::check(&mut sink);
    (sink.violations, sink.waived)
}

/// Aggregate result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    /// All violations, in deterministic (path, line) order.
    pub violations: Vec<Violation>,
    /// Total waivers consumed.
    pub waivers: usize,
    /// Files scanned.
    pub files: usize,
}

impl LintReport {
    /// Process exit code: 0 clean, 1 when any violation remains.
    pub fn exit_code(&self) -> i32 {
        i32::from(!self.violations.is_empty())
    }

    /// `path:line: rule: message` lines plus a final greppable summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{}:{}: {}: {}\n", v.file, v.line, v.rule, v.message));
        }
        out.push_str(&format!(
            "detlint: {} violation(s), {} waiver(s), {} file(s) scanned\n",
            self.violations.len(),
            self.waivers,
            self.files
        ));
        out
    }

    /// Machine-readable JSON (hand-rolled; the build has no serde).
    pub fn render_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.chars()
                .flat_map(|c| match c {
                    '"' => "\\\"".chars().collect::<Vec<_>>(),
                    '\\' => "\\\\".chars().collect(),
                    '\n' => "\\n".chars().collect(),
                    c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                    c => vec![c],
                })
                .collect()
        }
        let items: Vec<String> = self
            .violations
            .iter()
            .map(|v| {
                format!(
                    "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\"}}",
                    esc(&v.file),
                    v.line,
                    v.rule,
                    esc(&v.message)
                )
            })
            .collect();
        format!(
            "{{\"violations\":[{}],\"n_violations\":{},\"n_waivers\":{},\"n_files\":{}}}\n",
            items.join(","),
            self.violations.len(),
            self.waivers,
            self.files
        )
    }
}

/// Recursively collect `*.rs` files under `dir`, sorted, as (absolute,
/// root-relative `/`-separated) path pairs — sorted so reports and exit
/// codes are themselves deterministic.
fn walk(root: &Path, dir: &Path, out: &mut Vec<(std::path::PathBuf, String)>) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?.into_iter().map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((path, rel));
        }
    }
    Ok(())
}

/// Lint every `*.rs` file under `root` and aggregate the findings.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    let mut report = LintReport::default();
    for (path, rel) in files {
        let text = fs::read_to_string(&path)?;
        let (violations, waived) = lint_source(&rel, &text);
        report.violations.extend(violations);
        report.waivers += waived;
        report.files += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_produces_no_violations() {
        let src = "pub fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
        let (vs, waived) = lint_source("quant/clean.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(waived, 0);
    }

    #[test]
    fn own_crate_patterns_in_strings_do_not_fire() {
        // the scanner must not flag its own rule definitions: patterns
        // live in string literals, which the code view blanks
        let src = "const P: &str = \"partial_cmp(x).unwrap()\";\n";
        let (vs, _) = lint_source("util/x.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn waiver_suppresses_and_is_counted() {
        let src = "let t = Instant::now(); // detlint: allow(wall-clock, metrics annotation only)\n";
        let (vs, waived) = lint_source("serve/engine.rs", src);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(waived, 1);
    }

    #[test]
    fn reasonless_waiver_is_a_bad_waiver_and_does_not_suppress() {
        let src = "let t = Instant::now(); // detlint: allow(wall-clock)\n";
        let (vs, waived) = lint_source("serve/engine.rs", src);
        assert_eq!(waived, 0);
        let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"bad-waiver"), "{vs:?}");
        assert!(rules.contains(&"wall-clock"), "{vs:?}");
    }

    #[test]
    fn report_renders_machine_readable_json() {
        let src = "let x = a.partial_cmp(&b).unwrap();\n";
        let (violations, _) = lint_source("linalg/x.rs", src);
        let report = LintReport { violations, waivers: 0, files: 1 };
        assert_eq!(report.exit_code(), 1);
        let json = report.render_json();
        assert!(json.contains("\"rule\":\"partial-cmp-unwrap\""), "{json}");
        assert!(json.contains("\"n_violations\":1"), "{json}");
        assert!(report.render_text().contains("linalg/x.rs:1: partial-cmp-unwrap"));
    }
}
