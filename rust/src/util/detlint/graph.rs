//! Module-dependency graph pass: layering and cycle analysis over the
//! whole crate.
//!
//! The per-line rules in [`rules`](super::rules) police individual
//! hazard patterns; this pass polices the crate's *shape*. It extracts
//! every inter-module reference (`crate::<module>::…` on non-test code
//! lines — `use` declarations and inline paths alike) with file:line
//! provenance, and checks the resulting edge set against a declared
//! layering manifest (`rust/detlint_layers.toml`, hand-parsed — the
//! offline build has no toml dep):
//!
//! - an edge `from → to` not allowed by the manifest is a
//!   `layer-violation`, anchored at the first reference site;
//! - a module missing from the manifest, or a manifest entry naming a
//!   module that does not exist, is a `layer-violation` anchored in the
//!   manifest;
//! - a cycle in the *observed* graph is a `module-cycle` — always,
//!   whatever the manifest says — and a cycle in the manifest's own
//!   allow-graph is a `module-cycle` too, so the policy cannot quietly
//!   legalize one before it appears.
//!
//! Graph findings are not inline-waivable: the manifest *is* the waiver
//! mechanism, and edits to it are reviewed like code. Precision
//! sanctions ride in the same manifest (`[precision]` section, path =
//! reason) and feed the [`precision_cast`](super::rules::precision_cast)
//! rule; a sanction without a reason is a `bad-waiver`.

use std::collections::{BTreeMap, BTreeSet};

use super::{SourceFile, Violation};

/// Rule id for illegal/undeclared dependency edges.
pub const RULE_LAYER: &str = "layer-violation";
/// Rule id for dependency cycles (observed or allowed-by-manifest).
pub const RULE_CYCLE: &str = "module-cycle";

/// One `module = dep dep …` line from the manifest's `[layers]` section.
#[derive(Debug, Clone)]
pub struct LayerDecl {
    /// Module name (a top-level `src/` module).
    pub name: String,
    /// Modules it is allowed to depend on (`*` = anything).
    pub deps: Vec<String>,
    /// 1-based manifest line, for provenance.
    pub line: usize,
}

/// Parsed layering manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    /// Manifest path as reported in findings.
    pub file: String,
    /// `[layers]` declarations in file order.
    pub layers: Vec<LayerDecl>,
    /// `[precision]` sanctions: (path suffix, reason).
    pub precision: Vec<(String, String)>,
    /// Parse-time findings (malformed lines, reasonless sanctions).
    pub errors: Vec<Violation>,
}

impl Manifest {
    /// Hand-parse the manifest text. The format is a deliberately tiny
    /// toml subset: `[layers]` / `[precision]` section headers, `#`
    /// comments, and `key = value` lines (deps split on whitespace,
    /// reasons taken verbatim).
    pub fn parse(file: &str, text: &str) -> Manifest {
        let mut m = Manifest { file: file.to_string(), ..Manifest::default() };
        #[derive(PartialEq)]
        enum Section {
            None,
            Layers,
            Precision,
        }
        let mut section = Section::None;
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let lineno = idx + 1;
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = match name {
                    "layers" => Section::Layers,
                    "precision" => Section::Precision,
                    other => {
                        m.errors.push(Violation {
                            file: m.file.clone(),
                            line: lineno,
                            rule: RULE_LAYER,
                            message: format!("unknown manifest section [{other}]"),
                        });
                        Section::None
                    }
                };
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                m.errors.push(Violation {
                    file: m.file.clone(),
                    line: lineno,
                    rule: RULE_LAYER,
                    message: format!("malformed manifest line (expected `key = value`): {line}"),
                });
                continue;
            };
            let key = key.trim();
            let value = value.trim();
            match section {
                Section::Layers => {
                    let deps = value
                        .split_whitespace()
                        .map(|d| d.trim_matches(',').to_string())
                        .filter(|d| !d.is_empty())
                        .collect();
                    m.layers.push(LayerDecl { name: key.to_string(), deps, line: lineno });
                }
                Section::Precision => {
                    if value.is_empty() {
                        m.errors.push(Violation {
                            file: m.file.clone(),
                            line: lineno,
                            rule: "bad-waiver",
                            message: format!("precision sanction for `{key}` missing a reason"),
                        });
                    } else {
                        m.precision.push((key.to_string(), value.to_string()));
                    }
                }
                Section::None => {
                    m.errors.push(Violation {
                        file: m.file.clone(),
                        line: lineno,
                        rule: RULE_LAYER,
                        message: format!("entry outside any [section]: {line}"),
                    });
                }
            }
        }
        m
    }

    /// Paths sanctioned to cross the precision boundary (reasons are
    /// validated at parse time).
    pub fn sanctioned_paths(&self) -> Vec<String> {
        self.precision.iter().map(|(p, _)| p.clone()).collect()
    }

    /// Whether the manifest allows `from` to depend on `to`.
    fn allows(&self, from: &str, to: &str) -> bool {
        self.layers
            .iter()
            .find(|l| l.name == from)
            .is_some_and(|l| l.deps.iter().any(|d| d == to || d == "*"))
    }
}

/// One aggregated dependency edge with first-site provenance.
#[derive(Debug, Clone)]
pub struct Edge {
    /// Source module.
    pub from: String,
    /// Referenced module.
    pub to: String,
    /// File (root-relative) of the first reference.
    pub file: String,
    /// 1-based line of the first reference.
    pub line: usize,
    /// Total non-test reference sites.
    pub count: usize,
}

/// Top-level module a root-relative path belongs to: `quant/gptvq.rs` →
/// `quant`, `error.rs` → `error`. Crate-root files (`lib.rs`,
/// `main.rs`, `bin/…`) belong to no module — they wire everything
/// together by design.
pub fn module_of(rel: &str) -> Option<&str> {
    let head = match rel.split_once('/') {
        Some((head, _)) => head,
        None => rel.strip_suffix(".rs").unwrap_or(rel),
    };
    match head {
        "lib" | "main" | "bin" => None,
        h => Some(h),
    }
}

/// Extract the module names referenced as `crate::<ident>` on one
/// blanked code line.
fn crate_refs(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find("crate::") {
        let abs = from + p;
        let start = abs + "crate::".len();
        from = start;
        // reject "mycrate::" but accept "&crate::", "::crate::" etc.
        if abs > 0 && super::rules::is_ident_byte(bytes[abs - 1]) {
            continue;
        }
        let ident: String = line[start..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() {
            out.push(ident);
        }
    }
    out
}

/// Build the observed inter-module edge set from the lexed files
/// (non-test code lines only), aggregated per (from, to) with
/// first-site provenance. Deterministic: edges come out sorted.
pub fn collect_edges(files: &[(String, SourceFile)]) -> Vec<Edge> {
    let modules: BTreeSet<&str> =
        files.iter().filter_map(|(rel, _)| module_of(rel)).collect();
    let mut map: BTreeMap<(String, String), (String, usize, usize)> = BTreeMap::new();
    for (rel, src) in files {
        let Some(from) = module_of(rel) else { continue };
        for idx in 0..src.n_lines() {
            if src.in_test[idx] {
                continue;
            }
            for to in crate_refs(&src.code[idx]) {
                if to != from && modules.contains(to.as_str()) {
                    map.entry((from.to_string(), to))
                        .and_modify(|(_, _, c)| *c += 1)
                        .or_insert_with(|| (rel.clone(), idx + 1, 1));
                }
            }
        }
    }
    map.into_iter()
        .map(|((from, to), (file, line, count))| Edge { from, to, file, line, count })
        .collect()
}

/// Find elementary cycles reachable by DFS over `adj`, each normalized
/// to start at its lexically-smallest module and deduplicated.
fn find_cycles(adj: &BTreeMap<&str, BTreeSet<&str>>) -> Vec<Vec<String>> {
    fn dfs<'a>(
        node: &'a str,
        adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>, // 0 unseen, 1 on stack, 2 done
        stack: &mut Vec<&'a str>,
        cycles: &mut BTreeSet<Vec<String>>,
    ) {
        color.insert(node, 1);
        stack.push(node);
        if let Some(nexts) = adj.get(node) {
            for &next in nexts {
                match color.get(next).copied().unwrap_or(0) {
                    0 => dfs(next, adj, color, stack, cycles),
                    1 => {
                        // back edge: the stack from `next` onward is a cycle
                        let pos = stack.iter().position(|&n| n == next).unwrap_or(0);
                        let mut cyc: Vec<String> =
                            stack[pos..].iter().map(|s| s.to_string()).collect();
                        // normalize rotation so the same cycle found from
                        // different entry points dedupes
                        let min = cyc
                            .iter()
                            .enumerate()
                            .min_by(|a, b| a.1.cmp(b.1))
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        cyc.rotate_left(min);
                        cycles.insert(cyc);
                    }
                    _ => {}
                }
            }
        }
        stack.pop();
        color.insert(node, 2);
    }
    let mut color = BTreeMap::new();
    let mut cycles = BTreeSet::new();
    for &node in adj.keys() {
        if color.get(node).copied().unwrap_or(0) == 0 {
            dfs(node, adj, &mut color, &mut Vec::new(), &mut cycles);
        }
    }
    cycles.into_iter().collect()
}

/// Run the whole graph pass: manifest parse errors, undeclared/unknown
/// modules, illegal edges, observed cycles, and manifest allow-graph
/// cycles.
pub fn check_graph(manifest: &Manifest, files: &[(String, SourceFile)]) -> Vec<Violation> {
    let mut out = manifest.errors.clone();
    let modules: BTreeSet<&str> =
        files.iter().filter_map(|(rel, _)| module_of(rel)).collect();
    let declared: BTreeSet<&str> = manifest.layers.iter().map(|l| l.name.as_str()).collect();

    for &m in &modules {
        if !declared.contains(m) {
            out.push(Violation {
                file: manifest.file.clone(),
                line: 1,
                rule: RULE_LAYER,
                message: format!(
                    "module `{m}` exists in the source tree but is not declared in [layers]"
                ),
            });
        }
    }
    for l in &manifest.layers {
        if !modules.contains(l.name.as_str()) {
            out.push(Violation {
                file: manifest.file.clone(),
                line: l.line,
                rule: RULE_LAYER,
                message: format!("[layers] declares `{}`, which is not a source module", l.name),
            });
        }
        for d in &l.deps {
            if d != "*" && !modules.contains(d.as_str()) {
                out.push(Violation {
                    file: manifest.file.clone(),
                    line: l.line,
                    rule: RULE_LAYER,
                    message: format!(
                        "[layers] allows `{}` to use `{d}`, which is not a source module",
                        l.name
                    ),
                });
            }
        }
    }

    let edges = collect_edges(files);
    for e in &edges {
        if !manifest.allows(&e.from, &e.to) {
            let allowed = manifest
                .layers
                .iter()
                .find(|l| l.name == e.from)
                .map_or_else(|| "<undeclared>".to_string(), |l| l.deps.join(", "));
            out.push(Violation {
                file: e.file.clone(),
                line: e.line,
                rule: RULE_LAYER,
                message: format!(
                    "`{}` may not depend on `{}` ({} site(s), first here); allowed: [{}]",
                    e.from, e.to, e.count, allowed
                ),
            });
        }
    }

    // observed cycles — violations regardless of the manifest
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in &edges {
        adj.entry(e.from.as_str()).or_default().insert(e.to.as_str());
    }
    for cyc in find_cycles(&adj) {
        let path = format!("{} -> {}", cyc.join(" -> "), cyc[0]);
        let anchor = edges
            .iter()
            .find(|e| e.from == cyc[0] && e.to == cyc[(1) % cyc.len()])
            .map(|e| (e.file.clone(), e.line));
        let (file, line) = anchor.unwrap_or_else(|| (manifest.file.clone(), 1));
        out.push(Violation {
            file,
            line,
            rule: RULE_CYCLE,
            message: format!("module dependency cycle: {path}"),
        });
    }

    // cycles in the manifest's allow-graph: the policy itself must stay
    // a DAG so it can never legalize a future observed cycle
    let mut allow_adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for l in &manifest.layers {
        let e = allow_adj.entry(l.name.as_str()).or_default();
        for d in &l.deps {
            if d != "*" {
                e.insert(d.as_str());
            }
        }
    }
    for cyc in find_cycles(&allow_adj) {
        let path = format!("{} -> {}", cyc.join(" -> "), cyc[0]);
        let line = manifest
            .layers
            .iter()
            .find(|l| l.name == cyc[0])
            .map_or(1, |l| l.line);
        out.push(Violation {
            file: manifest.file.clone(),
            line,
            rule: RULE_CYCLE,
            message: format!("layering manifest allows a dependency cycle: {path}"),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(text: &str) -> SourceFile {
        SourceFile::parse(text)
    }

    #[test]
    fn module_of_maps_paths_to_top_level_modules() {
        assert_eq!(module_of("quant/gptvq.rs"), Some("quant"));
        assert_eq!(module_of("util/detlint/graph.rs"), Some("util"));
        assert_eq!(module_of("error.rs"), Some("error"));
        assert_eq!(module_of("lib.rs"), None);
        assert_eq!(module_of("main.rs"), None);
        assert_eq!(module_of("bin/detlint.rs"), None);
    }

    #[test]
    fn crate_refs_extracts_module_idents() {
        assert_eq!(crate_refs("use crate::tensor::Matrix;"), vec!["tensor"]);
        assert_eq!(
            crate_refs("let x = crate::quant::fit(crate::linalg::chol(h));"),
            vec!["quant", "linalg"]
        );
        assert!(crate_refs("use mycrate::tensor;").is_empty());
    }

    #[test]
    fn edges_skip_test_regions_and_aggregate_counts() {
        let files = vec![
            (
                "a/mod.rs".to_string(),
                src("use crate::b::X;\nfn f() { crate::b::g(); }\n#[cfg(test)]\nmod tests {\n    use crate::c::Y;\n}\n"),
            ),
            ("b/mod.rs".to_string(), src("pub fn g() {}\n")),
            ("c/mod.rs".to_string(), src("pub struct Y;\n")),
        ];
        let edges = collect_edges(&files);
        assert_eq!(edges.len(), 1, "{edges:?}");
        assert_eq!((edges[0].from.as_str(), edges[0].to.as_str()), ("a", "b"));
        assert_eq!((edges[0].line, edges[0].count), (1, 2));
    }

    #[test]
    fn manifest_parse_and_allow() {
        let text = "# comment\n[layers]\nhi = mid lo\nmid = lo\nlo =\n\n[precision]\nx/y.rs = container f32 by design\n";
        let m = Manifest::parse("layers.toml", text);
        assert!(m.errors.is_empty(), "{:?}", m.errors);
        assert_eq!(m.layers.len(), 3);
        assert!(m.allows("hi", "mid") && m.allows("mid", "lo"));
        assert!(!m.allows("lo", "hi") && !m.allows("mid", "hi"));
        assert_eq!(m.sanctioned_paths(), vec!["x/y.rs".to_string()]);
    }

    #[test]
    fn reasonless_precision_sanction_is_bad_waiver() {
        let m = Manifest::parse("layers.toml", "[precision]\nx/y.rs =\n");
        assert_eq!(m.errors.len(), 1);
        assert_eq!(m.errors[0].rule, "bad-waiver");
    }

    #[test]
    fn upward_edge_and_cycle_are_flagged() {
        let manifest = Manifest::parse("layers.toml", "[layers]\nhi = lo\nlo =\n");
        let files = vec![
            ("hi/mod.rs".to_string(), src("use crate::lo::X;\n")),
            ("lo/mod.rs".to_string(), src("use crate::hi::Y;\n")),
        ];
        let vs = check_graph(&manifest, &files);
        let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&RULE_LAYER), "{vs:?}"); // lo -> hi undeclared
        assert!(rules.contains(&RULE_CYCLE), "{vs:?}"); // hi <-> lo observed
    }

    #[test]
    fn manifest_allow_cycle_is_flagged_even_without_code() {
        let manifest = Manifest::parse("layers.toml", "[layers]\na = b\nb = a\n");
        let files = vec![
            ("a/mod.rs".to_string(), src("fn f() {}\n")),
            ("b/mod.rs".to_string(), src("fn g() {}\n")),
        ];
        let vs = check_graph(&manifest, &files);
        assert!(
            vs.iter().any(|v| v.rule == RULE_CYCLE && v.message.contains("manifest")),
            "{vs:?}"
        );
    }
}
