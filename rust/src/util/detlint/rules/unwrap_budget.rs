//! `unwrap-budget`: bare `unwrap()`/`expect()` density in library code.
//!
//! A handful of unwraps on genuinely-infallible paths (uncontended
//! locks, index invariants the module itself upholds) is idiomatic; a
//! file that accumulates dozens is one refactor away from a panic in
//! library code the seed suffered from (PR 1 fixed `tokens_2d`
//! panicking on ragged batches). The rule is a per-file budget over
//! non-test code, raisable with an explicit
//! `// detlint: budget(unwrap, N)` file comment that states *why* the
//! file's unwraps are sound as a class (see `util/pool.rs`), or waived
//! per line with `detlint: allow(unwrap-budget, reason)`.

use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "unwrap-budget";

/// Default per-file budget of non-test `unwrap()`/`expect()` calls.
pub const DEFAULT_BUDGET: usize = 10;

/// Count non-test unwraps/expects against the file's budget and emit a
/// single file-level violation (anchored at the first counted call) when
/// the budget is exceeded.
pub fn check(sink: &mut Sink<'_>) {
    let mut count = 0usize;
    let mut first: Option<usize> = None;
    for idx in 0..sink.src.n_lines() {
        if sink.src.in_test[idx] {
            continue;
        }
        let line = &sink.src.code[idx];
        let hits = line.matches(".unwrap()").count() + line.matches(".expect(").count();
        if hits == 0 {
            continue;
        }
        if sink.src.waived(idx, RULE) {
            sink.waived.push(RULE);
            continue;
        }
        if first.is_none() {
            first = Some(idx);
        }
        count += hits;
    }
    let budget = sink.src.unwrap_budget.unwrap_or(DEFAULT_BUDGET);
    if count > budget {
        // bypasses the per-line waiver path on purpose: a file-level
        // count is only waivable by raising the budget with a reason
        sink.violations.push(crate::util::detlint::Violation {
            file: sink.file.to_string(),
            line: first.map_or(1, |i| i + 1),
            rule: RULE,
            message: format!(
                "{count} bare unwrap()/expect() in non-test code exceeds budget {budget}"
            ),
        });
    }
}
