//! `unsafe-no-safety`: `unsafe` without a `// SAFETY:` comment.
//!
//! The crate has exactly one `unsafe` block — the lifetime transmute in
//! `util/pool.rs` that lets the queue store borrowed scope jobs as
//! `'static` — and its soundness argument (the scope's latch blocks
//! until every job has run) lives in a `// SAFETY:` comment that Miri
//! exercises in CI. This rule keeps that the pattern: any new `unsafe`
//! (block, fn, or impl) must carry its argument in a `// SAFETY:`
//! comment on the same line or within the five lines above.

use crate::util::detlint::rules::token_match;
use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "unsafe-no-safety";

/// How many preceding comment lines are searched for `SAFETY:`.
const LOOKBACK: usize = 5;

/// Flag `unsafe` tokens (tests included — unsound test code is still
/// unsound) lacking a nearby `SAFETY:` comment.
pub fn check(sink: &mut Sink<'_>) {
    for idx in 0..sink.src.n_lines() {
        if !token_match(&sink.src.code[idx], "unsafe") {
            continue;
        }
        let lo = idx.saturating_sub(LOOKBACK);
        let documented =
            sink.src.comments[lo..=idx].iter().any(|c| c.contains("SAFETY:"));
        if !documented {
            sink.emit(
                idx,
                RULE,
                "unsafe without a // SAFETY: comment in the preceding 5 lines".to_string(),
            );
        }
    }
}
