//! `hot-alloc`: heap allocation inside a marked hot region.
//!
//! The engine sweep, the fused decode-matmul, and the Engine step loop
//! are the crate's throughput-critical inner loops; PR 4 and PR 5 spent
//! whole PRs keeping allocations out of them (scratch buffers, the
//! persistent `WorkerPool`). This rule makes that property enforceable:
//! a region bracketed by `// detlint: hot(<label>)` and
//! `// detlint: endhot` comments may not contain `Vec::new`, `vec![`,
//! `.collect(`, or `.clone()` — allocate before the region or reuse a
//! scratch buffer. A genuinely-required allocation (e.g. a per-task
//! scratch local to a pool closure) is waived inline with
//! `detlint: allow(hot-alloc, reason)`. Mismatched markers are
//! themselves violations so a typo cannot silently disable the check.

use crate::util::detlint::rules::token_match;
use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "hot-alloc";

/// Allocation patterns matched on the blanked code view. The first
/// element is matched with token boundaries, the rest by substring
/// (they start with `.` or end with `[`, so boundaries are implied).
const TOKEN_PATTERNS: [&str; 2] = ["Vec::new", "vec!["];
const SUBSTR_PATTERNS: [&str; 3] = [".collect(", ".collect::<", ".clone()"];

/// Flag allocations on non-test lines inside hot regions, and report
/// every malformed region marker.
pub fn check(sink: &mut Sink<'_>) {
    let marker_errors: Vec<(usize, String)> =
        sink.src.marker_errors.iter().map(|e| (e.line, e.message.clone())).collect();
    for (line, message) in marker_errors {
        sink.emit(line, RULE, format!("malformed hot-region marker: {message}"));
    }
    for idx in 0..sink.src.n_lines() {
        if !sink.src.in_hot[idx] || sink.src.in_test[idx] {
            continue;
        }
        let line = sink.src.code[idx].clone();
        let mut hits: Vec<&str> = Vec::new();
        for pat in TOKEN_PATTERNS {
            if token_match(&line, pat) {
                hits.push(pat);
            }
        }
        for pat in SUBSTR_PATTERNS {
            if line.contains(pat) {
                hits.push(pat);
            }
        }
        if !hits.is_empty() {
            sink.emit(
                idx,
                RULE,
                format!(
                    "allocation in hot region (`{}`); preallocate outside the loop or reuse a scratch buffer",
                    hits.join("`, `")
                ),
            );
        }
    }
}
