//! `precision-cast`: float-precision boundary crossings outside the
//! sanctioned modules.
//!
//! PR 3 drew the crate's precision boundary: hot loops are generic over
//! [`Element`] (f64/f32) while Cholesky, EM seeding, and every reported
//! loss stay pinned to f64, guarded by `F32_LOSS_REL_TOL`. That boundary
//! is only as strong as its narrowest uncontrolled cast — a stray
//! `as f32` in an accumulator silently converts a controlled-precision
//! result into an uncontrolled one. This rule makes the boundary
//! greppable and enforced: narrowing casts (`as f32`) and the explicit
//! boundary calls (`from_f64`, `to_f64`, `.convert(`) may appear only in
//! modules sanctioned by the `[precision]` section of
//! `rust/detlint_layers.toml` (each sanction carries a mandatory reason)
//! or at sites waived inline with `detlint: allow(precision-cast, reason)`.
//!
//! Widening `as f64` casts are exact for every integer and f32 value
//! this crate produces, so they are flagged only under
//! `--strict-precision` — useful when auditing, too noisy to block on.
//!
//! [`Element`]: crate::tensor::element::Element

use crate::util::detlint::rules::token_match;
use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "precision-cast";

/// Modules that *are* the boundary, sanctioned even without a manifest:
/// the `Element` trait definition and the generic kernel layer.
pub const DEFAULT_SANCTIONED: [&str; 2] = ["tensor/element.rs", "tensor/ops.rs"];

/// Flag precision-boundary crossings on non-test lines of unsanctioned
/// files. `sanctioned` holds extra path suffixes from the layering
/// manifest's `[precision]` section; `strict` additionally flags
/// (exact, widening) `as f64` casts.
pub fn check(file: &str, sink: &mut Sink<'_>, sanctioned: &[String], strict: bool) {
    if DEFAULT_SANCTIONED.iter().any(|s| file.ends_with(s))
        || sanctioned.iter().any(|s| file.ends_with(s.as_str()))
    {
        return;
    }
    for idx in 0..sink.src.n_lines() {
        if sink.src.in_test[idx] {
            continue;
        }
        let line = sink.src.code[idx].clone();
        let mut hits: Vec<&str> = Vec::new();
        if token_match(&line, "as f32") {
            hits.push("as f32");
        }
        if strict && token_match(&line, "as f64") {
            hits.push("as f64");
        }
        if token_match(&line, "from_f64") {
            hits.push("from_f64");
        }
        if token_match(&line, "to_f64") {
            hits.push("to_f64");
        }
        if line.contains(".convert(") {
            hits.push(".convert(");
        }
        if !hits.is_empty() {
            sink.emit(
                idx,
                RULE,
                format!(
                    "precision boundary crossing (`{}`) outside a sanctioned module; \
                     sanction the file in detlint_layers.toml [precision] or waive with a reason",
                    hits.join("`, `")
                ),
            );
        }
    }
}
