//! `wall-clock`: `Instant::now`/`SystemTime` reads inside compute
//! modules.
//!
//! The determinism contract says schedules depend only on workload
//! shape, never on timing (`util::par::threads_for` is the canonical
//! statement). A wall-clock read in a compute module is one conditional
//! away from a timing-steered schedule — or from timing leaking into a
//! reported number that tests then pin. Clock reads belong in the
//! dedicated measurement modules; anywhere else they need a waiver
//! stating that the measured time only annotates output (metrics,
//! percentiles) and never steers computation.

use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "wall-clock";

/// Modules whose whole purpose is measurement: timers, serve-side
/// latency statistics, and the coordinator's metrics collector.
pub const ALLOWED: [&str; 3] = ["util/timer.rs", "coordinator/metrics.rs", "serve/stats.rs"];

/// Flag non-test clock reads outside the measurement modules.
pub fn check(file: &str, sink: &mut Sink<'_>) {
    if ALLOWED.iter().any(|a| file.ends_with(a)) {
        return;
    }
    for idx in 0..sink.src.n_lines() {
        if sink.src.in_test[idx] {
            continue;
        }
        let line = &sink.src.code[idx];
        if line.contains("Instant::now") || line.contains("SystemTime") {
            sink.emit(
                idx,
                RULE,
                "wall-clock read in a compute module; timing must never steer results"
                    .to_string(),
            );
        }
    }
}
