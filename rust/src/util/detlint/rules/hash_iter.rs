//! `hash-iter`: iteration over `HashMap`/`HashSet` in the deterministic
//! core without a sorting step.
//!
//! Hash iteration order is randomized per process, so any hash-keyed
//! walk that feeds quantization, serving, or reporting silently breaks
//! the bitwise-reproducibility contract (PR 2 hit exactly this with
//! nondeterministic layer ordering). The rule is scoped to the modules
//! under that contract — `quant/`, `coordinator/`, `serve/` — and is
//! satisfied by a `sort`/`BTree*` within the statement's next few
//! lines; keyed access (`get`, `entry`, `len`) never fires.

use crate::util::detlint::rules::token_match;
use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "hash-iter";

/// Module prefixes under the bitwise-determinism contract.
pub const SCOPES: [&str; 3] = ["quant/", "coordinator/", "serve/"];

/// Iteration methods whose visit order is the hash order.
const ITER_METHODS: [&str; 10] = [
    "iter()",
    "iter_mut()",
    "keys()",
    "values()",
    "values_mut()",
    "drain(",
    "into_iter()",
    "into_keys()",
    "into_values()",
    "retain(",
];

/// Evidence that the iteration is ordered before use.
const SORT_MARKS: [&str; 3] = ["sort", "Sorted", "BTree"];

/// Extract the name bound to a `HashMap`/`HashSet` on this line:
/// `let [mut] name = HashMap::…`, `let [mut] name: HashMap<…>`, a
/// struct field `name: HashMap<…>`, or a parameter
/// `name: &[mut] HashMap<…>`. Return-type and `use`-path mentions bind
/// nothing.
fn bound_name(line: &str) -> Option<String> {
    for marker in ["HashMap", "HashSet"] {
        let mut from = 0;
        while let Some(p) = line[from..].find(marker) {
            let abs = from + p;
            from = abs + marker.len();
            let mut before = line[..abs].trim_end();
            if let Some(h) = before.strip_suffix("std::collections::") {
                before = h.trim_end();
            }
            if before.ends_with("::") {
                continue; // some other qualified path (e.g. a use item)
            }
            if let Some(h) = before.strip_suffix("mut") {
                before = h.trim_end();
            }
            if let Some(h) = before.strip_suffix('&') {
                before = h.trim_end();
            }
            let head = match before.strip_suffix(':').or_else(|| before.strip_suffix('=')) {
                Some(h) => h.trim_end().trim_end_matches(':'),
                None => continue,
            };
            let name: String = head
                .chars()
                .rev()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect::<Vec<char>>()
                .into_iter()
                .rev()
                .collect();
            if !name.is_empty() && name != "mut" {
                return Some(name);
            }
        }
    }
    None
}

/// Flag unsorted iteration over hash-collection bindings in scoped
/// files. `file` is the path relative to the scan root, `/`-separated.
pub fn check(file: &str, sink: &mut Sink<'_>) {
    if !SCOPES.iter().any(|s| file.contains(s)) {
        return;
    }
    let names: Vec<String> = sink.src.code.iter().filter_map(|l| bound_name(l)).collect();
    if names.is_empty() {
        return;
    }
    for idx in 0..sink.src.n_lines() {
        if sink.src.in_test[idx] {
            continue;
        }
        let line = sink.src.code[idx].clone();
        let mut hit: Option<String> = None;
        for nm in &names {
            for m in ITER_METHODS {
                let pat = format!("{nm}.{m}");
                if token_match(&line, &pat) {
                    hit = Some(pat.clone());
                }
            }
            for pat in [format!("in &{nm}"), format!("in &mut {nm}"), format!("in {nm}")] {
                if token_match(&line, &pat) {
                    hit = Some(pat.clone());
                }
            }
        }
        if let Some(h) = hit {
            let end = (idx + 4).min(sink.src.n_lines());
            let ctx = sink.src.code[idx..end].join(" ");
            if !SORT_MARKS.iter().any(|s| ctx.contains(s)) {
                sink.emit(
                    idx,
                    RULE,
                    format!("unsorted hash iteration `{h}`; hash order is nondeterministic"),
                );
            }
        }
    }
}
