//! `partial-cmp-unwrap`: `partial_cmp(...).unwrap()` in comparator
//! position.
//!
//! `partial_cmp` returns `None` on NaN, so the unwrap panics the moment
//! a NaN reaches a sort/max/min — and the quantizer hot path (EM
//! objectives, eigenvalue ordering, seeding distances) is exactly where
//! a NaN from a degenerate Hessian first surfaces. The fix is
//! `f64::total_cmp`, which is a total order (NaN sorts to the tail
//! deterministically) and therefore also removes the comparator's
//! unspecified-order hazard. This was a real bug class here: PR 2 fixed
//! four such panics in serve stats and EM reseeding.

use crate::util::detlint::Sink;

/// Rule id.
pub const RULE: &str = "partial-cmp-unwrap";

/// Flag every non-test code line chaining `partial_cmp` into
/// `.unwrap()` (sorts, `max_by`, `min_by`, `binary_search_by`, …).
pub fn check(sink: &mut Sink<'_>) {
    for idx in 0..sink.src.n_lines() {
        if sink.src.in_test[idx] {
            continue;
        }
        let line = &sink.src.code[idx];
        if line.contains("partial_cmp") && line.contains(".unwrap()") {
            sink.emit(
                idx,
                RULE,
                "partial_cmp().unwrap() panics on NaN; use f64::total_cmp".to_string(),
            );
        }
    }
}
