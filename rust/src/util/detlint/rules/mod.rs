//! The determinism/robustness rules, one module per rule.
//!
//! Every rule is a pure function over the lexed [`SourceFile`] view: it
//! emits candidate violations into a [`Sink`], which resolves inline
//! `// detlint: allow(rule, reason)` waivers (same line or the line
//! above) before recording them. Rules never read the filesystem and
//! never parse Rust — see the module docs on
//! [`super::source`] for the lexical model and its limits.
//!
//! [`SourceFile`]: super::source::SourceFile
//! [`Sink`]: super::Sink

pub mod hash_iter;
pub mod hot_alloc;
pub mod partial_cmp;
pub mod precision_cast;
pub mod unsafe_safety;
pub mod unwrap_budget;
pub mod wall_clock;

/// Byte classifier shared by the token matchers: part of an identifier.
pub(crate) fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-boundary substring search: `pat` occurs in `line` with no
/// identifier byte directly before it, and — when `pat` itself ends in
/// an identifier byte — none directly after. This keeps a tracked name
/// `s` from matching inside `sites.iter()` and `unsafe` from matching
/// inside `unsafe_op`.
pub(crate) fn token_match(line: &str, pat: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find(pat) {
        let abs = from + p;
        let before_ok = abs == 0 || !is_ident_byte(bytes[abs - 1]);
        let end = abs + pat.len();
        let pat_ends_ident = pat.bytes().last().is_some_and(is_ident_byte);
        let after_ok = !pat_ends_ident || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = abs + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_match_respects_boundaries() {
        assert!(token_match("let x = unsafe {", "unsafe"));
        assert!(!token_match("let unsafe_op = 1;", "unsafe"));
        assert!(!token_match("sites.iter()", "s.iter()"));
        assert!(token_match("for k in sites.keys()", "sites.keys()"));
        assert!(!token_match("m_sites.keys()", "sites.keys()"));
    }
}
