//! Lexical source model for the determinism linter.
//!
//! detlint deliberately does **not** parse Rust (no `syn`, nothing from
//! the registry — the crate builds offline): it scans line-by-line over
//! a lightly lexed view of each file. Per line it separates *code* from
//! *comment text* — string and char literals are blanked out of the code
//! view so a rule pattern inside a message string can never fire — and
//! it tracks which lines sit inside `#[cfg(test)] mod` regions by brace
//! depth, because the rules police library code, not tests.

/// One parsed waiver comment: `// detlint: allow(<rule>, <reason>)`.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 0-based line index the waiver comment sits on.
    pub line: usize,
    /// Rule id named in the waiver.
    pub rule: String,
    /// Mandatory free-text justification; `None` is itself a violation.
    pub reason: Option<String>,
}

/// A malformed hot-region marker pair (`detlint: hot(...)` without a
/// matching `detlint: endhot`, or vice versa). Reported by the
/// `hot-alloc` rule so a half-marked region cannot silently disable the
/// allocation check.
#[derive(Debug, Clone)]
pub struct MarkerError {
    /// 0-based line index of the offending marker (or the dangling open).
    pub line: usize,
    /// What is wrong with the marker.
    pub message: String,
}

/// Lexed view of one source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Per-line code with comments and string/char literals blanked.
    pub code: Vec<String>,
    /// Per-line comment text (line + block comments).
    pub comments: Vec<String>,
    /// Per-line flag: the line carries a plain (non-doc) comment.
    /// Waivers are only honored in plain comments — rustdoc text
    /// (`///`, `//!`, `/** */`) routinely *mentions* the waiver syntax
    /// when documenting it, and must never enact it.
    pub plain_comment: Vec<bool>,
    /// Per-line flag: inside a `#[cfg(test)] mod` region.
    pub in_test: Vec<bool>,
    /// All `detlint: allow(...)` waivers in the file.
    pub waivers: Vec<Waiver>,
    /// File-level `detlint: budget(unwrap, N)` override, if any.
    pub unwrap_budget: Option<usize>,
    /// Per-line flag: inside a `// detlint: hot(<label>)` …
    /// `// detlint: endhot` region (exclusive of both marker lines).
    pub in_hot: Vec<bool>,
    /// Malformed hot-region markers (dangling open, stray close,
    /// nested open); surfaced by the `hot-alloc` rule.
    pub marker_errors: Vec<MarkerError>,
}

impl SourceFile {
    /// Lex `text` into the per-line code/comment views and the waiver
    /// and test-region maps the rules consume.
    pub fn parse(text: &str) -> SourceFile {
        let (code, comments, plain_comment) = strip_code(text);
        let in_test = test_regions(&code);
        let (waivers, unwrap_budget) = parse_waivers(&comments, &plain_comment);
        let (in_hot, marker_errors) = parse_hot_regions(&comments, &plain_comment);
        SourceFile {
            code,
            comments,
            plain_comment,
            in_test,
            waivers,
            unwrap_budget,
            in_hot,
            marker_errors,
        }
    }

    /// Number of lines in the file.
    pub fn n_lines(&self) -> usize {
        self.code.len()
    }

    /// Whether a violation of `rule` at 0-based `line` is waived: a
    /// reasoned `detlint: allow` on the same line or the line directly
    /// above. Reasonless waivers never apply (they are `bad-waiver`
    /// violations instead).
    pub fn waived(&self, line: usize, rule: &str) -> bool {
        self.waivers.iter().any(|w| {
            w.rule == rule
                && w.reason.is_some()
                && (w.line == line || w.line + 1 == line)
        })
    }
}

/// Split raw source into per-line (code, comment) views: comments are
/// removed from the code side (and collected on the comment side), and
/// string/char literals are blanked from the code side so patterns in
/// message text never match. Block comments and (non-raw) strings are
/// tracked across the whole file; raw-string hashes are treated as plain
/// quotes, which is exact enough for a lint heuristic on this crate.
fn strip_code(text: &str) -> (Vec<String>, Vec<String>, Vec<bool>) {
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut plain_flags = Vec::new();
    let mut in_block = false;
    let mut block_is_doc = false;
    for raw in text.lines() {
        let b: Vec<char> = raw.chars().collect();
        let mut code = String::new();
        let mut comment = String::new();
        let mut plain = in_block && !block_is_doc;
        let mut i = 0usize;
        let mut in_str = false;
        while i < b.len() {
            let c = b[i];
            let next = b.get(i + 1).copied();
            if in_block {
                if c == '*' && next == Some('/') {
                    in_block = false;
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
                continue;
            }
            if in_str {
                if c == '\\' {
                    i += 2;
                    continue;
                }
                if c == '"' {
                    in_str = false;
                    code.push('"');
                }
                i += 1;
                continue;
            }
            if c == '/' && next == Some('/') {
                // /// and //! are rustdoc; only plain // enacts waivers
                if !matches!(b.get(i + 2), Some('/') | Some('!')) {
                    plain = true;
                }
                comment.extend(&b[i + 2..]);
                break;
            }
            if c == '/' && next == Some('*') {
                in_block = true;
                block_is_doc = matches!(b.get(i + 2), Some('*') | Some('!'));
                if !block_is_doc {
                    plain = true;
                }
                i += 2;
                continue;
            }
            if c == '"' {
                in_str = true;
                code.push('"');
                i += 1;
                continue;
            }
            if c == '\'' {
                // char literal ('x' or '\x') vs lifetime ('a): blank the
                // former, pass the latter through untouched
                let lit_len = match (next, b.get(i + 2).copied(), b.get(i + 3).copied()) {
                    (Some('\\'), Some(_), Some('\'')) => Some(4),
                    (Some(ch), Some('\''), _) if ch != '\\' && ch != '\'' => Some(3),
                    _ => None,
                };
                if let Some(len) = lit_len {
                    code.push_str("' '");
                    i += len;
                    continue;
                }
            }
            code.push(c);
            i += 1;
        }
        code_lines.push(code);
        comment_lines.push(comment);
        plain_flags.push(plain);
    }
    (code_lines, comment_lines, plain_flags)
}

/// Per-line flags marking `#[cfg(test)] mod` regions, tracked by brace
/// depth on the stripped code view.
fn test_regions(code: &[String]) -> Vec<bool> {
    let mut flags = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut pending = false; // saw #[cfg(test)], waiting for the mod {
    let mut region_depth: Option<i64> = None;
    for (idx, line) in code.iter().enumerate() {
        if region_depth.is_some() {
            flags[idx] = true;
        }
        if line.contains("#[cfg(test)]") {
            pending = true;
        }
        let opens = line.matches('{').count() as i64;
        let closes = line.matches('}').count() as i64;
        if pending && line.contains("mod") && opens > 0 {
            region_depth = Some(depth);
            pending = false;
            flags[idx] = true;
        }
        depth += opens - closes;
        if let Some(rd) = region_depth {
            if depth <= rd {
                region_depth = None;
            }
        }
    }
    flags
}

/// Scan *plain* comment text for waivers (the `allow` form with a rule
/// and reason) and the file-level unwrap-budget override; rustdoc text
/// is skipped so documentation of the syntax never enacts it.
fn parse_waivers(comments: &[String], plain: &[bool]) -> (Vec<Waiver>, Option<usize>) {
    let mut waivers = Vec::new();
    let mut budget = None;
    for (idx, com) in comments.iter().enumerate() {
        if !plain[idx] {
            continue;
        }
        let mut rest: &str = com;
        while let Some(pos) = rest.find("detlint:") {
            let after = rest[pos + "detlint:".len()..].trim_start();
            if let Some(args) = after.strip_prefix("allow(") {
                if let Some(end) = args.find(')') {
                    let inner = &args[..end];
                    let (rule, reason) = match inner.split_once(',') {
                        Some((r, why)) => {
                            let why = why.trim();
                            (r.trim(), (!why.is_empty()).then(|| why.to_string()))
                        }
                        None => (inner.trim(), None),
                    };
                    waivers.push(Waiver { line: idx, rule: rule.to_string(), reason });
                    rest = &args[end..];
                    continue;
                }
            } else if let Some(args) = after.strip_prefix("budget(unwrap,") {
                if let Some(end) = args.find(')') {
                    if let Ok(n) = args[..end].trim().parse::<usize>() {
                        budget = Some(n);
                    }
                    rest = &args[end..];
                    continue;
                }
            }
            rest = after;
        }
    }
    (waivers, budget)
}

/// Scan *plain* comment text for hot-region markers:
/// `// detlint: hot(<label>)` opens a region, `// detlint: endhot`
/// closes it. The region covers the lines strictly between the two
/// marker lines — allocations on a marker line itself are the marker
/// author's responsibility to avoid. Like waivers, markers in rustdoc
/// text never apply. Mismatched markers are collected as errors so the
/// `hot-alloc` rule can report them: a half-marked region must never
/// silently disable the check.
fn parse_hot_regions(comments: &[String], plain: &[bool]) -> (Vec<bool>, Vec<MarkerError>) {
    let mut in_hot = vec![false; comments.len()];
    let mut errors = Vec::new();
    let mut open: Option<usize> = None;
    for (idx, com) in comments.iter().enumerate() {
        if let Some(line) = open {
            if idx > line {
                in_hot[idx] = true;
            }
        }
        if !plain[idx] {
            continue;
        }
        if com.contains("detlint: endhot") {
            match open {
                Some(_) => {
                    open = None;
                    // the closing marker line is outside the region
                    in_hot[idx] = false;
                }
                None => errors.push(MarkerError {
                    line: idx,
                    message: "`detlint: endhot` without an open hot region".to_string(),
                }),
            }
            continue;
        }
        if let Some(pos) = com.find("detlint: hot") {
            // the marker token must end here ("hotel" is not a marker);
            // a parenthesized label — hot(engine-sweep) — is encouraged
            let after = com.as_bytes().get(pos + "detlint: hot".len()).copied();
            let is_marker = !after.is_some_and(super::rules::is_ident_byte);
            if is_marker {
                if open.is_some() {
                    errors.push(MarkerError {
                        line: idx,
                        message: "`detlint: hot` inside an already-open hot region".to_string(),
                    });
                } else {
                    open = Some(idx);
                }
            }
        }
    }
    if let Some(line) = open {
        errors.push(MarkerError {
            line,
            message: "hot region never closed (missing `// detlint: endhot`)".to_string(),
        });
    }
    (in_hot, errors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped_from_code() {
        let src = "let x = \"partial_cmp\"; // partial_cmp in comment\nlet y = 1;";
        let f = SourceFile::parse(src);
        assert!(!f.code[0].contains("partial_cmp"));
        assert!(f.comments[0].contains("partial_cmp"));
        assert!(f.code[1].contains("let y"));
    }

    #[test]
    fn block_comments_span_lines() {
        let src = "a /* unsafe\nstill unsafe */ b";
        let f = SourceFile::parse(src);
        assert!(!f.code[0].contains("unsafe"));
        assert!(!f.code[1].contains("unsafe"));
        assert!(f.code[1].contains('b'));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "let c = 'x'; fn f<'a>(v: &'a str) {}";
        let f = SourceFile::parse(src);
        assert!(!f.code[0].contains('x'));
        assert!(f.code[0].contains("'a"));
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let f = SourceFile::parse(src);
        assert!(!f.in_test[0]);
        assert!(f.in_test[2] && f.in_test[3] && f.in_test[4]);
        assert!(!f.in_test[5]);
    }

    #[test]
    fn waivers_parse_rule_and_reason() {
        let src = "x(); // detlint: allow(wall-clock, metrics only)\ny(); // detlint: allow(hash-iter)";
        let f = SourceFile::parse(src);
        assert_eq!(f.waivers.len(), 2);
        assert_eq!(f.waivers[0].rule, "wall-clock");
        assert_eq!(f.waivers[0].reason.as_deref(), Some("metrics only"));
        assert!(f.waivers[1].reason.is_none());
        assert!(f.waived(0, "wall-clock"));
        assert!(f.waived(1, "wall-clock"), "waiver covers the following line");
        assert!(!f.waived(1, "hash-iter"), "reasonless waiver never applies");
    }

    #[test]
    fn doc_comments_never_enact_waivers() {
        // documenting the waiver syntax in rustdoc (as util::detlint's
        // own module docs do) must not register a waiver or a bad-waiver
        let src = "/// use `// detlint: allow(wall-clock, why)` to waive\nfn f() {}";
        let f = SourceFile::parse(src);
        assert!(f.waivers.is_empty(), "{:?}", f.waivers);
        let src2 = "//! `// detlint: allow(rule, reason)`\nfn g() {}";
        let f2 = SourceFile::parse(src2);
        assert!(f2.waivers.is_empty());
        // a plain comment with the same text still works
        let src3 = "x(); // detlint: allow(wall-clock, real reason)";
        assert_eq!(SourceFile::parse(src3).waivers.len(), 1);
    }

    #[test]
    fn hot_regions_cover_interior_lines_only() {
        let src = "a();\n// detlint: hot(sweep)\nb();\nc();\n// detlint: endhot\nd();";
        let f = SourceFile::parse(src);
        assert_eq!(f.in_hot, vec![false, false, true, true, false, false]);
        assert!(f.marker_errors.is_empty(), "{:?}", f.marker_errors);
    }

    #[test]
    fn mismatched_hot_markers_are_errors() {
        let unclosed = SourceFile::parse("// detlint: hot(x)\na();");
        assert_eq!(unclosed.marker_errors.len(), 1);
        assert_eq!(unclosed.marker_errors[0].line, 0);
        let stray = SourceFile::parse("a();\n// detlint: endhot");
        assert_eq!(stray.marker_errors.len(), 1);
        assert_eq!(stray.marker_errors[0].line, 1);
        let nested = SourceFile::parse("// detlint: hot(a)\n// detlint: hot(b)\n// detlint: endhot");
        assert_eq!(nested.marker_errors.len(), 1, "{:?}", nested.marker_errors);
    }

    #[test]
    fn doc_comments_never_open_hot_regions() {
        let src = "/// mark with `// detlint: hot(label)`\nfn f() { let v = vec![0; 4]; }";
        let f = SourceFile::parse(src);
        assert!(f.marker_errors.is_empty(), "{:?}", f.marker_errors);
        assert!(f.in_hot.iter().all(|h| !h));
    }

    #[test]
    fn budget_override_is_parsed() {
        let src = "// detlint: budget(unwrap, 24) — locks only\nfn f() {}";
        let f = SourceFile::parse(src);
        assert_eq!(f.unwrap_budget, Some(24));
    }
}
