//! Minimal property-test driver (no proptest crate available offline).
//!
//! `check` runs a property over many RNG-derived cases; on failure it
//! panics with the failing case seed so the case can be replayed exactly:
//!
//! ```
//! use gptvq::util::prop::check;
//! check("abs is non-negative", 100, |rng| {
//!     let x = rng.gaussian();
//!     if x.abs() >= 0.0 { Ok(()) } else { Err(format!("x={x}")) }
//! });
//! ```

use super::rng::Rng;

/// Base seed; combined with the case index via splitmix-style mixing so
/// each case is independent but reproducible.
pub const BASE_SEED: u64 = 0x6774_7671_2024_0000; // "gtvq" 2024

/// Run `cases` random cases of a property. The closure gets a fresh,
/// case-seeded RNG and returns `Err(description)` to fail.
pub fn check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = BASE_SEED ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two slices are elementwise close (absolute + relative).
pub fn assert_close(got: &[f64], want: &[f64], atol: f64, rtol: f64, ctx: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{ctx}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = atol + rtol * w.abs();
        if (g - w).abs() > tol {
            return Err(format!("{ctx}: index {i}: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let x = rng.uniform();
            if x < 2.0 {
                Err(format!("always fails, x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-12, 1e-12, "eq").is_ok());
    }

    #[test]
    fn assert_close_rejects_far() {
        assert!(assert_close(&[1.0], &[2.0], 1e-6, 1e-6, "far").is_err());
    }
}
