//! Small shared utilities: deterministic RNG, property-test driver,
//! timers, the persistent worker pool, data-parallel helpers, the
//! loom-swappable sync shim, and the determinism linter.

pub mod detlint;
pub mod par;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod timer;

pub use par::{
    effective_threads, parallel_map, parallel_map_scoped, parallel_row_bands,
    parallel_row_bands_scoped, test_threads, threads_for,
};
pub use pool::{PoolScope, WorkerPool};
pub use rng::Rng;
pub use timer::Timer;
