//! Small shared utilities: deterministic RNG, property-test driver, timers.

pub mod prop;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
