//! Small shared utilities: deterministic RNG, property-test driver,
//! timers, and fork-join parallelism helpers.

pub mod par;
pub mod prop;
pub mod rng;
pub mod timer;

pub use par::{effective_threads, parallel_map, parallel_row_bands, test_threads, threads_for};
pub use rng::Rng;
pub use timer::Timer;
