//! Persistent, channel-fed worker pool with deterministic task slotting.
//!
//! PR 2's fork-join helpers paid a `std::thread::scope` spawn/join on
//! every parallel stage — tens of µs per worker, which dominates on
//! small layers where the engine dispatches thousands of short stages
//! (per-step assignment, block propagation, span flushes). A
//! [`WorkerPool`] is created **once per engine / calibration / pipeline
//! invocation** and fed through a shared job queue instead: dispatching
//! a stage costs a queue push and a condvar wake, not a thread spawn.
//!
//! The determinism contract is unchanged from the scoped helpers: tasks
//! carry fixed slot indices and every result lands in its own slot (or
//! its own disjoint row band), so the reduction order — and therefore
//! the output, bitwise — is identical for every pool width, including 1
//! (which runs inline without touching the queue at all).
//!
//! Deadlock freedom under nesting: a thread that waits for a batch
//! (`scope`/`run`) does not park unconditionally — while its batch is
//! outstanding it *helps*, popping and running queued jobs (its own or
//! another batch's). Nested fan-outs (EM inside a strip task, a matmul
//! inside a calibration sequence task, span-pipelined EM prefetch next
//! to a flush) therefore always make progress even when every worker is
//! occupied: the work is conserved, only the executing thread changes,
//! and slotting keeps the result independent of who ran what.

// Synchronization comes from the `util::sync` shim, not `std::sync`
// directly: a `--cfg loom` build swaps every primitive below for loom's
// model-checked twin, and `tests/loom_pool.rs` then exhaustively
// explores the latch/help-while-waiting/condvar interleavings that the
// parity tests can only sample. `OnceLock` stays on std — it backs the
// lazily-created inline pool, which owns no threads and is outside the
// checked protocol.
use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

use crate::util::par::{effective_threads, par_grain};
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{spawn_worker, Arc, Condvar, JoinHandle, Mutex};

// detlint: budget(unwrap, 24) — every non-test unwrap in this module is
// a `Mutex::lock().unwrap()` (or the latch's panic-slot lock) whose only
// failure mode is a lock poisoned by an already-propagating worker
// panic; unwrapping forwards that panic, which is the pool's documented
// panic-propagation behavior, not an unhandled error path.

/// A queued unit of work. Jobs are type-erased closures; lifetimes are
/// handled by [`WorkerPool::scope`], which never returns before every
/// job it spawned has completed.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the pool handle and its worker threads.
struct Shared {
    /// FIFO of pending jobs; guarded by one mutex also used to make
    /// condvar waits race-free.
    queue: Mutex<VecDeque<Job>>,
    /// Signaled on every job push and every batch completion.
    cv: Condvar,
    /// Set once by `Drop`; workers exit when the queue is drained.
    shutdown: AtomicBool,
}

/// Completion tracker of one spawned batch (a `scope`'s jobs).
struct Latch {
    /// Jobs spawned but not yet finished.
    remaining: AtomicUsize,
    /// First panic payload captured from a job of this batch.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new() -> Latch {
        Latch { remaining: AtomicUsize::new(0), panic: Mutex::new(None) }
    }
}

/// A persistent pool of `n_threads - 1` worker threads plus the calling
/// thread, created once per quantization/calibration invocation and
/// borrowed by every parallel stage inside it.
///
/// * Workers are spawned lazily on the first real fan-out, so an
///   inline pool (`n_threads == 1`, or every stage below the grain)
///   costs no threads at all.
/// * Batches are submitted with [`WorkerPool::run`] (index-addressed
///   map, the common case) or [`WorkerPool::scope`] (arbitrary borrowed
///   jobs, used by the engine's span-pipelined EM prefetch).
/// * Dropping the pool shuts the queue down and joins the workers.
pub struct WorkerPool {
    n_threads: usize,
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("n_threads", &self.n_threads).finish()
    }
}

impl WorkerPool {
    /// Pool of `n_threads` execution lanes (the caller counts as one;
    /// `n_threads - 1` OS workers are spawned on first use). `0` means
    /// "all available cores", matching `GptvqConfig::n_threads` and the
    /// CLI `--threads` convention.
    pub fn new(n_threads: usize) -> WorkerPool {
        WorkerPool {
            n_threads: effective_threads(n_threads),
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                shutdown: AtomicBool::new(false),
            }),
            workers: Mutex::new(Vec::new()),
        }
    }

    /// The shared width-1 pool: always runs inline on the caller, never
    /// touches the queue, spawns no threads. Used by the single-threaded
    /// entry points (`matmul`, `recon_loss`, …) so they pay no per-call
    /// pool construction.
    pub fn inline() -> &'static WorkerPool {
        static INLINE: OnceLock<WorkerPool> = OnceLock::new();
        INLINE.get_or_init(|| WorkerPool::new(1))
    }

    /// Execution lanes of this pool (callers + workers).
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Grain-gated lane count for a stage of `work` scalar ops: below
    /// the active grain (`GPTVQ_PAR_GRAIN` override included) the stage
    /// should run inline; at or above it, use the full pool. Depends
    /// only on the workload shape, never on timing, so schedules stay
    /// reproducible — the exact contract `util::par::threads_for` had
    /// for the scoped helpers.
    pub fn threads_for(&self, work: usize) -> usize {
        if work < par_grain() {
            1
        } else {
            self.n_threads
        }
    }

    /// Run `f(0), f(1), …, f(nr-1)` concurrently, where
    /// `nr = n_runners.min(self.n_threads()).max(1)`, and return when
    /// all calls have completed. Each index is invoked exactly once;
    /// `nr == 1` runs inline without touching the queue. Panics in any
    /// runner are propagated to the caller after the batch completes.
    pub fn run<F>(&self, n_runners: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let nr = n_runners.min(self.n_threads).max(1);
        if nr == 1 {
            f(0);
            return;
        }
        self.scope(|s| {
            let fr = &f;
            for i in 1..nr {
                s.spawn(move || fr(i));
            }
            fr(0);
        });
    }

    /// Structured-concurrency entry: spawn borrowed jobs on the pool
    /// and block until **all** of them have completed before returning
    /// — also on panic, so jobs can safely borrow the caller's stack
    /// (the guarantee `std::thread::scope` gives, minus the per-call
    /// thread spawn). Job panics are re-raised on the caller after the
    /// batch drains. While waiting, the caller helps by executing
    /// queued jobs, so nested scopes cannot deadlock.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope PoolScope<'scope, 'env>) -> R,
    {
        let scope = PoolScope {
            pool: self,
            latch: Arc::new(Latch::new()),
            scope_marker: PhantomData,
            env_marker: PhantomData,
        };

        // if `f` itself unwinds, outstanding jobs still borrow frames
        // below us — wait for them before the unwind continues
        struct Guard<'a> {
            pool: &'a WorkerPool,
            latch: Arc<Latch>,
            armed: bool,
        }
        impl Drop for Guard<'_> {
            fn drop(&mut self) {
                if self.armed {
                    self.pool.wait_latch(&self.latch);
                }
            }
        }
        let mut guard = Guard { pool: self, latch: scope.latch.clone(), armed: true };
        let r = f(&scope);
        guard.armed = false;
        drop(guard);

        self.wait_latch(&scope.latch);
        let panicked = scope.latch.panic.lock().unwrap().take();
        if let Some(p) = panicked {
            resume_unwind(p);
        }
        r
    }

    /// Block until `latch` reaches zero, executing queued jobs (of any
    /// batch) while waiting instead of parking unconditionally.
    fn wait_latch(&self, latch: &Latch) {
        if latch.remaining.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if latch.remaining.load(Ordering::Acquire) == 0 {
                return;
            }
            if let Some(job) = q.pop_front() {
                drop(q);
                job();
                q = self.shared.queue.lock().unwrap();
            } else {
                q = self.shared.cv.wait(q).unwrap();
            }
        }
    }

    /// Enqueue a type-erased job and wake a lane for it, spawning the
    /// worker threads on the first real fan-out.
    fn push_job(&self, job: Job) {
        if self.n_threads > 1 {
            self.ensure_workers();
        }
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(job);
        drop(q);
        self.shared.cv.notify_all();
    }

    fn ensure_workers(&self) {
        let mut ws = self.workers.lock().unwrap();
        if !ws.is_empty() {
            return;
        }
        for _ in 1..self.n_threads {
            let shared = self.shared.clone();
            ws.push(spawn_worker("gptvq-pool", move || worker_loop(shared)));
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // every scope has drained its own jobs before returning, so the
        // queue is empty of live work here; workers just need the signal
        self.shared.shutdown.store(true, Ordering::Release);
        drop(self.shared.queue.lock().unwrap());
        self.shared.cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(job) = q.pop_front() {
            drop(q);
            job(); // job wrappers catch panics; the worker survives
            q = shared.queue.lock().unwrap();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        q = shared.cv.wait(q).unwrap();
    }
}

/// Spawn handle of one [`WorkerPool::scope`] invocation. Jobs spawned
/// here may borrow anything that outlives the scope (`'env`); the scope
/// does not return until they have all run. The lifetime structure
/// (invariant `'scope`/`'env` markers, `'env: 'scope`) mirrors
/// `std::thread::Scope`, which this type is the pooled analog of.
pub struct PoolScope<'scope, 'env: 'scope> {
    pool: &'scope WorkerPool,
    latch: Arc<Latch>,
    scope_marker: PhantomData<&'scope mut &'scope ()>,
    env_marker: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> PoolScope<'scope, 'env> {
    /// Spawn one job onto the pool. The first panicking job of the
    /// batch has its payload re-raised by `scope` after all jobs drain.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.latch.remaining.fetch_add(1, Ordering::SeqCst);
        let latch = self.latch.clone();
        let shared = self.pool.shared.clone();
        let wrapper = move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            if let Err(p) = result {
                let mut slot = latch.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            latch.remaining.fetch_sub(1, Ordering::Release);
            // lock/unlock pairs the decrement with any in-flight
            // cv.wait so the completion signal cannot be missed
            drop(shared.queue.lock().unwrap());
            shared.cv.notify_all();
        };
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(wrapper);
        // SAFETY: `scope` (and its unwind guard) blocks until
        // `latch.remaining` returns to zero, i.e. until this job has
        // finished running — so the job never outlives `'env` even
        // though the queue stores it as `'static`.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        self.pool.push_job(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_invokes_each_index_exactly_once() {
        for nt in [1, 2, 4, 8] {
            let pool = WorkerPool::new(nt);
            let hits: Vec<AtomicUsize> = (0..nt).map(|_| AtomicUsize::new(0)).collect();
            pool.run(nt, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "{nt} lanes, index {i}");
            }
        }
    }

    #[test]
    fn run_caps_at_pool_width_and_runs_inline_when_single() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run(16, |i| {
            assert!(i < 2);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        let inline = WorkerPool::inline();
        let count = AtomicUsize::new(0);
        inline.run(8, |i| {
            assert_eq!(i, 0);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_jobs_borrow_caller_state() {
        let pool = WorkerPool::new(4);
        let data = vec![1usize, 2, 3, 4, 5];
        let total = AtomicUsize::new(0);
        pool.scope(|s| {
            for &v in &data {
                let total = &total;
                s.spawn(move || {
                    total.fetch_add(v, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn pool_is_reusable_across_many_batches() {
        // the point of persistence: hundreds of dispatches on one pool
        let pool = WorkerPool::new(4);
        let mut acc = 0usize;
        for round in 0..200 {
            let partial = AtomicUsize::new(0);
            pool.run(4, |i| {
                partial.fetch_add(round * 4 + i, Ordering::SeqCst);
            });
            acc += partial.load(Ordering::SeqCst);
        }
        let want: usize = (0..800).sum();
        assert_eq!(acc, want);
    }

    #[test]
    fn nested_run_inside_jobs_makes_progress() {
        // inner fan-outs from pool lanes must not deadlock: waiting
        // lanes help-execute queued jobs
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        pool.run(4, |_outer| {
            pool.run(4, |_inner| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_overlaps_spawned_batch_with_caller_run() {
        // the span-pipelining shape: a spawned batch drains while the
        // caller runs its own fan-out on the same pool
        let pool = WorkerPool::new(4);
        let em = AtomicUsize::new(0);
        let flush = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..8 {
                let em = &em;
                s.spawn(move || {
                    em.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.run(4, |_| {
                flush.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(em.load(Ordering::SeqCst), 8);
        assert_eq!(flush.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, |i| {
                if i == 2 {
                    panic!("boom in lane 2");
                }
            });
        }));
        assert!(caught.is_err(), "runner panic must reach the caller");
        // the pool must still be fully operational afterwards
        let ok = AtomicUsize::new(0);
        pool.run(4, |_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn threads_for_gates_on_the_active_grain() {
        let pool = WorkerPool::new(8);
        let grain = par_grain();
        assert_eq!(pool.threads_for(grain), 8);
        if grain > 0 {
            assert_eq!(pool.threads_for(grain - 1), 1);
        }
    }

    #[test]
    fn zero_resolves_to_all_cores() {
        assert!(WorkerPool::new(0).n_threads() >= 1);
        assert_eq!(WorkerPool::new(3).n_threads(), 3);
    }
}
