//! Wall-clock timing helpers used by the coordinator metrics and the
//! bench harness (no criterion offline).

use std::time::Instant;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// Measurement statistics over repeated runs (median is the headline
/// number, matching what criterion would report).
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let median_s = if n % 2 == 1 {
            samples[n / 2]
        } else {
            0.5 * (samples[n / 2 - 1] + samples[n / 2])
        };
        Stats {
            n,
            median_s,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            min_s: samples[0],
            max_s: samples[n - 1],
        }
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `iters` measured.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_odd_even() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median_s, 2.0);
        let s = Stats::from_samples(vec![4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median_s, 2.5);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 4.0);
    }

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0;
        let stats = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(stats.n, 5);
        assert!(stats.min_s <= stats.median_s && stats.median_s <= stats.max_s);
    }
}
