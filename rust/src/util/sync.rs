//! Swappable synchronization primitives for the concurrency core.
//!
//! `util::pool` — the one module in the crate with an `unsafe` block and
//! a blocking wait protocol — imports its primitives from here instead
//! of `std::sync`. A normal build re-exports the std types unchanged
//! (zero cost, zero behavior change). Compiling with
//! `RUSTFLAGS="--cfg loom"` swaps in [loom]'s model-checked versions so
//! `tests/loom_pool.rs` can exhaustively enumerate thread interleavings
//! of the latch / help-while-waiting / condvar protocol instead of
//! sampling them the way the parity tests do.
//!
//! The `loom` crate itself is **not** an offline dependency: the default
//! build never references it (everything `cfg(loom)` is compiled out),
//! and CI's loom job does `cargo add --dev loom` before setting the cfg.
//! This keeps the crate's zero-registry-dependency offline build intact
//! (see the note at the top of `Cargo.toml`).
//!
//! [loom]: https://docs.rs/loom

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
pub use loom::thread::JoinHandle;

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
pub use std::thread::JoinHandle;

/// Spawn one pool worker thread. The std path names the thread (visible
/// in debuggers and sanitizer reports); loom's `thread::spawn` takes no
/// name, so under model checking the name is advisory-only and dropped.
pub fn spawn_worker<F>(name: &str, f: F) -> JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    #[cfg(loom)]
    {
        let _ = name;
        loom::thread::spawn(f)
    }
    #[cfg(not(loom))]
    {
        std::thread::Builder::new()
            .name(name.into())
            .spawn(f)
            .expect("spawn pool worker")
    }
}
