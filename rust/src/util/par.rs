//! Deterministic data-parallel helpers: pool-backed by default, with the
//! historical `std::thread::scope` fork-join variants kept alongside.
//!
//! Every helper partitions work into index-addressed items (or disjoint
//! row bands) whose results land at fixed positions, so the outcome is
//! bitwise identical for any thread count — including 1, which runs
//! inline without spawning. This is what lets the quantization engine
//! guarantee `--threads N` never changes a single quantized weight.
//!
//! Since PR 4 the primary [`parallel_map`]/[`parallel_row_bands`]
//! execute on a borrowed [`WorkerPool`](crate::util::WorkerPool):
//! dispatching a stage reuses the pool's long-lived workers instead of
//! paying a spawn/join per stage. The `*_scoped` variants are the PR 2
//! fork-join implementations, retained as the parity reference and as
//! the baseline the throughput bench measures spawn overhead against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::pool::WorkerPool;

/// Below this many scalar ops, dispatch overhead dominates any speedup,
/// so `threads_for` stays inline. Calibrated for the sweep's per-step
/// stages: a d-column assignment or block-tail propagation on a ≲1k-row
/// layer runs inline; span flushes, EM E-steps and the update matmuls
/// fan out.
pub const PAR_GRAIN: usize = 256 * 1024;

/// The active grain: `PAR_GRAIN` unless overridden by `GPTVQ_PAR_GRAIN`
/// (read once per process). CI's threaded test pass sets it to 1 so every
/// gated stage genuinely fans out even on test-sized inputs — the grain
/// only moves the inline/parallel cutover, never the result.
pub fn par_grain() -> usize {
    static GRAIN: OnceLock<usize> = OnceLock::new();
    *GRAIN.get_or_init(|| {
        std::env::var("GPTVQ_PAR_GRAIN").ok().and_then(|v| v.parse().ok()).unwrap_or(PAR_GRAIN)
    })
}

/// Resolve a configured thread count: 0 means "all available cores".
pub fn effective_threads(n: usize) -> usize {
    if n == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        n
    }
}

/// Threads to actually use for a task of `work` scalar ops: stay inline
/// below the grain so tiny steps (e.g. one d-column assignment on a small
/// layer) never pay dispatch cost. Depends only on the workload shape,
/// never on timing, so the schedule — and the result — is reproducible.
/// The pool-aware equivalent is [`WorkerPool::threads_for`].
pub fn threads_for(n_threads: usize, work: usize) -> usize {
    if work < par_grain() {
        1
    } else {
        effective_threads(n_threads)
    }
}

/// Thread count for the test suite: CI sets `GPTVQ_TEST_THREADS=4` to run
/// every pipeline/engine test through the parallel paths; defaults to 1.
pub fn test_threads() -> usize {
    std::env::var("GPTVQ_TEST_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Map `f` over `0..n_items` on up to `n_runners` pool lanes, returning
/// the results in item order. Items are claimed from a shared counter,
/// so scheduling is dynamic, but each result lands in its own slot — the
/// output is identical for any pool width and runner count (`1` runs
/// inline on the caller without touching the pool queue).
pub fn parallel_map<R, F>(pool: &WorkerPool, n_runners: usize, n_items: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let nr = n_runners.min(pool.n_threads()).min(n_items.max(1));
    if nr <= 1 || n_items <= 1 {
        return (0..n_items).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n_items);
    slots.resize_with(n_items, || None);
    let slots = Mutex::new(slots);
    let next = AtomicUsize::new(0);
    pool.run(nr, |_runner| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n_items {
            break;
        }
        let r = f(i);
        slots.lock().unwrap()[i] = Some(r);
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.expect("every item index is claimed exactly once"))
        .collect()
}

/// Split a row-major buffer of `rows` × `cols` into contiguous row bands
/// and run `f(first_row, band)` on each band concurrently on the pool.
/// Bands are disjoint, so any per-row computation is bitwise identical
/// for every pool width; `f` must not make one row's result depend on
/// another's. Generic over the element type so both the f64 and f32
/// compute paths share one banding scheme (and one determinism
/// argument).
pub fn parallel_row_bands<T, F>(
    pool: &WorkerPool,
    data: &mut [T],
    rows: usize,
    cols: usize,
    n_runners: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert_eq!(data.len(), rows * cols);
    let nr = n_runners.min(pool.n_threads()).min(rows.max(1));
    if nr <= 1 || rows == 0 || cols == 0 {
        f(0, data);
        return;
    }
    let band = rows.div_ceil(nr);
    // hand each runner index its own disjoint band through a cell; the
    // per-band lock is uncontended (exactly one runner touches it)
    let chunks: Vec<Mutex<(usize, &mut [T])>> = data
        .chunks_mut(band * cols)
        .enumerate()
        .map(|(idx, chunk)| Mutex::new((idx * band, chunk)))
        .collect();
    pool.run(chunks.len(), |i| {
        let mut cell = chunks[i].lock().unwrap();
        let (row0, chunk) = &mut *cell;
        f(*row0, chunk);
    });
}

/// The PR 2 fork-join `parallel_map`: spawns a fresh `std::thread::scope`
/// per call. Kept as the parity reference for the pool-backed version
/// and as the spawn-overhead baseline in `benches/quantize_throughput`.
pub fn parallel_map_scoped<R, F>(n_threads: usize, n_items: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let n_threads = effective_threads(n_threads).min(n_items.max(1));
    if n_threads <= 1 || n_items <= 1 {
        return (0..n_items).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n_items);
    slots.resize_with(n_items, || None);
    let slots = Mutex::new(slots);
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.expect("every item index is claimed exactly once"))
        .collect()
}

/// The PR 2 fork-join `parallel_row_bands` (fresh scope per call); see
/// [`parallel_map_scoped`] for why it is retained.
pub fn parallel_row_bands_scoped<T, F>(
    data: &mut [T],
    rows: usize,
    cols: usize,
    n_threads: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert_eq!(data.len(), rows * cols);
    let n_threads = effective_threads(n_threads).min(rows.max(1));
    if n_threads <= 1 || rows == 0 || cols == 0 {
        f(0, data);
        return;
    }
    let band = rows.div_ceil(n_threads);
    std::thread::scope(|scope| {
        for (idx, chunk) in data.chunks_mut(band * cols).enumerate() {
            let f = &f;
            scope.spawn(move || f(idx * band, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn threads_for_stays_inline_below_grain() {
        // written against the active grain so the test also holds under a
        // GPTVQ_PAR_GRAIN override (CI's threaded pass sets it to 1)
        let grain = par_grain();
        assert_eq!(threads_for(8, grain), 8);
        if grain > 0 {
            assert_eq!(threads_for(8, grain - 1), 1);
        }
    }

    #[test]
    fn parallel_map_preserves_item_order() {
        for nt in [1, 2, 4, 7] {
            let pool = WorkerPool::new(nt);
            let got = parallel_map(&pool, nt, 100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "{nt} lanes");
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let pool = WorkerPool::new(4);
        let empty: Vec<usize> = parallel_map(&pool, 4, 0, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(&pool, 4, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn pool_map_matches_scoped_map() {
        // satellite parity: the pool-backed helper must reproduce the
        // fork-join reference exactly, at every width
        for nt in [1, 2, 4, 8] {
            let pool = WorkerPool::new(nt);
            let got = parallel_map(&pool, nt, 57, |i| (i * 31 + 7) % 13);
            let want = parallel_map_scoped(nt, 57, |i| (i * 31 + 7) % 13);
            assert_eq!(got, want, "{nt} lanes");
        }
    }

    #[test]
    fn row_bands_cover_all_rows_disjointly() {
        for nt in [1, 2, 3, 4, 9] {
            let pool = WorkerPool::new(nt);
            let (rows, cols) = (7, 5);
            let mut data = vec![0.0; rows * cols];
            parallel_row_bands(&pool, &mut data, rows, cols, nt, |row0, band| {
                let band_rows = band.len() / cols;
                for i in 0..band_rows {
                    for c in 0..cols {
                        band[i * cols + c] += (row0 + i) as f64;
                    }
                }
            });
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(data[r * cols + c], r as f64, "{nt} lanes ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn pool_row_bands_match_scoped_row_bands() {
        // satellite parity: identical banding results, pool vs scope
        let fill = |data: &mut [f64], rows: usize, cols: usize, scoped: bool, nt: usize| {
            let op = |row0: usize, band: &mut [f64]| {
                let band_rows = band.len() / cols;
                for i in 0..band_rows {
                    for c in 0..cols {
                        band[i * cols + c] = ((row0 + i) * cols + c) as f64 * 0.5;
                    }
                }
            };
            if scoped {
                parallel_row_bands_scoped(data, rows, cols, nt, op);
            } else {
                let pool = WorkerPool::new(nt);
                parallel_row_bands(&pool, data, rows, cols, nt, op);
            }
        };
        let (rows, cols) = (23, 11);
        for nt in [1, 2, 4, 8] {
            let mut a = vec![0.0; rows * cols];
            let mut b = vec![0.0; rows * cols];
            fill(&mut a, rows, cols, false, nt);
            fill(&mut b, rows, cols, true, nt);
            assert_eq!(a, b, "{nt} lanes");
        }
    }

    #[test]
    fn row_bands_handle_degenerate_shapes() {
        let pool = WorkerPool::new(4);
        let mut empty: Vec<f64> = Vec::new();
        parallel_row_bands(&pool, &mut empty, 0, 4, 4, |_, band| assert!(band.is_empty()));
        let mut one = vec![1.0, 2.0];
        parallel_row_bands(&pool, &mut one, 1, 2, 4, |row0, band| {
            assert_eq!(row0, 0);
            for v in band.iter_mut() {
                *v *= 2.0;
            }
        });
        assert_eq!(one, vec![2.0, 4.0]);
    }

    #[test]
    fn runner_cap_never_changes_map_results() {
        let pool = WorkerPool::new(8);
        let reference: Vec<usize> = (0..40).map(|i| i * 3).collect();
        for cap in [1, 2, 3, 8, 100] {
            assert_eq!(parallel_map(&pool, cap, 40, |i| i * 3), reference, "cap {cap}");
        }
    }
}
