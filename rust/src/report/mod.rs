//! Table rendering for the bench harness and CLI: fixed-width aligned
//! columns matching the layout of the paper's tables, plus file capture
//! for EXPERIMENTS.md.

use std::fmt::Write as _;

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let mut line = String::new();
        for c in 0..ncols {
            let _ = write!(line, "{:<w$}  ", self.headers[c], w = widths[c]);
        }
        let _ = writeln!(out, "{}", line.trim_end());
        let _ = writeln!(out, "{}", "-".repeat(line.trim_end().len()));
        for row in &self.rows {
            let mut line = String::new();
            for c in 0..ncols {
                let _ = write!(line, "{:<w$}  ", row[c], w = widths[c]);
            }
            let _ = writeln!(out, "{}", line.trim_end());
        }
        out
    }

    /// Print to stdout and append to `reports/<name>.txt` when the
    /// GPTVQ_REPORT_DIR env var is set (used by `cargo bench`).
    pub fn emit(&self, name: &str) {
        let rendered = self.render();
        println!("{rendered}");
        if let Ok(dir) = std::env::var("GPTVQ_REPORT_DIR") {
            let _ = std::fs::create_dir_all(&dir);
            let path = std::path::Path::new(&dir).join(format!("{name}.txt"));
            let _ = std::fs::write(path, &rendered);
        }
    }
}

pub mod experiments;

/// Format a float with sensible precision for tables.
pub fn fmt_f(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a >= 1000.0 {
        format!("{v:.3e}")
    } else if a >= 10.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ppl"]);
        t.row(&["RTN".into(), "12.5".into()]);
        t.row(&["GPTVQ 2D (ours)".into(), "8.2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("GPTVQ 2D (ours)"));
        // header padded to the widest cell
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].starts_with("method"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(fmt_f(12345.0), "1.234e4");
        assert_eq!(fmt_f(42.123), "42.12");
        assert_eq!(fmt_f(3.14159), "3.142");
    }
}
