//! Shared experiment drivers used by the bench harness and the examples:
//! load-once model/corpus state, quantize-with-method, evaluate — the
//! plumbing every table in EXPERIMENTS.md goes through.

use std::path::{Path, PathBuf};

use crate::coordinator::{quantize_model, Method, PipelineConfig};
use crate::data::tokens::{read_tokens, TokenStream};
use crate::error::{Error, Result};
use crate::eval::{evaluate_task, load_task, perplexity};
use crate::model::Model;

/// Locate the artifacts directory (env override for CI layouts).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("GPTVQ_ARTIFACTS") {
        return PathBuf::from(d);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when the build-time artifacts exist (benches skip politely when
/// `make artifacts` has not run).
pub fn artifacts_available(preset: &str) -> bool {
    let d = artifacts_dir();
    d.join(format!("model_{preset}.ckpt")).exists() && d.join("corpus_valid.bin").exists()
}

/// Shared experiment state: FP model + corpora, loaded once per bench.
pub struct ExpContext {
    pub preset: String,
    pub model: Model,
    pub train: TokenStream,
    pub valid: TokenStream,
    pub eval_seqs: usize,
    pub calib_seqs: usize,
}

impl ExpContext {
    pub fn load(preset: &str) -> Result<ExpContext> {
        let dir = artifacts_dir();
        if !artifacts_available(preset) {
            return Err(Error::msg(format!(
                "artifacts for preset '{preset}' not built — run `make artifacts`"
            )));
        }
        let model = Model::load(&dir, preset)?;
        let train = read_tokens(dir.join("corpus_train.bin"))?;
        let valid = read_tokens(dir.join("corpus_valid.bin"))?;
        // fast mode trades metric resolution for wall-clock (CI use)
        let fast = std::env::var("GPTVQ_BENCH_FAST").is_ok();
        Ok(ExpContext {
            preset: preset.to_string(),
            model,
            train,
            valid,
            eval_seqs: if fast { 6 } else { 16 },
            calib_seqs: if fast { 8 } else { 32 },
        })
    }

    /// FP baseline perplexity.
    pub fn fp_perplexity(&self) -> f64 {
        perplexity(&self.model, &self.valid, self.eval_seqs, self.model.cfg.max_seq).ppl
    }

    /// Quantize a fresh copy of the model with `method`; returns
    /// (validation ppl, mean effective bpv, quantize-stage seconds).
    pub fn run_method(&self, method: Method) -> Result<QuantRun> {
        let mut model = self.model.clone();
        let mut cfg = PipelineConfig::new(method);
        cfg.calib_sequences = self.calib_seqs;
        cfg.calib_seq_len = self.model.cfg.max_seq;
        let report = quantize_model(&mut model, &self.train, &cfg)?;
        let ppl = perplexity(&model, &self.valid, self.eval_seqs, self.model.cfg.max_seq).ppl;
        Ok(QuantRun {
            method: report.method.clone(),
            ppl,
            bpv: report.mean_effective_bpv(),
            quantize_seconds: report.metrics.seconds("quantize"),
            total_weights: report.total_weights,
            model,
            vq_model: report.vq_model,
        })
    }

    /// Zero-shot probe accuracies for a model: (task name, accuracy).
    pub fn zero_shot(&self, model: &Model, max_items: usize) -> Vec<(String, f64)> {
        let dir = artifacts_dir();
        let mut out = Vec::new();
        for name in ["cloze", "pair", "induction"] {
            let path = dir.join(format!("task_{name}.bin"));
            if path.exists() {
                if let Ok(task) = load_task(&path) {
                    out.push((name.to_string(), evaluate_task(model, &task, max_items)));
                }
            }
        }
        out
    }
}

/// One quantization run's outcome.
pub struct QuantRun {
    pub method: String,
    pub ppl: f64,
    pub bpv: f64,
    pub quantize_seconds: f64,
    pub total_weights: usize,
    pub model: Model,
    pub vq_model: Option<crate::vqformat::VqModel>,
}

/// Standard GPTVQ configs for the paper's bpv settings on this testbed.
/// `overhead` is the non-index budget: 0.125 (g128-equivalent) or 0.25
/// (g64-equivalent).
pub fn paper_gptvq(d: usize, bits: u32, overhead: f64) -> crate::quant::gptvq::GptvqConfig {
    let mut cfg = crate::quant::gptvq::GptvqConfig::for_setting(d, bits, overhead);
    if std::env::var("GPTVQ_BENCH_FAST").is_ok() {
        cfg.em_iters = 25;
        cfg.update_iters = 10;
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_is_stable() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn context_loads_and_runs_fast_method_if_artifacts() {
        if !artifacts_available("tiny") {
            eprintln!("skipping: artifacts not built");
            return;
        }
        std::env::set_var("GPTVQ_BENCH_FAST", "1");
        let ctx = ExpContext::load("tiny").unwrap();
        let fp = ctx.fp_perplexity();
        assert!(fp > 1.0 && fp < 100.0, "fp ppl {fp}");
        let run = ctx.run_method(Method::Rtn { bits: 4, group_size: 64 }).unwrap();
        assert!(run.ppl.is_finite());
        assert!(run.ppl < fp * 3.0, "4-bit RTN should not explode: {} vs {}", run.ppl, fp);
        std::env::remove_var("GPTVQ_BENCH_FAST");
    }
}
