//! GVQTOKS1 token-stream reader (mirror of `python/compile/corpus.py`)
//! and deterministic sequence sampling.
//!
//! The paper calibrates on 128 sequences of 2048 tokens from WikiText2;
//! our substitute samples `n` sequences of `seq_len` byte tokens from the
//! synthetic corpus with an explicit seed, so calibration sets are
//! identical across runs and methods.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::Rng;

const MAGIC: &[u8; 8] = b"GVQTOKS1";

/// A byte-token corpus.
#[derive(Debug, Clone)]
pub struct TokenStream {
    pub tokens: Vec<u8>,
}

impl TokenStream {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Read a GVQTOKS1 file.
pub fn read_tokens(path: impl AsRef<Path>) -> Result<TokenStream> {
    let path_str = path.as_ref().display().to_string();
    let bytes = std::fs::read(path.as_ref())?;
    if bytes.len() < 16 || &bytes[..8] != MAGIC {
        return Err(Error::format(&path_str, "bad GVQTOKS1 header"));
    }
    let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    if bytes.len() < 16 + n {
        return Err(Error::format(&path_str, format!("truncated: want {n} tokens")));
    }
    Ok(TokenStream { tokens: bytes[16..16 + n].to_vec() })
}

/// Sample `n` random sequences of `seq_len` tokens (deterministic in
/// `seed`). Starts are uniform over valid positions.
pub fn sample_sequences(stream: &TokenStream, n: usize, seq_len: usize, seed: u64) -> Vec<Vec<u8>> {
    assert!(stream.len() > seq_len, "corpus shorter than sequence length");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let start = rng.below(stream.len() - seq_len);
            stream.tokens[start..start + seq_len].to_vec()
        })
        .collect()
}

/// Deterministic, evenly spaced evaluation slices covering the stream —
/// used for perplexity so the metric is not sampling-noisy.
pub fn eval_sequences(stream: &TokenStream, n: usize, seq_len: usize) -> Vec<Vec<u8>> {
    assert!(stream.len() >= seq_len);
    let max_start = stream.len() - seq_len;
    (0..n)
        .map(|i| {
            let start = if n == 1 { 0 } else { i * max_start / (n - 1) };
            stream.tokens[start..start + seq_len].to_vec()
        })
        .collect()
}

/// Synthetic token stream for tests: Markov-ish bytes with skewed
/// distribution (not the python corpus — just structurally similar).
pub fn synthetic_stream(n: usize, seed: u64) -> TokenStream {
    let mut rng = Rng::new(seed);
    let mut tokens = Vec::with_capacity(n);
    let mut prev = 32u8;
    for _ in 0..n {
        let t = if rng.uniform() < 0.7 {
            // locally correlated
            prev.wrapping_add((rng.below(5)) as u8)
        } else {
            (97 + rng.below(26)) as u8 // a-z
        };
        tokens.push(t);
        prev = t;
    }
    TokenStream { tokens }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic() {
        let s = synthetic_stream(10_000, 1);
        let a = sample_sequences(&s, 8, 64, 42);
        let b = sample_sequences(&s, 8, 64, 42);
        assert_eq!(a, b);
        let c = sample_sequences(&s, 8, 64, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn sequences_have_requested_shape() {
        let s = synthetic_stream(5_000, 2);
        let seqs = sample_sequences(&s, 5, 128, 0);
        assert_eq!(seqs.len(), 5);
        assert!(seqs.iter().all(|q| q.len() == 128));
    }

    #[test]
    fn eval_sequences_cover_start_and_end() {
        let s = synthetic_stream(1_000, 3);
        let seqs = eval_sequences(&s, 4, 100);
        assert_eq!(seqs[0], s.tokens[0..100].to_vec());
        assert_eq!(seqs[3], s.tokens[900..1000].to_vec());
    }

    #[test]
    fn read_rejects_garbage() {
        let p = std::env::temp_dir().join(format!("gvq_tok_bad_{}", std::process::id()));
        std::fs::write(&p, b"NOTTOKENS").unwrap();
        assert!(read_tokens(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn reads_artifact_corpus_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/corpus_valid.bin");
        if !p.exists() {
            eprintln!("skipping: corpus not built");
            return;
        }
        let s = read_tokens(&p).unwrap();
        assert!(s.len() >= 100_000);
        // byte tokens, printable-ish english text dominates
        let spaces = s.tokens.iter().filter(|&&t| t == b' ').count();
        assert!(spaces > s.len() / 20);
    }
}
