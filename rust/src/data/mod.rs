//! Data plumbing: token streams (GVQTOKS1), deterministic batch sampling
//! for calibration and evaluation, and a native synthetic-token generator
//! for tests that must not depend on built artifacts.

pub mod tokens;

pub use tokens::{read_tokens, sample_sequences, TokenStream};
