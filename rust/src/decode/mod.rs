//! VQ decompression kernels (paper §4.2, Table 3).
//!
//! The paper decodes VQ weights on an Arm CPU with the TBL instruction —
//! an in-register lookup table translating packed indices to values. The
//! scalar-ISA analog here: packed index bitstreams + LUT decode with an
//! unrolled inner loop the compiler can keep in registers. The comparison
//! set matches Table 3:
//!
//!   INT4 — 4-bit uniform codes, per-group scale/zero dequant
//!   INT8 — 8-bit codes, scale dequant
//!   VQ   — d-dim codebook, `d*b`-bit packed indices, one LUT per dim
//!
//! The latency model is bytes-moved plus decode work; the bench harness
//! (`benches/table3_decode.rs`) measures decoded weights/second and
//! reports footprint and relative latency exactly like the paper's table.

pub mod int_baseline;
pub mod pack;

use crate::quant::vq::Codebook;

pub use int_baseline::{dequant_int4, dequant_int8, pack_int4};
pub use pack::PackedIndices;

/// Decode a packed VQ index stream through a codebook LUT into `out`
/// (length = n_indices * d). `lut` is the f32 codebook, row-major [k, d].
///
/// Fast paths for the Table 3 settings (4- and 5-bit indices, d = 1/2)
/// unroll 8 indices per iteration; the generic path handles everything.
pub fn decode_vq_f32(packed: &PackedIndices, lut: &[f32], d: usize, out: &mut [f32]) {
    let n = packed.len();
    assert_eq!(out.len(), n * d, "output buffer size");
    match (packed.bits, d) {
        (4, 1) => decode_4bit_d1(packed, lut, out),
        (4, 2) => decode_4bit_d2(packed, lut, out),
        _ => decode_generic(packed, lut, d, out),
    }
}

/// Generic bit-unpack + gather with a streaming u64 bit buffer (§Perf:
/// avoids the per-index multi-byte reassembly of `PackedIndices::get`).
fn decode_generic(packed: &PackedIndices, lut: &[f32], d: usize, out: &mut [f32]) {
    let bits = packed.bits as usize;
    let mask = (1u64 << bits) - 1;
    let data = &packed.data;
    let mut buf: u64 = 0;
    let mut have: usize = 0;
    let mut byte_pos: usize = 0;
    for i in 0..packed.len() {
        while have < bits {
            buf |= (data[byte_pos] as u64) << have;
            byte_pos += 1;
            have += 8;
        }
        let idx = (buf & mask) as usize;
        buf >>= bits;
        have -= bits;
        match d {
            1 => out[i] = lut[idx],
            2 => {
                out[i * 2] = lut[idx * 2];
                out[i * 2 + 1] = lut[idx * 2 + 1];
            }
            _ => {
                let base = idx * d;
                out[i * d..(i + 1) * d].copy_from_slice(&lut[base..base + d]);
            }
        }
    }
}

/// 4-bit indices, scalar codebook: two lookups per byte (TBL analog).
fn decode_4bit_d1(packed: &PackedIndices, lut: &[f32], out: &mut [f32]) {
    let n = packed.len();
    let data = &packed.data;
    let full = n / 2;
    for b in 0..full {
        let byte = data[b];
        out[b * 2] = lut[(byte & 0x0F) as usize];
        out[b * 2 + 1] = lut[(byte >> 4) as usize];
    }
    if n % 2 == 1 {
        out[n - 1] = lut[(data[full] & 0x0F) as usize];
    }
}

/// 4-bit indices, 2-dim codebook: each index expands to 2 values — the
/// paper's "2D VQ with 2 bits per index translates to 2 LUTs" layout.
fn decode_4bit_d2(packed: &PackedIndices, lut: &[f32], out: &mut [f32]) {
    let n = packed.len();
    let data = &packed.data;
    let full = n / 2;
    for b in 0..full {
        let byte = data[b];
        let lo = (byte & 0x0F) as usize * 2;
        let hi = (byte >> 4) as usize * 2;
        out[b * 4] = lut[lo];
        out[b * 4 + 1] = lut[lo + 1];
        out[b * 4 + 2] = lut[hi];
        out[b * 4 + 3] = lut[hi + 1];
    }
    if n % 2 == 1 {
        let lo = (data[full] & 0x0F) as usize * 2;
        out[(n - 1) * 2] = lut[lo];
        out[(n - 1) * 2 + 1] = lut[lo + 1];
    }
}

/// Convenience: build an f32 LUT from a Codebook.
pub fn lut_from_codebook(cb: &Codebook) -> Vec<f32> {
    // detlint: allow(precision-cast, the serving LUT is f32 by container format design)
    cb.centroids.iter().map(|&v| v as f32).collect()
}

/// Bytes moved per weight for a VQ setting (index bits + amortized
/// codebook) — the footprint column of Table 3.
pub fn vq_bytes_per_weight(d: usize, bits_per_index: u32, k: usize, group_size: usize) -> f64 {
    let index_bits = bits_per_index as f64 / d as f64;
    let codebook_bits = (k * d * 8) as f64 / group_size as f64; // int8 codebook
    (index_bits + codebook_bits) / 8.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Rng;

    fn random_packed(rng: &mut Rng, n: usize, bits: u32) -> (PackedIndices, Vec<u16>) {
        let k = 1usize << bits;
        let idx: Vec<u16> = (0..n).map(|_| rng.below(k) as u16).collect();
        (PackedIndices::pack(&idx, bits), idx)
    }

    #[test]
    fn decode_matches_reference_over_settings() {
        check("decode == gather(unpack)", 20, |rng| {
            let bits = [2u32, 3, 4, 5, 8][rng.below(5)];
            let d = [1usize, 2, 4][rng.below(3)];
            let n = 1 + rng.below(500);
            let k = 1usize << bits;
            let (packed, idx) = random_packed(rng, n, bits);
            let lut: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
            let mut out = vec![0f32; n * d];
            decode_vq_f32(&packed, &lut, d, &mut out);
            for i in 0..n {
                for t in 0..d {
                    let want = lut[idx[i] as usize * d + t];
                    if out[i * d + t] != want {
                        return Err(format!("mismatch at ({i},{t})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fast_paths_match_generic() {
        let mut rng = Rng::new(7);
        for d in [1usize, 2] {
            let (packed, _) = random_packed(&mut rng, 1001, 4);
            let lut: Vec<f32> = (0..16 * d).map(|_| rng.gaussian() as f32).collect();
            let mut fast = vec![0f32; 1001 * d];
            decode_vq_f32(&packed, &lut, d, &mut fast);
            let mut slow = vec![0f32; 1001 * d];
            decode_generic(&packed, &lut, d, &mut slow);
            assert_eq!(fast, slow, "d={d}");
        }
    }

    #[test]
    fn bytes_per_weight_table3_rows() {
        // Table 3: "2D 2.5B @ 512" -> 5-bit index over d=2 (2.5 bits/dim)
        // + int8 codebook of k=32: at group 512 that is 1 extra bpv
        // (3.5 bpv); the paper's 3-bpv row amortizes over 1024 weights
        let b = vq_bytes_per_weight(2, 5, 32, 512);
        assert!((b - 3.5 / 8.0).abs() < 1e-9, "{b}");
        let b = vq_bytes_per_weight(2, 5, 32, 1024);
        assert!((b - 3.0 / 8.0).abs() < 1e-9, "{b}");
        // "2D 2B @ 1024": 4-bit index, k=16, group 1024 -> 2.25 bpv
        let b = vq_bytes_per_weight(2, 4, 16, 1024);
        assert!((b - 2.25 / 8.0).abs() < 1e-9, "{b}");
        // "1D 3B @ 128": 3-bit index, k=8, group 128 -> 3.5 bpv
        let b = vq_bytes_per_weight(1, 3, 8, 128);
        assert!((b - 3.5 / 8.0).abs() < 1e-9, "{b}");
    }
}
