//! Packed index bitstreams: `bits`-wide little-endian codes packed
//! contiguously, the storage format for VQ assignments and INT4 codes.

/// A packed stream of `n` indices at `bits` bits each.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedIndices {
    pub bits: u32,
    pub n: usize,
    pub data: Vec<u8>,
}

impl PackedIndices {
    /// Pack indices (each < 2^bits) into a bitstream.
    pub fn pack(indices: &[u16], bits: u32) -> PackedIndices {
        assert!((1..=16).contains(&bits));
        let n = indices.len();
        let total_bits = n * bits as usize;
        let mut data = vec![0u8; total_bits.div_ceil(8)];
        let mask = ((1u32 << bits) - 1) as u16;
        for (i, &raw) in indices.iter().enumerate() {
            let idx = raw & mask;
            debug_assert_eq!(idx, raw, "index {raw} exceeds {bits} bits");
            let bitpos = i * bits as usize;
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let val = (idx as u32) << off;
            data[byte] |= (val & 0xFF) as u8;
            if off + bits as usize > 8 {
                data[byte + 1] |= ((val >> 8) & 0xFF) as u8;
            }
            if off + bits as usize > 16 {
                data[byte + 2] |= ((val >> 16) & 0xFF) as u8;
            }
        }
        PackedIndices { bits, n, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Unpack index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u16 {
        debug_assert!(i < self.n);
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut val = self.data[byte] as u32 >> off;
        let mut have = 8 - off;
        let mut next = byte + 1;
        while have < bits {
            val |= (self.data[next] as u32) << have;
            have += 8;
            next += 1;
        }
        (val & ((1u32 << bits) - 1)) as u16
    }

    /// Iterate all indices in order.
    pub fn iter(&self) -> impl Iterator<Item = u16> + '_ {
        (0..self.n).map(move |i| self.get(i))
    }

    /// Unpack everything.
    pub fn unpack(&self) -> Vec<u16> {
        self.iter().collect()
    }

    /// Storage bytes (the transfer cost of the index stream).
    pub fn byte_len(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_all_bitwidths() {
        check("pack/unpack roundtrip", 30, |rng| {
            let bits = 1 + rng.below(12) as u32;
            let n = rng.below(300);
            let k = 1usize << bits;
            let idx: Vec<u16> = (0..n).map(|_| rng.below(k) as u16).collect();
            let packed = PackedIndices::pack(&idx, bits);
            if packed.unpack() != idx {
                return Err(format!("roundtrip failed bits={bits} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn storage_is_tight() {
        let idx = vec![1u16; 100];
        for bits in [2u32, 3, 4, 5, 8] {
            let p = PackedIndices::pack(&idx, bits);
            assert_eq!(p.byte_len(), (100 * bits as usize).div_ceil(8), "bits={bits}");
        }
    }

    #[test]
    fn boundary_crossing_values() {
        // 3-bit indices crossing byte boundaries with max values
        let idx = vec![7u16; 17];
        let p = PackedIndices::pack(&idx, 3);
        assert_eq!(p.unpack(), idx);
    }

    #[test]
    fn empty_stream() {
        let p = PackedIndices::pack(&[], 4);
        assert!(p.is_empty());
        assert_eq!(p.unpack(), Vec::<u16>::new());
    }

    #[test]
    fn get_random_access_matches_iter() {
        let idx: Vec<u16> = (0..97).map(|i| (i % 32) as u16).collect();
        let p = PackedIndices::pack(&idx, 5);
        for (i, want) in idx.iter().enumerate() {
            assert_eq!(p.get(i), *want);
        }
    }
}
