//! Uniform-integer dequantization baselines for Table 3: INT4 (packed,
//! per-group scale/zero) and INT8 (per-group scale).

use crate::decode::pack::PackedIndices;

/// Pack 4-bit uniform codes (values < 16).
pub fn pack_int4(codes: &[u16]) -> PackedIndices {
    PackedIndices::pack(codes, 4)
}

/// Dequantize packed INT4 codes: `out[i] = zero[g] + code * scale[g]`
/// with `g = i / group_size`. The multiply-add per element is the extra
/// work VQ avoids — the core of the paper's latency argument.
pub fn dequant_int4(
    packed: &PackedIndices,
    scales: &[f32],
    zeros: &[f32],
    group_size: usize,
    out: &mut [f32],
) {
    let n = packed.len();
    assert_eq!(out.len(), n);
    assert_eq!(packed.bits, 4);
    let data = &packed.data;
    let full = n / 2;
    for b in 0..full {
        let byte = data[b];
        let i0 = b * 2;
        let g0 = i0 / group_size;
        let g1 = (i0 + 1) / group_size;
        out[i0] = zeros[g0] + (byte & 0x0F) as f32 * scales[g0];
        out[i0 + 1] = zeros[g1] + (byte >> 4) as f32 * scales[g1];
    }
    if n % 2 == 1 {
        let g = (n - 1) / group_size;
        out[n - 1] = zeros[g] + (data[full] & 0x0F) as f32 * scales[g];
    }
}

/// Dequantize INT8 codes (one byte per weight, symmetric scale).
pub fn dequant_int8(codes: &[i8], scales: &[f32], group_size: usize, out: &mut [f32]) {
    assert_eq!(out.len(), codes.len());
    for (i, &c) in codes.iter().enumerate() {
        out[i] = c as f32 * scales[i / group_size];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn int4_roundtrip_on_grid() {
        let mut rng = Rng::new(1);
        let n = 256;
        let gs = 64;
        let codes: Vec<u16> = (0..n).map(|_| rng.below(16) as u16).collect();
        let scales: Vec<f32> = (0..n / gs).map(|_| rng.range(0.01, 0.1) as f32).collect();
        let zeros: Vec<f32> = (0..n / gs).map(|_| rng.gaussian() as f32 * 0.1).collect();
        let packed = pack_int4(&codes);
        let mut out = vec![0f32; n];
        dequant_int4(&packed, &scales, &zeros, gs, &mut out);
        for i in 0..n {
            let want = zeros[i / gs] + codes[i] as f32 * scales[i / gs];
            assert_eq!(out[i], want, "i={i}");
        }
    }

    #[test]
    fn int4_odd_length() {
        let codes = vec![5u16; 33];
        let packed = pack_int4(&codes);
        let mut out = vec![0f32; 33];
        dequant_int4(&packed, &[2.0], &[1.0], 64, &mut out);
        assert!(out.iter().all(|&v| v == 11.0));
    }

    #[test]
    fn int8_dequant() {
        let codes: Vec<i8> = vec![-128, -1, 0, 1, 127, 64, -64, 2];
        let mut out = vec![0f32; 8];
        dequant_int8(&codes, &[0.5, 2.0], 4, &mut out);
        assert_eq!(out[0], -64.0);
        assert_eq!(out[4], 254.0);
        assert_eq!(out[7], 4.0);
    }
}
