//! Dense row-major f64 matrix substrate.
//!
//! No external BLAS is available offline; [`Matrix::matmul`] and friends
//! implement cache-blocked kernels tuned in the §Perf pass (see
//! EXPERIMENTS.md). All quantization math runs in f64 for numerical
//! robustness; f32 appears only at interchange boundaries (checkpoints,
//! HLO buffers, packed formats).

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{axpy, matmul, matmul_a_bt, matmul_at_b, matmul_at_b_threaded, matmul_threaded};
