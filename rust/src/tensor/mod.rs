//! Dense row-major matrix substrate, generic over element precision.
//!
//! No external BLAS is available offline; [`ops`](self) implements
//! cache-blocked kernels with explicit 8-lane inner loops that the
//! auto-vectorizer turns into SIMD at either width. The [`Element`] trait
//! (implemented by `f64` and `f32`) parameterizes every kernel:
//! [`Matrix`] (`f64`) is the reference path on which all accuracy
//! baselines are pinned, and [`Matrix32`] backs the `--precision f32`
//! fast path through the quantization hot loops. Numerically sensitive
//! work — Cholesky/eigen factorizations, EM seeding, final loss
//! accounting — always runs in f64; `f32` additionally appears at
//! interchange boundaries (checkpoints, HLO buffers, packed formats).

mod element;
mod matrix;
mod ops;

pub use element::{Element, Precision};
pub use matrix::{Matrix, Matrix32, MatrixG};
pub use ops::{
    axpy, matmul, matmul_a_bt, matmul_at_b, matmul_at_b_on, matmul_at_b_threaded, matmul_on,
    matmul_threaded,
};
