//! Matmul kernels: cache-blocked, i-k-j inner ordering so the innermost
//! loop is a contiguous FMA over the output row (auto-vectorizes well).
//!
//! Three orientations avoid materializing transposes on the hot paths:
//!   matmul      : C = A @ B
//!   matmul_a_bt : C = A @ B^T   (B stored row-major as [n, k])
//!   matmul_at_b : C = A^T @ B   (used for Hessian accumulation X X^T)

use super::matrix::Matrix;

/// C = A[m,k] @ B[k,n].
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    // i-k-j: for each output row, accumulate scaled B rows.
    const KB: usize = 64; // k-blocking keeps B rows hot in L1/L2
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for p in kb..kend {
                let aval = arow[p];
                if aval == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aval * bv;
                }
            }
        }
    }
    c
}

/// C = A[m,k] @ B^T where B is stored as [n,k]: C[i,j] = dot(A[i,:], B[j,:]).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] = acc;
        }
    }
    c
}

/// C = A^T @ B where A is [k,m], B is [k,n]: C[i,j] = sum_p A[p,i]*B[p,j].
/// Computed as a rank-1 accumulation per row of A/B (contiguous in both).
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b inner dim");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aval = arow[i];
            if aval == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0;
                for p in 0..a.cols() {
                    acc += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    fn rand_matrix(rng: &mut crate::util::Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.gaussian())
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b);
        assert_eq!(c.as_slice(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(5, 5, |r, c| (r * 5 + c) as f64);
        let i = Matrix::identity(5);
        assert_eq!(matmul(&a, &i), a);
        assert_eq!(matmul(&i, &a), a);
    }

    #[test]
    fn matmul_matches_naive_property() {
        check("matmul == naive", 20, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(17);
            let n = 1 + rng.below(17);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, k, n);
            let fast = matmul(&a, &b);
            let slow = naive(&a, &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "matmul")
        });
    }

    #[test]
    fn matmul_a_bt_matches_transpose() {
        check("a_bt == a @ b.T", 20, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, n, k);
            let fast = matmul_a_bt(&a, &b);
            let slow = matmul(&a, &b.transpose());
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "a_bt")
        });
    }

    #[test]
    fn matmul_at_b_matches_transpose() {
        check("at_b == a.T @ b", 20, |rng| {
            let k = 1 + rng.below(9);
            let m = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, k, m);
            let b = rand_matrix(rng, k, n);
            let fast = matmul_at_b(&a, &b);
            let slow = matmul(&a.transpose(), &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "at_b")
        });
    }

    #[test]
    fn big_blocked_matmul_correct() {
        let mut rng = crate::util::Rng::new(11);
        let a = rand_matrix(&mut rng, 130, 70);
        let b = rand_matrix(&mut rng, 70, 90);
        let fast = matmul(&a, &b);
        let slow = naive(&a, &b);
        crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-8, 1e-8, "big").unwrap();
    }
}
