//! Matmul kernels: precision-generic, cache-blocked, i-k-j inner ordering
//! so the innermost loop is a contiguous FMA over the output row.
//!
//! Every kernel is generic over [`Element`] (`f64` reference path, `f32`
//! fast path) and written in an explicit-width style: the innermost loops
//! process fixed 8-lane chunks with scalar remainders, which the
//! auto-vectorizer compiles to full-width SIMD at either precision (8
//! doubles = 2–4 AVX registers, 8 floats = 1–2). The lane structure is
//! fixed at compile time, so results do not depend on input length
//! beyond the usual sequential accumulation order.
//!
//! Three orientations avoid materializing transposes on the hot paths:
//!   matmul      : C = A @ B
//!   matmul_a_bt : C = A @ B^T   (B stored row-major as [n, k])
//!   matmul_at_b : C = A^T @ B   (used for Hessian accumulation X X^T)
//!
//! `matmul` and `matmul_at_b` have `_on` variants that split the
//! *output rows* across the lanes of a borrowed persistent
//! [`WorkerPool`] (with `_threaded` wrappers for standalone use). Each
//! output row is produced by the exact same sequential k-blocked
//! accumulation as the single-threaded kernel, so results are bitwise
//! identical for every pool width — the property the GPTVQ engine's
//! `--threads` guarantee rests on. They are shared by
//! `recon_loss`/`loss_and_eh`/`codebook_update` (E @ H) and the Hessian
//! collector (X^T X), at both precisions.

use super::element::Element;
use super::matrix::MatrixG;
use crate::util::par::parallel_row_bands;
use crate::util::pool::WorkerPool;

/// k-blocking keeps the B rows touched by one pass hot in L1/L2.
const KB: usize = 64;

/// Unroll width of the explicit-width kernels. Eight elements fill the
/// widest common SIMD registers at f32 (one AVX2 register) and stay a
/// small multiple at f64; the chunked loops below carry no cross-lane
/// dependency, so the compiler vectorizes them at either width.
const LANES: usize = 8;

/// `y += a * x` over contiguous slices — the shared innermost kernel of
/// the matmuls and of the GPTVQ error-propagation/lazy-flush loops.
///
/// Explicit 8-lane body: lanes are independent element-wise updates, so
/// the result is identical (bitwise, at every width) to the plain scalar
/// loop — unrolling only exposes the independence to the vectorizer.
#[inline]
pub fn axpy<E: Element>(y: &mut [E], a: E, x: &[E]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len() - y.len() % LANES;
    let (y_main, y_tail) = y.split_at_mut(n);
    let (x_main, x_tail) = x.split_at(n);
    for (yc, xc) in y_main.chunks_exact_mut(LANES).zip(x_main.chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] += a * xc[l];
        }
    }
    for (yv, xv) in y_tail.iter_mut().zip(x_tail) {
        *yv += a * *xv;
    }
}

/// C = A[m,k] @ B[k,n].
pub fn matmul<E: Element>(a: &MatrixG<E>, b: &MatrixG<E>) -> MatrixG<E> {
    matmul_on(a, b, WorkerPool::inline())
}

/// `matmul` with output rows split across up to `n_threads` workers
/// (bitwise identical to the single-threaded result; small products run
/// inline). Standalone-use wrapper around [`matmul_on`]; callers that
/// already hold a pool should use that directly to avoid re-spawning
/// workers per product.
pub fn matmul_threaded<E: Element>(a: &MatrixG<E>, b: &MatrixG<E>, n_threads: usize) -> MatrixG<E> {
    matmul_on(a, b, &WorkerPool::new(n_threads))
}

/// `matmul` with output rows split across the lanes of a borrowed
/// [`WorkerPool`] (bitwise identical to the single-threaded result;
/// products below the grain run inline on the caller).
pub fn matmul_on<E: Element>(a: &MatrixG<E>, b: &MatrixG<E>, pool: &WorkerPool) -> MatrixG<E> {
    assert_eq!(a.cols(), b.rows(), "matmul inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = MatrixG::zeros(m, n);
    let nt = pool.threads_for(m.saturating_mul(k).saturating_mul(n));
    parallel_row_bands(pool, c.as_mut_slice(), m, n, nt, |row0, band| {
        let band_rows = if n > 0 { band.len() / n } else { 0 };
        // i-k-j: for each output row, accumulate scaled B rows.
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..band_rows {
                let arow = a.row(row0 + i);
                let crow = &mut band[i * n..(i + 1) * n];
                for p in kb..kend {
                    let aval = arow[p];
                    if aval == E::ZERO {
                        continue;
                    }
                    axpy(crow, aval, b.row(p));
                }
            }
        }
    });
    c
}

/// C = A[m,k] @ B^T where B is stored as [n,k]: C[i,j] = dot(A[i,:], B[j,:]).
///
/// Accumulates each element sequentially (no lane reduction): this
/// orientation backs the SVD codebook-compression path, and keeping the
/// historical accumulation order preserves bitwise reproducibility of
/// f64 results against all prior runs — the contract the reference path
/// advertises.
pub fn matmul_a_bt<E: Element>(a: &MatrixG<E>, b: &MatrixG<E>) -> MatrixG<E> {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = MatrixG::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = E::ZERO;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] = acc;
        }
    }
    c
}

/// C = A^T @ B where A is [k,m], B is [k,n]: C[i,j] = sum_p A[p,i]*B[p,j].
/// Computed as a rank-1 accumulation per row of A/B (contiguous in both).
pub fn matmul_at_b<E: Element>(a: &MatrixG<E>, b: &MatrixG<E>) -> MatrixG<E> {
    matmul_at_b_on(a, b, WorkerPool::inline())
}

/// `matmul_at_b` with output rows (columns of A) split across workers.
/// Standalone-use wrapper around [`matmul_at_b_on`].
pub fn matmul_at_b_threaded<E: Element>(
    a: &MatrixG<E>,
    b: &MatrixG<E>,
    n_threads: usize,
) -> MatrixG<E> {
    matmul_at_b_on(a, b, &WorkerPool::new(n_threads))
}

/// `matmul_at_b` with output rows (columns of A) split across the lanes
/// of a borrowed [`WorkerPool`]. Every element accumulates over p in
/// ascending order in both variants, so the result is bitwise identical
/// for any pool width.
pub fn matmul_at_b_on<E: Element>(
    a: &MatrixG<E>,
    b: &MatrixG<E>,
    pool: &WorkerPool,
) -> MatrixG<E> {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b inner dim");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = MatrixG::zeros(m, n);
    let nt = pool.threads_for(k.saturating_mul(m).saturating_mul(n));
    parallel_row_bands(pool, c.as_mut_slice(), m, n, nt, |row0, band| {
        let band_rows = if n > 0 { band.len() / n } else { 0 };
        for p in 0..k {
            let arow = a.row(p);
            let brow = b.row(p);
            for i in 0..band_rows {
                let aval = arow[row0 + i];
                if aval == E::ZERO {
                    continue;
                }
                axpy(&mut band[i * n..(i + 1) * n], aval, brow);
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Matrix32};
    use crate::util::prop::check;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0;
                for p in 0..a.cols() {
                    acc += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    fn rand_matrix(rng: &mut crate::util::Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.gaussian())
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn axpy_unrolled_matches_scalar_across_lengths() {
        // the 8-lane body + tail must cover every length split exactly
        let mut rng = crate::util::Rng::new(40);
        for len in [0, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65] {
            let x: Vec<f64> = (0..len).map(|_| rng.gaussian()).collect();
            let mut y: Vec<f64> = (0..len).map(|_| rng.gaussian()).collect();
            let mut y_ref = y.clone();
            axpy(&mut y, 0.7, &x);
            for (yv, xv) in y_ref.iter_mut().zip(&x) {
                *yv += 0.7 * xv;
            }
            assert_eq!(y, y_ref, "len {len}");
        }
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b);
        assert_eq!(c.as_slice(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(5, 5, |r, c| (r * 5 + c) as f64);
        let i = Matrix::identity(5);
        assert_eq!(matmul(&a, &i), a);
        assert_eq!(matmul(&i, &a), a);
    }

    #[test]
    fn matmul_matches_naive_property() {
        check("matmul == naive", 20, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(17);
            let n = 1 + rng.below(17);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, k, n);
            let fast = matmul(&a, &b);
            let slow = naive(&a, &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "matmul")
        });
    }

    #[test]
    fn matmul_threaded_is_bitwise_identical() {
        // the determinism guarantee: big enough to cross PAR_GRAIN and
        // genuinely run multi-threaded (97*67*83 ≈ 540k > 256k)
        let mut rng = crate::util::Rng::new(17);
        let a = rand_matrix(&mut rng, 97, 67);
        let b = rand_matrix(&mut rng, 67, 83);
        let single = matmul_threaded(&a, &b, 1);
        for nt in [2, 3, 4, 8] {
            assert_eq!(matmul_threaded(&a, &b, nt), single, "{nt} threads");
        }
    }

    #[test]
    fn matmul_on_shared_pool_matches_per_call_pools() {
        // one persistent pool reused across many products must give the
        // same bits as a fresh pool (or scope) per product
        let mut rng = crate::util::Rng::new(23);
        let pool = crate::util::WorkerPool::new(4);
        for _ in 0..3 {
            let a = rand_matrix(&mut rng, 97, 67);
            let b = rand_matrix(&mut rng, 67, 83);
            assert_eq!(matmul_on(&a, &b, &pool), matmul_threaded(&a, &b, 4));
            assert_eq!(matmul_at_b_on(&a, &a, &pool), matmul_at_b_threaded(&a, &a, 4));
        }
    }

    #[test]
    fn matmul_at_b_threaded_is_bitwise_identical() {
        // 110*60*70 ≈ 460k > PAR_GRAIN, so the fan-out actually engages
        let mut rng = crate::util::Rng::new(18);
        let a = rand_matrix(&mut rng, 110, 60);
        let b = rand_matrix(&mut rng, 110, 70);
        let single = matmul_at_b_threaded(&a, &b, 1);
        for nt in [2, 4, 8] {
            assert_eq!(matmul_at_b_threaded(&a, &b, nt), single, "{nt} threads");
        }
    }

    #[test]
    fn f32_kernels_track_f64_within_single_precision() {
        // same inputs through both monomorphizations: the f32 kernels must
        // agree with the f64 reference to f32 rounding accuracy
        let mut rng = crate::util::Rng::new(19);
        let a = rand_matrix(&mut rng, 33, 41);
        let b = rand_matrix(&mut rng, 41, 29);
        let wide = matmul(&a, &b);
        let narrow = matmul::<f32>(&a.convert(), &b.convert());
        for (w, n) in wide.as_slice().iter().zip(narrow.as_slice()) {
            assert!((w - n.to_f64()).abs() < 1e-3 * (1.0 + w.abs()), "{w} vs {n}");
        }
        let xtx64 = matmul_at_b(&a, &a);
        let xtx32 = matmul_at_b::<f32>(&a.convert(), &a.convert());
        for (w, n) in xtx64.as_slice().iter().zip(xtx32.as_slice()) {
            assert!((w - n.to_f64()).abs() < 1e-3 * (1.0 + w.abs()), "{w} vs {n}");
        }
    }

    #[test]
    fn f32_threaded_kernels_are_bitwise_identical() {
        // the determinism contract holds at f32 too
        let mut rng = crate::util::Rng::new(20);
        let a: Matrix32 = rand_matrix(&mut rng, 97, 67).convert();
        let b: Matrix32 = rand_matrix(&mut rng, 67, 83).convert();
        let single = matmul_threaded(&a, &b, 1);
        for nt in [2, 4, 8] {
            assert_eq!(matmul_threaded(&a, &b, nt), single, "{nt} threads");
        }
        let c: Matrix32 = rand_matrix(&mut rng, 110, 70).convert();
        let single_atb = matmul_at_b_threaded(&c, &c, 1);
        for nt in [2, 4, 8] {
            assert_eq!(matmul_at_b_threaded(&c, &c, nt), single_atb, "{nt} threads");
        }
    }

    #[test]
    fn matmul_a_bt_matches_transpose() {
        check("a_bt == a @ b.T", 20, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, n, k);
            let fast = matmul_a_bt(&a, &b);
            let slow = matmul(&a, &b.transpose());
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "a_bt")
        });
    }

    #[test]
    fn matmul_at_b_matches_transpose() {
        check("at_b == a.T @ b", 20, |rng| {
            let k = 1 + rng.below(9);
            let m = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, k, m);
            let b = rand_matrix(rng, k, n);
            let fast = matmul_at_b(&a, &b);
            let slow = matmul(&a.transpose(), &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "at_b")
        });
    }

    #[test]
    fn big_blocked_matmul_correct() {
        let mut rng = crate::util::Rng::new(11);
        let a = rand_matrix(&mut rng, 130, 70);
        let b = rand_matrix(&mut rng, 70, 90);
        let fast = matmul(&a, &b);
        let slow = naive(&a, &b);
        crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-8, 1e-8, "big").unwrap();
    }
}
