//! Matmul kernels: cache-blocked, i-k-j inner ordering so the innermost
//! loop is a contiguous FMA over the output row (auto-vectorizes well).
//!
//! Three orientations avoid materializing transposes on the hot paths:
//!   matmul      : C = A @ B
//!   matmul_a_bt : C = A @ B^T   (B stored row-major as [n, k])
//!   matmul_at_b : C = A^T @ B   (used for Hessian accumulation X X^T)
//!
//! `matmul` and `matmul_at_b` have `_threaded` variants that split the
//! *output rows* across scoped workers. Each output row is produced by the
//! exact same sequential k-blocked accumulation as the single-threaded
//! kernel, so results are bitwise identical for every thread count — the
//! property the GPTVQ engine's `--threads` guarantee rests on. They are
//! shared by `recon_loss`/`codebook_update` (E @ H) and the Hessian
//! collector (X^T X).

use super::matrix::Matrix;
use crate::util::par::{parallel_row_bands, threads_for};

/// k-blocking keeps the B rows touched by one pass hot in L1/L2.
const KB: usize = 64;

/// `y += a * x` over contiguous slices — the shared innermost kernel of
/// the matmuls and of the GPTVQ error-propagation/lazy-flush loops.
#[inline]
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// C = A[m,k] @ B[k,n].
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_threaded(a, b, 1)
}

/// `matmul` with output rows split across up to `n_threads` workers
/// (bitwise identical to the single-threaded result; small products run
/// inline).
pub fn matmul_threaded(a: &Matrix, b: &Matrix, n_threads: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let nt = threads_for(n_threads, m.saturating_mul(k).saturating_mul(n));
    parallel_row_bands(c.as_mut_slice(), m, n, nt, |row0, band| {
        let band_rows = if n > 0 { band.len() / n } else { 0 };
        // i-k-j: for each output row, accumulate scaled B rows.
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..band_rows {
                let arow = a.row(row0 + i);
                let crow = &mut band[i * n..(i + 1) * n];
                for p in kb..kend {
                    let aval = arow[p];
                    if aval == 0.0 {
                        continue;
                    }
                    axpy(crow, aval, b.row(p));
                }
            }
        }
    });
    c
}

/// C = A[m,k] @ B^T where B is stored as [n,k]: C[i,j] = dot(A[i,:], B[j,:]).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt inner dim");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] = acc;
        }
    }
    c
}

/// C = A^T @ B where A is [k,m], B is [k,n]: C[i,j] = sum_p A[p,i]*B[p,j].
/// Computed as a rank-1 accumulation per row of A/B (contiguous in both).
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_at_b_threaded(a, b, 1)
}

/// `matmul_at_b` with output rows (columns of A) split across workers.
/// Every element accumulates over p in ascending order in both variants,
/// so the result is bitwise identical for any thread count.
pub fn matmul_at_b_threaded(a: &Matrix, b: &Matrix, n_threads: usize) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b inner dim");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let nt = threads_for(n_threads, k.saturating_mul(m).saturating_mul(n));
    parallel_row_bands(c.as_mut_slice(), m, n, nt, |row0, band| {
        let band_rows = if n > 0 { band.len() / n } else { 0 };
        for p in 0..k {
            let arow = a.row(p);
            let brow = b.row(p);
            for i in 0..band_rows {
                let aval = arow[row0 + i];
                if aval == 0.0 {
                    continue;
                }
                axpy(&mut band[i * n..(i + 1) * n], aval, brow);
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0;
                for p in 0..a.cols() {
                    acc += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    fn rand_matrix(rng: &mut crate::util::Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.gaussian())
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b);
        assert_eq!(c.as_slice(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(5, 5, |r, c| (r * 5 + c) as f64);
        let i = Matrix::identity(5);
        assert_eq!(matmul(&a, &i), a);
        assert_eq!(matmul(&i, &a), a);
    }

    #[test]
    fn matmul_matches_naive_property() {
        check("matmul == naive", 20, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(17);
            let n = 1 + rng.below(17);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, k, n);
            let fast = matmul(&a, &b);
            let slow = naive(&a, &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "matmul")
        });
    }

    #[test]
    fn matmul_threaded_is_bitwise_identical() {
        // the determinism guarantee: big enough to cross PAR_GRAIN and
        // genuinely run multi-threaded (97*67*83 ≈ 540k > 256k)
        let mut rng = crate::util::Rng::new(17);
        let a = rand_matrix(&mut rng, 97, 67);
        let b = rand_matrix(&mut rng, 67, 83);
        let single = matmul_threaded(&a, &b, 1);
        for nt in [2, 3, 4, 8] {
            assert_eq!(matmul_threaded(&a, &b, nt), single, "{nt} threads");
        }
    }

    #[test]
    fn matmul_at_b_threaded_is_bitwise_identical() {
        // 110*60*70 ≈ 460k > PAR_GRAIN, so the fan-out actually engages
        let mut rng = crate::util::Rng::new(18);
        let a = rand_matrix(&mut rng, 110, 60);
        let b = rand_matrix(&mut rng, 110, 70);
        let single = matmul_at_b_threaded(&a, &b, 1);
        for nt in [2, 4, 8] {
            assert_eq!(matmul_at_b_threaded(&a, &b, nt), single, "{nt} threads");
        }
    }

    #[test]
    fn matmul_a_bt_matches_transpose() {
        check("a_bt == a @ b.T", 20, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, m, k);
            let b = rand_matrix(rng, n, k);
            let fast = matmul_a_bt(&a, &b);
            let slow = matmul(&a, &b.transpose());
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "a_bt")
        });
    }

    #[test]
    fn matmul_at_b_matches_transpose() {
        check("at_b == a.T @ b", 20, |rng| {
            let k = 1 + rng.below(9);
            let m = 1 + rng.below(9);
            let n = 1 + rng.below(9);
            let a = rand_matrix(rng, k, m);
            let b = rand_matrix(rng, k, n);
            let fast = matmul_at_b(&a, &b);
            let slow = matmul(&a.transpose(), &b);
            crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-9, 1e-9, "at_b")
        });
    }

    #[test]
    fn big_blocked_matmul_correct() {
        let mut rng = crate::util::Rng::new(11);
        let a = rand_matrix(&mut rng, 130, 70);
        let b = rand_matrix(&mut rng, 70, 90);
        let fast = matmul(&a, &b);
        let slow = naive(&a, &b);
        crate::util::prop::assert_close(fast.as_slice(), slow.as_slice(), 1e-8, 1e-8, "big").unwrap();
    }
}
