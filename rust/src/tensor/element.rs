//! The [`Element`] trait: the scalar abstraction behind the precision-
//! generic compute layer, plus the user-facing [`Precision`] selector.
//!
//! Every hot kernel in [`crate::tensor::ops`] — and the GPTVQ engine
//! stages built on them (Hessian accumulation, EM, sweep, codebook
//! update) — is written once, generically over `Element`, and
//! monomorphized for `f64` and `f32`. The `f64` instantiation is the
//! reference path: for it, `from_f64`/`to_f64` are identities and the
//! generic kernels execute exactly the operations of the original
//! scalar-f64 code, so determinism and accuracy baselines are preserved.
//! The `f32` instantiation is the throughput path: half the memory
//! traffic and twice the SIMD lanes through the same auto-vectorized
//! loops.
//!
//! Numerically sensitive stages stay `f64` regardless of the selected
//! precision: Cholesky/eigen factorizations ([`crate::linalg`]), EM
//! seeding (which runs through the eigendecomposition), and the final
//! reconstruction-loss accounting reported in
//! [`crate::quant::gptvq::GptvqStats`].

use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

use crate::error::Error;

/// A floating-point scalar the compute kernels can be instantiated with.
///
/// Implemented for `f64` (the reference precision) and `f32` (the fast
/// path). The bound list covers everything the generic kernels need:
/// plain arithmetic, comparisons, thread-safety, and exact conversion to
/// and from `f64` (`f32 -> f64` widening is exact, so round-tripping an
/// `f32` value through `to_f64`/`from_f64` never changes it).
pub trait Element:
    Copy
    + Clone
    + Debug
    + Display
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Positive infinity (argmin initialization).
    const INFINITY: Self;
    /// Width name for logs and bench output: `"f64"` or `"f32"`.
    const NAME: &'static str;
    /// Relative early-stop tolerance for iterative refinement (the EM
    /// convergence check): tight for `f64` (1e-8), looser for `f32`
    /// (1e-5) where iterating below the width's own rounding noise would
    /// burn cycles without changing the outcome.
    const EM_REL_TOL: f64;
    /// The [`Precision`] selector this width corresponds to, so generic
    /// code can dispatch back into precision-keyed APIs.
    const PRECISION: Precision;

    /// Exact widening (for `f32`) or identity (for `f64`).
    fn to_f64(self) -> f64;
    /// Narrowing (for `f32`, round-to-nearest) or identity (for `f64`).
    fn from_f64(v: f64) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Total order including NaN (degenerate weights must not panic a
    /// sort — same contract as `f64::total_cmp`).
    fn total_cmp(&self, other: &Self) -> std::cmp::Ordering;
}

impl Element for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const INFINITY: f64 = f64::INFINITY;
    const NAME: &'static str = "f64";
    const EM_REL_TOL: f64 = 1e-8;
    const PRECISION: Precision = Precision::F64;

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline(always)]
    fn abs(self) -> f64 {
        f64::abs(self)
    }
    #[inline(always)]
    fn total_cmp(&self, other: &f64) -> std::cmp::Ordering {
        f64::total_cmp(self, other)
    }
}

impl Element for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const INFINITY: f32 = f32::INFINITY;
    const NAME: &'static str = "f32";
    const EM_REL_TOL: f64 = 1e-5;
    const PRECISION: Precision = Precision::F32;

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline(always)]
    fn abs(self) -> f32 {
        f32::abs(self)
    }
    #[inline(always)]
    fn total_cmp(&self, other: &f32) -> std::cmp::Ordering {
        f32::total_cmp(self, other)
    }
}

/// Compute precision selector for the quantization hot loops.
///
/// `F64` (the default) runs every stage in double precision — the
/// reference configuration, bitwise-reproducible against all prior
/// results. `F32` runs the throughput-bound stages (Hessian `X^T X`
/// accumulation, EM init, sweep assignment, error propagation / lazy
/// flush, and the codebook-update matmuls) in single precision while
/// keeping the Cholesky factorization, EM seeding, and final loss
/// accounting in `f64`. Accuracy is pinned by the guardrail tests in
/// [`crate::quant::gptvq`] and the pipeline suite.
///
/// Selected via `GptvqConfig::precision` / `PipelineConfig::precision`
/// or the CLI `--precision {f64,f32}` flag. Both precisions keep the
/// engine's determinism contract: thread count never changes the output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Scalar f64 everywhere — the reference path.
    #[default]
    F64,
    /// f32 hot loops with f64 factorizations and loss accounting.
    F32,
}

impl Precision {
    /// Canonical lowercase name (`"f64"` / `"f32"`), matching the CLI
    /// `--precision` values.
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

impl Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Precision {
    type Err = Error;

    fn from_str(s: &str) -> Result<Precision, Error> {
        match s {
            "f64" | "F64" | "double" => Ok(Precision::F64),
            "f32" | "F32" | "single" => Ok(Precision::F32),
            other => Err(Error::Config(format!("unknown precision {other} (expected f64 or f32)"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_conversions_are_identity() {
        assert_eq!(1.5f64.to_f64(), 1.5);
        assert_eq!(<f64 as Element>::from_f64(-2.25), -2.25);
    }

    #[test]
    fn f32_roundtrip_is_exact_for_f32_values() {
        // widening then narrowing an f32 value must be lossless
        for v in [1.5f32, -2.25, 1e-20, 3.4e38, 0.1] {
            assert_eq!(<f32 as Element>::from_f64(v.to_f64()), v);
        }
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!("f64".parse::<Precision>().unwrap(), Precision::F64);
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert!("f16".parse::<Precision>().is_err());
        assert_eq!(Precision::F32.to_string(), "f32");
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn element_constants() {
        assert_eq!(<f32 as Element>::ZERO + <f32 as Element>::ONE, 1.0f32);
        assert!(<f32 as Element>::INFINITY > 3.4e38f32);
        assert_eq!(<f64 as Element>::NAME, "f64");
        assert_eq!(<f32 as Element>::NAME, "f32");
    }
}
