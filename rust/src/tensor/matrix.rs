//! The `Matrix` type: dense, row-major, f64.

use crate::error::{Error, Result};

/// Dense row-major matrix. Element (r, c) lives at `data[r * cols + c]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Matrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_vec: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Matrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_f32: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col_copy(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on big matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Copy a column range [c0, c1) into a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Copy a row range [r0, r1) into a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        let data = self.data[r0 * self.cols..r1 * self.cols].to_vec();
        Matrix { rows: r1 - r0, cols: self.cols, data }
    }

    /// Write `block` into columns [c0, c0+block.cols).
    pub fn set_cols(&mut self, c0: usize, block: &Matrix) {
        assert_eq!(block.rows, self.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            self.row_mut(r)[c0..c0 + block.cols].copy_from_slice(block.row(r));
        }
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f64>() / self.data.len() as f64
        }
    }

    /// y = self @ x for a vector x (len == cols).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col_copy(1), vec![2., 5.]);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(7, 13, |r, c| (r * 100 + c) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 13);
        assert_eq!(t.get(5, 3), m.get(3, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn slicing() {
        let m = Matrix::from_fn(4, 6, |r, c| (r * 10 + c) as f64);
        let s = m.slice_cols(2, 5);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.get(1, 0), 12.0);
        let rs = m.slice_rows(1, 3);
        assert_eq!(rs.rows(), 2);
        assert_eq!(rs.get(0, 0), 10.0);
    }

    #[test]
    fn set_cols_writes_block() {
        let mut m = Matrix::zeros(2, 4);
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        m.set_cols(1, &b);
        assert_eq!(m.row(0), &[0., 1., 2., 0.]);
        assert_eq!(m.row(1), &[0., 3., 4., 0.]);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]).unwrap();
        let d = a.sub(&b);
        assert_eq!(d.get(0, 0), 0.5);
        assert!((a.frob_norm_sq() - 30.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
        assert_eq!(a.mean(), 2.5);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let y = m.matvec(&[1.0, 0.5, -1.0]);
        assert_eq!(y, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_f32(1, 3, &[1.5f32, -2.25, 0.0]).unwrap();
        assert_eq!(m.to_f32(), vec![1.5f32, -2.25, 0.0]);
    }
}
