//! The dense row-major matrix type, generic over the element width.
//!
//! [`MatrixG<E>`] is the storage type behind every compute path;
//! [`Matrix`] (= `MatrixG<f64>`) is the canonical alias used across the
//! crate, and [`Matrix32`] (= `MatrixG<f32>`) backs the single-precision
//! fast path. Conversion between widths is explicit ([`MatrixG::convert`])
//! so precision boundaries are visible at the call site.

use super::element::Element;
use crate::error::{Error, Result};

/// Dense row-major matrix over element type `E`. Element (r, c) lives at
/// `data[r * cols + c]`.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixG<E: Element> {
    rows: usize,
    cols: usize,
    data: Vec<E>,
}

/// The canonical double-precision matrix (the reference compute path).
pub type Matrix = MatrixG<f64>;

/// Single-precision matrix backing the `--precision f32` fast path.
pub type Matrix32 = MatrixG<f32>;

impl<E: Element> MatrixG<E> {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> MatrixG<E> {
        MatrixG { rows, cols, data: vec![E::ZERO; rows * cols] }
    }

    /// n×n identity.
    pub fn identity(n: usize) -> MatrixG<E> {
        let mut m = MatrixG::zeros(n, n);
        for i in 0..n {
            m.set(i, i, E::ONE);
        }
        m
    }

    /// Wrap a row-major buffer; errors if the length does not match.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<E>) -> Result<MatrixG<E>> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_vec: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(MatrixG { rows, cols, data })
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> E) -> MatrixG<E> {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatrixG { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total number of elements (`rows * cols`).
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the matrix holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element at (r, c).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> E {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Overwrite element (r, c).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: E) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a contiguous slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[E] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [E] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c` (rows are contiguous, columns are not).
    pub fn col_copy(&self, c: usize) -> Vec<E> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// The full row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[E] {
        &self.data
    }

    /// The full row-major buffer, mutably.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [E] {
        &mut self.data
    }

    /// Copy into another element width (`f64 -> f32` narrows with
    /// round-to-nearest; `f32 -> f64` is exact; same-width is a clone).
    pub fn convert<F: Element>(&self) -> MatrixG<F> {
        MatrixG {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| F::from_f64(v.to_f64())).collect(),
        }
    }

    /// Blocked transpose (cache-friendly on big matrices).
    pub fn transpose(&self) -> MatrixG<E> {
        let mut out = MatrixG::zeros(self.cols, self.rows);
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Copy a column range [c0, c1) into a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> MatrixG<E> {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = MatrixG::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Copy a row range [r0, r1) into a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> MatrixG<E> {
        assert!(r0 <= r1 && r1 <= self.rows);
        let data = self.data[r0 * self.cols..r1 * self.cols].to_vec();
        MatrixG { rows: r1 - r0, cols: self.cols, data }
    }

    /// Write `block` into columns [c0, c0+block.cols).
    pub fn set_cols(&mut self, c0: usize, block: &MatrixG<E>) {
        assert_eq!(block.rows, self.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            self.row_mut(r)[c0..c0 + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// Multiply every element by `s` in place.
    pub fn scale(&mut self, s: E) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Element-wise `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &MatrixG<E>) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
    }

    /// Element-wise difference `self - other` (shapes must match).
    pub fn sub(&self, other: &MatrixG<E>) -> MatrixG<E> {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| a - b).collect();
        MatrixG { rows: self.rows, cols: self.cols, data }
    }

    /// Squared Frobenius norm, accumulated in the element width.
    pub fn frob_norm_sq(&self) -> f64 {
        let mut acc = E::ZERO;
        for &v in &self.data {
            acc += v * v;
        }
        acc.to_f64()
    }

    /// Largest absolute element (0 for an empty matrix; NaNs are skipped,
    /// matching `f64::max` semantics).
    pub fn max_abs(&self) -> f64 {
        let mut m = E::ZERO;
        for &v in &self.data {
            if v.abs() > m {
                m = v.abs();
            }
        }
        m.to_f64()
    }

    /// Mean of all elements (0 for an empty matrix).
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let mut acc = E::ZERO;
        for &v in &self.data {
            acc += v;
        }
        acc.to_f64() / self.data.len() as f64
    }

    /// y = self @ x for a vector x (len == cols).
    pub fn matvec(&self, x: &[E]) -> Vec<E> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                let mut acc = E::ZERO;
                for (&a, &b) in self.row(r).iter().zip(x) {
                    acc += a * b;
                }
                acc
            })
            .collect()
    }
}

impl MatrixG<f64> {
    /// Build an f64 matrix from an f32 buffer (interchange boundary:
    /// checkpoints, HLO buffers, packed containers).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Matrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_f32: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(MatrixG { rows, cols, data: data.iter().map(|&x| x as f64).collect() })
    }

    /// Narrow to an f32 buffer (interchange boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col_copy(1), vec![2., 5.]);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(7, 13, |r, c| (r * 100 + c) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 13);
        assert_eq!(t.get(5, 3), m.get(3, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn slicing() {
        let m = Matrix::from_fn(4, 6, |r, c| (r * 10 + c) as f64);
        let s = m.slice_cols(2, 5);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.get(1, 0), 12.0);
        let rs = m.slice_rows(1, 3);
        assert_eq!(rs.rows(), 2);
        assert_eq!(rs.get(0, 0), 10.0);
    }

    #[test]
    fn set_cols_writes_block() {
        let mut m = Matrix::zeros(2, 4);
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        m.set_cols(1, &b);
        assert_eq!(m.row(0), &[0., 1., 2., 0.]);
        assert_eq!(m.row(1), &[0., 3., 4., 0.]);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]).unwrap();
        let d = a.sub(&b);
        assert_eq!(d.get(0, 0), 0.5);
        assert!((a.frob_norm_sq() - 30.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
        assert_eq!(a.mean(), 2.5);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let y = m.matvec(&[1.0, 0.5, -1.0]);
        assert_eq!(y, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_f32(1, 3, &[1.5f32, -2.25, 0.0]).unwrap();
        assert_eq!(m.to_f32(), vec![1.5f32, -2.25, 0.0]);
    }

    #[test]
    fn generic_f32_matrix_basics() {
        let m = Matrix32::from_fn(3, 3, |r, c| (r * 3 + c) as f32);
        assert_eq!(m.get(2, 1), 7.0f32);
        let i = Matrix32::identity(3);
        assert_eq!(i.get(1, 1), 1.0f32);
        assert_eq!(i.get(0, 1), 0.0f32);
        let t = m.transpose();
        assert_eq!(t.get(1, 2), m.get(2, 1));
    }

    #[test]
    fn convert_roundtrips_f32_values() {
        // f32 -> f64 -> f32 must be lossless
        let m = Matrix32::from_fn(4, 5, |r, c| (r as f32 + 0.25) * (c as f32 - 1.5));
        let wide: Matrix = m.convert();
        let back: Matrix32 = wide.convert();
        assert_eq!(m, back);
    }

    #[test]
    fn convert_narrowing_rounds() {
        let m = Matrix::from_vec(1, 1, vec![0.1]).unwrap();
        let narrow: Matrix32 = m.convert();
        assert_eq!(narrow.get(0, 0), 0.1f32);
        // narrowing then widening shows the representation gap
        let wide: Matrix = narrow.convert();
        assert!((wide.get(0, 0) - 0.1).abs() < 1e-8);
        assert!(wide.get(0, 0) != 0.1 || 0.1f32 as f64 == 0.1);
    }
}
