//! Launcher configuration: a minimal `--key value` CLI parser plus
//! `key=value` config-file loading with CLI override — the config system
//! behind the `gptvq` binary (no clap offline).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand, positionals, and `--key value` /
/// `--key=value` / bare `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Cli {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(args: &[String]) -> Cli {
        let mut cli = Cli::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    cli.options.insert(stripped.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    cli.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if cli.command.is_none() {
                cli.command = Some(a.clone());
            } else {
                cli.positional.push(a.clone());
            }
            i += 1;
        }
        cli
    }

    /// Merge a `key=value` config file under the CLI (CLI wins).
    pub fn load_config_file(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let text = std::fs::read_to_string(path.as_ref())?;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                self.options.entry(k.trim().to_string()).or_insert_with(|| v.trim().to_string());
            }
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::Config(format!("--{key}: {e}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::Config(format!("--{key}: {e}"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some(v) => v == "true" || v == "1" || v == "yes",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let cli = Cli::parse(&argv(&["quantize", "extra", "--preset", "small", "--d=2", "--verbose"]));
        assert_eq!(cli.command.as_deref(), Some("quantize"));
        assert_eq!(cli.get("preset"), Some("small"));
        assert_eq!(cli.get("d"), Some("2"));
        assert_eq!(cli.get("verbose"), Some("true"));
        assert_eq!(cli.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let cli = Cli::parse(&argv(&["x", "--n", "42", "--f", "2.5", "--b", "yes"]));
        assert_eq!(cli.get_usize("n", 0).unwrap(), 42);
        assert_eq!(cli.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(cli.get_f64("f", 0.0).unwrap(), 2.5);
        assert!(cli.get_bool("b", false));
        assert!(cli.get_usize("f", 0).is_err());
    }

    #[test]
    fn config_file_is_overridden_by_cli() {
        let p = std::env::temp_dir().join(format!("gvq_cfg_{}", std::process::id()));
        std::fs::write(&p, "# comment\npreset=base\nd=4\n").unwrap();
        let mut cli = Cli::parse(&argv(&["quantize", "--preset", "small"]));
        cli.load_config_file(&p).unwrap();
        assert_eq!(cli.get("preset"), Some("small")); // CLI wins
        assert_eq!(cli.get("d"), Some("4")); // file fills the gap
        std::fs::remove_file(p).ok();
    }
}
