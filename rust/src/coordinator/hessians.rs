//! Calibration-driven Hessian collection.
//!
//! One forward pass over the calibration set with the activation hook
//! captures the layerwise `H = 2 E[x x^T]` for every quantizable linear.
//! Inputs to Wq/Wk/Wv are identical (post-ln_attn activations), as are
//! WGate/WUp — the cache shares one estimator per input site to avoid
//! triple-accumulating.
//!
//! Since PR 4 the sequence walk itself is parallel: calibration
//! sequences run their forward passes on pool lanes, each producing its
//! ordered list of per-site `X^T X` products ([`XtxBatch`]), and the
//! coordinator absorbs those partials **in fixed sequence order** — the
//! exact accumulation operations of the serial walk, so the collected
//! Hessians are bitwise identical for every thread count.

use std::collections::HashMap;

use crate::data::tokens::TokenStream;
use crate::model::forward::forward_logits_hook;
use crate::model::{LinearKind, Model};
use crate::quant::{HessianEstimator, XtxBatch};
use crate::tensor::Precision;
use crate::util::{parallel_map, WorkerPool};

/// The shared input site feeding a linear.
fn input_site(kind: LinearKind) -> &'static str {
    match kind {
        LinearKind::Wq | LinearKind::Wk | LinearKind::Wv => "attn_in",
        LinearKind::Wo => "attn_out",
        LinearKind::WGate | LinearKind::WUp => "ffn_in",
        LinearKind::WDown => "ffn_act",
    }
}

/// Per-layer, per-site Hessian estimators.
///
/// Determinism audit (detlint `hash-iter`): the table is `HashMap`-keyed
/// for O(1) hook-path lookups, so its raw iteration order is
/// nondeterministic. Every consumer that *walks* the cache must go
/// through [`HessianCache::sorted_keys`]/[`HessianCache::iter_sorted`];
/// the quantization pipeline itself only uses keyed access
/// ([`HessianCache::get`] per `(layer, LinearKind)`), which is
/// order-free by construction.
#[derive(Debug, Default)]
pub struct HessianCache {
    sites: HashMap<(usize, &'static str), HessianEstimator>,
}

impl HessianCache {
    /// Estimator for a (layer, linear) pair.
    pub fn get(&self, layer: usize, kind: LinearKind) -> Option<&HessianEstimator> {
        self.sites.get(&(layer, input_site(kind)))
    }

    /// Number of (layer, input-site) estimators collected.
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    /// Site keys in deterministic order (layer index, then site name) —
    /// independent of hash seed and insertion order. The only sanctioned
    /// way to enumerate the cache.
    pub fn sorted_keys(&self) -> Vec<(usize, &'static str)> {
        let mut keys: Vec<(usize, &'static str)> = self.sites.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Walk the estimators in [`HessianCache::sorted_keys`] order; any
    /// quantization or reporting sweep over all sites must use this so
    /// downstream output never inherits hash order.
    pub fn iter_sorted(
        &self,
    ) -> impl Iterator<Item = ((usize, &'static str), &HessianEstimator)> + '_ {
        self.sorted_keys().into_iter().map(move |k| (k, &self.sites[&k]))
    }

    /// Fold one site product into its estimator — the single
    /// accumulation step both collection schedules are built from.
    fn absorb_one(&mut self, key: (usize, &'static str), batch: &XtxBatch) {
        let est = self.sites.entry(key).or_insert_with(|| HessianEstimator::new(batch.dim()));
        est.absorb(batch);
    }

    /// Fold one sequence's ordered site products into the cache. Sites
    /// are independent accumulators, so only the per-site order matters
    /// — and callers preserve it by absorbing sequences in index order.
    fn absorb_sequence(&mut self, partial: Vec<((usize, &'static str), XtxBatch)>) {
        for (key, batch) in partial {
            self.absorb_one(key, &batch);
        }
    }
}

/// The shared hook filter: the site a (layer, linear) call contributes
/// to, or `None` when it is out of scope (`only_layer`) or a duplicate
/// of a shared site (Wq fires first for attn_in, WGate for ffn_in).
fn hooked_site(
    only_layer: Option<usize>,
    layer: usize,
    kind: LinearKind,
) -> Option<&'static str> {
    if let Some(l) = only_layer {
        if layer != l {
            return None;
        }
    }
    if matches!(kind, LinearKind::Wk | LinearKind::Wv | LinearKind::WUp) {
        return None;
    }
    Some(input_site(kind))
}

/// One calibration sequence's forward pass: every hooked input site's
/// `x^T x` product at the requested precision, in hook-firing order.
/// Pure with respect to the cache — the products are absorbed later so
/// the accumulation order can be fixed regardless of which lane ran
/// which sequence.
fn sequence_batches(
    model: &Model,
    seq: &[u8],
    only_layer: Option<usize>,
    precision: Precision,
    pool: &WorkerPool,
) -> Vec<((usize, &'static str), XtxBatch)> {
    let mut out: Vec<((usize, &'static str), XtxBatch)> = Vec::new();
    let mut hook = |layer: usize, kind: LinearKind, x: &crate::tensor::Matrix| {
        if let Some(site) = hooked_site(only_layer, layer, kind) {
            out.push(((layer, site), XtxBatch::compute(x, precision, pool)));
        }
    };
    forward_logits_hook(model, seq, Some(&mut hook));
    out
}

/// Run the calibration set through the model (optionally restricted to
/// `only_layer`) and accumulate Hessians at every input site.
/// Standalone-use wrapper around [`collect_hessians_on`].
pub fn collect_hessians(
    model: &Model,
    sequences: &[Vec<u8>],
    only_layer: Option<usize>,
    n_threads: usize,
    precision: Precision,
) -> HessianCache {
    collect_hessians_on(model, sequences, only_layer, &WorkerPool::new(n_threads), precision)
}

/// Cap on the transient memory the windowed sequence fan-out may hold
/// in per-sequence partials (2 GiB). One partial carries an `X^T X`
/// product per hooked site, so in one-shot mode on a large model a
/// window of `n_threads` partials can dwarf the Hessian cache itself;
/// past this budget the walk stays sequence-serial (per-site matmuls
/// still pool-threaded — the pre-PR 4 parallelism). The gate depends
/// only on the model shape, never on timing, and both schedules are
/// bitwise identical, so it is purely a memory/throughput trade.
const PARTIAL_WINDOW_BUDGET_BYTES: usize = 2 << 30;

/// Estimated bytes of one sequence's partial (`X^T X` per hooked site):
/// per layer, three `d_model²` sites (attn_in, attn_out, ffn_in) plus
/// one `d_ffn²` site (ffn_act), in f64.
fn partial_bytes_estimate(model: &Model, only_layer: Option<usize>) -> usize {
    let d = model.cfg.d_model;
    let f = model.cfg.d_ffn;
    let per_layer = 3 * d * d + f * f;
    let layers = if only_layer.is_some() { 1 } else { model.cfg.n_layers };
    layers.saturating_mul(per_layer).saturating_mul(8)
}

/// [`collect_hessians`] on a borrowed [`WorkerPool`].
///
/// Parallelism has two levels, both deterministic: sequences fan across
/// pool lanes in windows of up to `pool.n_threads()` (each forward pass
/// producing per-site [`XtxBatch`] partials, absorbed in fixed sequence
/// order — see [`HessianEstimator::absorb`]), and each per-site
/// `X^T X` product runs on the shared pool matmul path at the requested
/// `precision` ([`Precision::F32`] computes the product in single
/// precision and widens into the f64 master accumulator — the
/// Hessian-collection arm of the CLI's `--precision f32`). The sequence
/// fan-out engages only while a window of partials fits
/// [`PARTIAL_WINDOW_BUDGET_BYTES`]; sequential mode (`only_layer`, 4
/// sites per partial) always fits, which keeps it the memory-lean path
/// on large models. The accumulated Hessians are bitwise identical for
/// any pool width and either schedule.
pub fn collect_hessians_on(
    model: &Model,
    sequences: &[Vec<u8>],
    only_layer: Option<usize>,
    pool: &WorkerPool,
    precision: Precision,
) -> HessianCache {
    let mut cache = HessianCache::default();
    let nt = pool.n_threads();
    let window_bytes = nt.saturating_mul(partial_bytes_estimate(model, only_layer));
    if nt <= 1 || sequences.len() <= 1 || window_bytes > PARTIAL_WINDOW_BUDGET_BYTES {
        // sequence-serial walk: stream each site product straight into
        // its estimator (one product live at a time — the genuinely
        // memory-lean path the budget gate falls back to), with the
        // products themselves still pool-threaded
        for seq in sequences {
            let mut hook = |layer: usize, kind: LinearKind, x: &crate::tensor::Matrix| {
                if let Some(site) = hooked_site(only_layer, layer, kind) {
                    cache.absorb_one((layer, site), &XtxBatch::compute(x, precision, pool));
                }
            };
            forward_logits_hook(model, seq, Some(&mut hook));
        }
        return cache;
    }
    for chunk in sequences.chunks(nt) {
        let partials = parallel_map(pool, nt, chunk.len(), |i| {
            sequence_batches(model, &chunk[i], only_layer, precision, pool)
        });
        // reduction stays in sequence order: parallel_map returns slots
        // by index, so this is the serial walk's accumulation sequence
        for partial in partials {
            cache.absorb_sequence(partial);
        }
    }
    cache
}

/// Convenience: sample calibration sequences and collect in one call.
pub fn collect_from_stream(
    model: &Model,
    stream: &TokenStream,
    n_seq: usize,
    seq_len: usize,
    seed: u64,
) -> HessianCache {
    let seqs = crate::data::tokens::sample_sequences(stream, n_seq, seq_len, seed);
    collect_hessians(model, &seqs, None, 1, Precision::F64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    #[test]
    fn collects_all_sites() {
        let m = tiny_model(31);
        let seqs = vec![(0u8..16).collect::<Vec<u8>>(), (5u8..21).collect()];
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        // 4 sites x 2 layers
        assert_eq!(cache.n_sites(), 8);
        for layer in 0..2 {
            for kind in LinearKind::ALL {
                let est = cache.get(layer, kind).expect("site present");
                assert_eq!(est.n_samples(), 32); // 2 seqs x 16 tokens
                let expected_dim = match kind {
                    LinearKind::WDown => m.cfg.d_ffn,
                    _ => m.cfg.d_model,
                };
                assert_eq!(est.dim(), expected_dim);
            }
        }
    }

    #[test]
    fn parallel_sequence_walk_is_bitwise_identical() {
        // the PR 4 claim: per-sequence partials absorbed in order give
        // exactly the serial walk's Hessians, at any pool width and
        // either precision — including more sequences than lanes
        // (windowed) and fewer (inner matmul threading)
        let m = tiny_model(35);
        let seqs: Vec<Vec<u8>> =
            (0..6).map(|s| (s..s + 20).map(|v| v as u8).collect()).collect();
        for precision in [Precision::F64, Precision::F32] {
            let serial = collect_hessians(&m, &seqs, None, 1, precision);
            for nt in [2, 4, 8] {
                let par = collect_hessians(&m, &seqs, None, nt, precision);
                assert_eq!(par.n_sites(), serial.n_sites(), "{precision:?} {nt}t");
                for layer in 0..2 {
                    for kind in LinearKind::ALL {
                        let a = serial.get(layer, kind).unwrap();
                        let b = par.get(layer, kind).unwrap();
                        assert_eq!(a.n_samples(), b.n_samples());
                        assert_eq!(
                            a.hessian().as_slice(),
                            b.hessian().as_slice(),
                            "{precision:?} {nt}t layer {layer} {kind:?}"
                        );
                    }
                }
            }
            // sequential mode (the ROADMAP item by name): per-layer
            // collection must be parity-clean too
            let serial_l1 = collect_hessians(&m, &seqs, Some(1), 1, precision);
            let par_l1 = collect_hessians(&m, &seqs, Some(1), 4, precision);
            for kind in LinearKind::ALL {
                assert_eq!(
                    serial_l1.get(1, kind).unwrap().hessian().as_slice(),
                    par_l1.get(1, kind).unwrap().hessian().as_slice(),
                    "{precision:?} sequential-mode {kind:?}"
                );
            }
        }
    }

    #[test]
    fn sorted_iteration_is_insertion_order_independent() {
        // the detlint hash-iter audit, pinned: walking the cache through
        // sorted_keys/iter_sorted must give one deterministic sequence
        // regardless of the (hash-order-dependent) insertion history
        let pool = WorkerPool::new(1);
        let x = crate::tensor::Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f64 * 0.25 - 1.0);
        let batch = XtxBatch::compute(&x, Precision::F64, &pool);
        let keys: Vec<(usize, &'static str)> = vec![
            (2, "ffn_in"),
            (0, "attn_in"),
            (1, "attn_out"),
            (0, "ffn_act"),
            (1, "attn_in"),
        ];
        let mut fwd = HessianCache::default();
        for &k in &keys {
            fwd.absorb_one(k, &batch);
        }
        let mut rev = HessianCache::default();
        for &k in keys.iter().rev() {
            rev.absorb_one(k, &batch);
        }
        let want = {
            let mut s = keys.clone();
            s.sort_unstable();
            s
        };
        assert_eq!(fwd.sorted_keys(), want, "sorted (layer, site) order");
        assert_eq!(fwd.sorted_keys(), rev.sorted_keys(), "insertion order must not leak");
        for ((ka, ea), (kb, eb)) in fwd.iter_sorted().zip(rev.iter_sorted()) {
            assert_eq!(ka, kb);
            assert_eq!(ea.n_samples(), eb.n_samples());
            assert_eq!(ea.hessian().as_slice(), eb.hessian().as_slice());
        }
    }

    #[test]
    fn shared_sites_are_shared() {
        let m = tiny_model(32);
        let seqs = vec![(0u8..12).collect::<Vec<u8>>()];
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        let hq = cache.get(0, LinearKind::Wq).unwrap().hessian();
        let hk = cache.get(0, LinearKind::Wk).unwrap().hessian();
        assert_eq!(hq.as_slice(), hk.as_slice());
    }

    #[test]
    fn only_layer_restriction() {
        let m = tiny_model(33);
        let seqs = vec![(0u8..12).collect::<Vec<u8>>()];
        let cache = collect_hessians(&m, &seqs, Some(1), 1, Precision::F64);
        assert_eq!(cache.n_sites(), 4);
        assert!(cache.get(0, LinearKind::Wq).is_none());
        assert!(cache.get(1, LinearKind::Wq).is_some());
    }

    #[test]
    fn hessian_is_usable_for_factorization() {
        let m = tiny_model(34);
        let seqs: Vec<Vec<u8>> = (0..4).map(|s| (s..s + 24).map(|v| v as u8).collect()).collect();
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        let est = cache.get(0, LinearKind::Wo).unwrap();
        let u = est.inverse_factor(0.01).expect("PD after damping");
        assert_eq!(u.rows(), m.cfg.d_model);
    }
}
