//! Calibration-driven Hessian collection.
//!
//! One forward pass over the calibration set with the activation hook
//! captures the layerwise `H = 2 E[x x^T]` for every quantizable linear.
//! Inputs to Wq/Wk/Wv are identical (post-ln_attn activations), as are
//! WGate/WUp — the cache shares one estimator per input site to avoid
//! triple-accumulating.

use std::collections::HashMap;

use crate::data::tokens::TokenStream;
use crate::model::forward::forward_logits_hook;
use crate::model::{LinearKind, Model};
use crate::quant::HessianEstimator;
use crate::tensor::Precision;

/// The shared input site feeding a linear.
fn input_site(kind: LinearKind) -> &'static str {
    match kind {
        LinearKind::Wq | LinearKind::Wk | LinearKind::Wv => "attn_in",
        LinearKind::Wo => "attn_out",
        LinearKind::WGate | LinearKind::WUp => "ffn_in",
        LinearKind::WDown => "ffn_act",
    }
}

/// Per-layer, per-site Hessian estimators.
#[derive(Debug, Default)]
pub struct HessianCache {
    sites: HashMap<(usize, &'static str), HessianEstimator>,
}

impl HessianCache {
    /// Estimator for a (layer, linear) pair.
    pub fn get(&self, layer: usize, kind: LinearKind) -> Option<&HessianEstimator> {
        self.sites.get(&(layer, input_site(kind)))
    }

    /// Number of (layer, input-site) estimators collected.
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }
}

/// Run the calibration set through the model (optionally restricted to
/// `only_layer`) and accumulate Hessians at every input site. The per-site
/// `X^T X` products run on the shared threaded matmul path with
/// `n_threads` workers (sequence order — and thus the accumulated Hessian
/// — is identical for any thread count) at the requested `precision`:
/// [`Precision::F32`] computes each batch product in single precision and
/// widens into the f64 master accumulator (see
/// [`HessianEstimator::update_prec`]), which is the Hessian-collection
/// arm of the CLI's `--precision f32`.
pub fn collect_hessians(
    model: &Model,
    sequences: &[Vec<u8>],
    only_layer: Option<usize>,
    n_threads: usize,
    precision: Precision,
) -> HessianCache {
    let mut cache = HessianCache::default();
    for seq in sequences {
        let mut hook = |layer: usize, kind: LinearKind, x: &crate::tensor::Matrix| {
            if let Some(l) = only_layer {
                if layer != l {
                    return;
                }
            }
            let site = input_site(kind);
            // skip duplicate calls for shared sites (Wq fires first)
            if matches!(kind, LinearKind::Wk | LinearKind::Wv | LinearKind::WUp) {
                return;
            }
            let est = cache
                .sites
                .entry((layer, site))
                .or_insert_with(|| HessianEstimator::new(x.cols()));
            est.update_prec(x, precision, n_threads);
        };
        forward_logits_hook(model, seq, Some(&mut hook));
    }
    cache
}

/// Convenience: sample calibration sequences and collect in one call.
pub fn collect_from_stream(
    model: &Model,
    stream: &TokenStream,
    n_seq: usize,
    seq_len: usize,
    seed: u64,
) -> HessianCache {
    let seqs = crate::data::tokens::sample_sequences(stream, n_seq, seq_len, seed);
    collect_hessians(model, &seqs, None, 1, Precision::F64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    #[test]
    fn collects_all_sites() {
        let m = tiny_model(31);
        let seqs = vec![(0u8..16).collect::<Vec<u8>>(), (5u8..21).collect()];
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        // 4 sites x 2 layers
        assert_eq!(cache.n_sites(), 8);
        for layer in 0..2 {
            for kind in LinearKind::ALL {
                let est = cache.get(layer, kind).expect("site present");
                assert_eq!(est.n_samples(), 32); // 2 seqs x 16 tokens
                let expected_dim = match kind {
                    LinearKind::WDown => m.cfg.d_ffn,
                    _ => m.cfg.d_model,
                };
                assert_eq!(est.dim(), expected_dim);
            }
        }
    }

    #[test]
    fn shared_sites_are_shared() {
        let m = tiny_model(32);
        let seqs = vec![(0u8..12).collect::<Vec<u8>>()];
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        let hq = cache.get(0, LinearKind::Wq).unwrap().hessian();
        let hk = cache.get(0, LinearKind::Wk).unwrap().hessian();
        assert_eq!(hq.as_slice(), hk.as_slice());
    }

    #[test]
    fn only_layer_restriction() {
        let m = tiny_model(33);
        let seqs = vec![(0u8..12).collect::<Vec<u8>>()];
        let cache = collect_hessians(&m, &seqs, Some(1), 1, Precision::F64);
        assert_eq!(cache.n_sites(), 4);
        assert!(cache.get(0, LinearKind::Wq).is_none());
        assert!(cache.get(1, LinearKind::Wq).is_some());
    }

    #[test]
    fn hessian_is_usable_for_factorization() {
        let m = tiny_model(34);
        let seqs: Vec<Vec<u8>> = (0..4).map(|s| (s..s + 24).map(|v| v as u8).collect()).collect();
        let cache = collect_hessians(&m, &seqs, None, crate::util::test_threads(), Precision::F64);
        let est = cache.get(0, LinearKind::Wo).unwrap();
        let u = est.inverse_factor(0.01).expect("PD after damping");
        assert_eq!(u.rows(), m.cfg.d_model);
    }
}
