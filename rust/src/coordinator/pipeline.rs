//! The quantization pipeline: method dispatch + block-sequential sweep +
//! worker fan-out + container packing.
//!
//! Weights are stored `[in, out]` in the model; quantization methods use
//! the paper layout `[out, in]` (Hessian over inputs). This module owns
//! that transpose boundary.

use std::sync::Mutex;

use crate::coordinator::hessians::{collect_hessians_on, HessianCache};
use crate::coordinator::metrics::PipelineMetrics;
use crate::data::tokens::{sample_sequences, TokenStream};
use crate::error::{Error, Result};
use crate::model::{LinearKind, Model};
use crate::quant::gptq::gptq_quantize;
use crate::quant::gptvq::{gptvq_quantize, gptvq_quantize_on, GptvqConfig};
use crate::quant::kmeans::kmeans_vq_quantize;
use crate::quant::uniform::rtn_quantize;
use crate::quant::vq::update::recon_loss_on;
use crate::quant::HessianEstimator;
use crate::tensor::{Matrix, Precision};
use crate::util::WorkerPool;
use crate::vqformat::{pack_groups, VqModel};

/// Quantization method selector (the rows of Tables 1/2/4).
#[derive(Debug, Clone)]
pub enum Method {
    /// Round-to-nearest uniform (no data)
    Rtn { bits: u32, group_size: usize },
    /// GPTQ uniform with error feedback
    Gptq { bits: u32, group_size: usize },
    /// the paper's method
    Gptvq(GptvqConfig),
    /// k-means VQ baseline (Table 1); `data_aware` weights by diag(H)
    Kmeans { d: usize, k: usize, group_size: usize, data_aware: bool, iters: usize },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Rtn { bits, group_size } => format!("RTN W{bits}@g{group_size}"),
            Method::Gptq { bits, group_size } => format!("GPTQ W{bits}@g{group_size}"),
            Method::Gptvq(c) => format!("GPTVQ {}D {}b", c.d, c.bits_per_dim),
            Method::Kmeans { d, k, data_aware, .. } => {
                format!("kmeans {}D k{}{}", d, k, if *data_aware { "+data" } else { "" })
            }
        }
    }
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub method: Method,
    /// calibration sequences (paper: 128 of 2048 tokens; scaled here)
    pub calib_sequences: usize,
    pub calib_seq_len: usize,
    pub calib_seed: u64,
    /// re-collect activations block by block through the already-quantized
    /// prefix (GPTQ's sequential mode) vs one FP pass for all layers
    pub sequential: bool,
    pub damp: f64,
    /// worker threads: fans out over the linears of a block, feeds the
    /// Hessian-collection matmuls, and is inherited as the in-matrix
    /// thread count by GPTVQ when `GptvqConfig::n_threads == 0`. The
    /// budget is split between those levels, never multiplied. 0 = all
    /// cores. Results are bitwise identical for every value.
    pub n_threads: usize,
    /// compute width of the whole pipeline: the Hessian-collection
    /// matmuls (`X^T X`) and the in-matrix GPTVQ engine (it overrides
    /// `GptvqConfig::precision` inside the pipeline, so this is the one
    /// knob behind the CLI `--precision` flag). Damping, Cholesky, and
    /// all reported losses always run in f64.
    pub precision: Precision,
}

impl PipelineConfig {
    pub fn new(method: Method) -> Self {
        PipelineConfig {
            method,
            calib_sequences: 32,
            calib_seq_len: 128,
            calib_seed: 0xCA11B,
            sequential: false,
            damp: 0.01,
            n_threads: 1,
            precision: Precision::F64,
        }
    }
}

/// Per-layer quantization record.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    pub recon_loss: f64,
    pub effective_bpv: f64,
    pub seconds: f64,
}

/// Full pipeline outcome.
#[derive(Debug)]
pub struct PipelineReport {
    pub method: String,
    pub layers: Vec<LayerRecord>,
    pub metrics: PipelineMetrics,
    pub total_weights: usize,
    /// packed container (populated for VQ methods)
    pub vq_model: Option<VqModel>,
}

impl PipelineReport {
    pub fn weights_per_second(&self) -> f64 {
        let quant_secs = self.metrics.seconds("quantize");
        if quant_secs > 0.0 {
            self.total_weights as f64 / quant_secs
        } else {
            0.0
        }
    }

    pub fn mean_effective_bpv(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.effective_bpv).sum::<f64>() / self.layers.len() as f64
    }
}

/// Quantize one weight matrix (storage layout [in, out]) with a method.
/// Returns (new storage-layout weights, recon loss, effective bpv, groups
/// for packing when VQ).
///
/// `pool` is this job's persistent worker pool — sized by
/// [`quantize_model`] to its share of the pipeline's thread budget and
/// reused across every layer the job processes. The GPTVQ arm runs the
/// engine on it when the method config says "inherit"
/// (`GptvqConfig::n_threads == 0`); an explicit nonzero `n_threads`
/// keeps its own dedicated pool per invocation, preserving the
/// historical override semantics. `precision` is the pipeline-level
/// compute width and overrides `GptvqConfig::precision` inside the
/// pipeline, so one knob governs collection and engine alike.
fn quantize_one(
    w_storage: &Matrix,
    est: &HessianEstimator,
    method: &Method,
    damp: f64,
    pool: &WorkerPool,
    precision: Precision,
) -> Result<(Matrix, f64, f64, Option<(usize, usize, Vec<crate::quant::vq::VqGroup>)>)> {
    let w = w_storage.transpose(); // paper layout [out, in]
    // the GPTVQ arm derives *both* `u` and the loss/update Hessian from
    // the method's own damp — mixing PipelineConfig::damp into `h` made
    // the sweep and the codebook update optimize different objectives
    // whenever the two settings diverged
    let h = match method {
        Method::Gptvq(cfg) => est.dampened(cfg.damp),
        _ => est.dampened(damp),
    };
    match method {
        Method::Rtn { bits, group_size } => {
            let q = rtn_quantize(&w, *bits, *group_size).dequantize();
            let loss = recon_loss_on(&w, &q, &h, pool);
            let bpv = *bits as f64 + 16.0 / *group_size as f64;
            Ok((q.transpose(), loss, bpv, None))
        }
        Method::Gptq { bits, group_size } => {
            let u = est.inverse_factor(damp)?;
            let res = gptq_quantize(&w, &u, *bits, *group_size, 128);
            let loss = recon_loss_on(&w, &res.qweight, &h, pool);
            Ok((res.qweight.transpose(), loss, res.bits_per_value(), None))
        }
        Method::Gptvq(cfg) => {
            let u = est.inverse_factor(cfg.damp)?;
            let mut cfg = cfg.clone();
            cfg.precision = precision;
            let res = if cfg.n_threads == 0 {
                cfg.n_threads = pool.n_threads();
                gptvq_quantize_on(&w, &u, &h, &cfg, pool)?
            } else {
                gptvq_quantize(&w, &u, &h, &cfg)?
            };
            let loss = res.stats.loss_after_update;
            let bpv = res.effective_bpv;
            let pack = (cfg.d, cfg.k(), res.groups);
            Ok((res.qweight.transpose(), loss, bpv, Some(pack)))
        }
        Method::Kmeans { d, k, group_size, data_aware, iters } => {
            let href = if *data_aware { Some(&h) } else { None };
            let q = kmeans_vq_quantize(&w, *d, *k, *group_size, 256, href, *iters, 0);
            let loss = recon_loss_on(&w, &q, &h, pool);
            let bpv = (*k as f64).log2() / *d as f64
                + (*k * *d * 8) as f64 / *group_size as f64;
            Ok((q.transpose(), loss, bpv, None))
        }
    }
}

/// Run the full pipeline, mutating `model` in place (weights replaced by
/// their quantized versions) and returning the report.
pub fn quantize_model(
    model: &mut Model,
    stream: &TokenStream,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    let mut metrics = PipelineMetrics::new();
    let seqs = sample_sequences(stream, cfg.calib_sequences, cfg.calib_seq_len, cfg.calib_seed);
    // one normalization for every phase: 0 = all cores (same convention
    // as GptvqConfig::n_threads and the CLI --threads default)
    let n_threads = crate::util::effective_threads(cfg.n_threads);

    // persistent worker pools, created once for the whole run instead of
    // re-deriving and re-spawning workers per layer: a full-width pool
    // for calibration (sequences fan across it) and one pool per
    // concurrent quantization job, splitting the budget between the two
    // nesting levels (jobs × inner = n_threads, never multiplied).
    // Workers spawn lazily, so an inline-sized pool costs nothing.
    let calib_pool = WorkerPool::new(n_threads);
    let concurrent_jobs = n_threads.min(LinearKind::ALL.len()).max(1);
    let inner_threads = (n_threads / concurrent_jobs).max(1);
    let job_pools: Vec<WorkerPool> =
        (0..concurrent_jobs).map(|_| WorkerPool::new(inner_threads)).collect();

    // one-shot Hessian collection unless sequential
    let mut cache: Option<HessianCache> = None;
    if !cfg.sequential {
        cache = Some(metrics.stage("calibration", || {
            collect_hessians_on(model, &seqs, None, &calib_pool, cfg.precision)
        }));
    }

    let mut layers: Vec<LayerRecord> = Vec::new();
    let mut vq_model = VqModel::default();
    let mut total_weights = 0usize;
    let n_layers = model.cfg.n_layers;

    for layer in 0..n_layers {
        let layer_cache;
        let cache_ref = if cfg.sequential {
            layer_cache = metrics.stage("calibration", || {
                collect_hessians_on(model, &seqs, Some(layer), &calib_pool, cfg.precision)
            });
            &layer_cache
        } else {
            cache.as_ref().unwrap()
        };

        // fan the 7 linears of this block across worker threads; jobs
        // carry their LinearKind::ALL index so completion order never
        // leaks into the report
        let jobs: Vec<(usize, LinearKind, Matrix, &HessianEstimator)> = LinearKind::ALL
            .iter()
            .enumerate()
            .map(|(idx, &kind)| {
                let est = cache_ref
                    .get(layer, kind)
                    .ok_or_else(|| Error::msg(format!("no hessian for layer {layer} {kind:?}")))?;
                Ok((idx, kind, model.linear(layer, kind).clone(), est))
            })
            .collect::<Result<_>>()?;

        let results: Mutex<Vec<(usize, LinearKind, Matrix, f64, f64, f64, Option<_>)>> =
            Mutex::new(Vec::new());
        // detlint: allow(wall-clock, layer wall-time is reported in metrics only and never steers the schedule)
        let t_quant = std::time::Instant::now();
        // the budget split between the two nesting levels (jobs × inner)
        // is baked into `job_pools`, created once before the layer loop;
        // each coordinator thread here only orchestrates its chunk — the
        // compute runs on its chunk's persistent pool, so no workers are
        // re-spawned per layer. Results are bitwise identical either way.
        std::thread::scope(|scope| -> Result<()> {
            let chunks: Vec<Vec<&(usize, LinearKind, Matrix, &HessianEstimator)>> = {
                let mut cs: Vec<Vec<&(usize, LinearKind, Matrix, &HessianEstimator)>> =
                    (0..concurrent_jobs).map(|_| Vec::new()).collect();
                for (i, job) in jobs.iter().enumerate() {
                    cs[i % concurrent_jobs].push(job);
                }
                cs
            };
            let mut handles = Vec::new();
            for (ci, chunk) in chunks.into_iter().enumerate() {
                let results = &results;
                let method = &cfg.method;
                let damp = cfg.damp;
                let precision = cfg.precision;
                let pool = &job_pools[ci];
                handles.push(scope.spawn(move || -> Result<()> {
                    for (idx, kind, w, est) in chunk {
                        // detlint: allow(wall-clock, per-linear quantize seconds annotate the report; results never depend on them)
                        let t = std::time::Instant::now();
                        let (q, loss, bpv, pack) =
                            quantize_one(w, est, method, damp, pool, precision)?;
                        let secs = t.elapsed().as_secs_f64();
                        results.lock().unwrap().push((*idx, *kind, q, loss, bpv, secs, pack));
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| Error::msg("worker panicked"))??;
            }
            Ok(())
        })?;
        metrics.add_seconds("quantize", t_quant.elapsed().as_secs_f64());

        // workers finish in arbitrary order; restore the canonical
        // LinearKind enumeration so reports and containers are stable
        // across runs and thread counts
        let mut layer_results = results.into_inner().unwrap();
        layer_results.sort_by_key(|r| r.0);
        for (_idx, kind, q, loss, bpv, secs, pack) in layer_results {
            let name = Model::linear_name(layer, kind);
            total_weights += q.rows() * q.cols();
            if let Some((d, k, groups)) = pack {
                let (rows, cols) = (q.cols(), q.rows()); // paper layout dims
                vq_model.linears.insert(name.clone(), pack_groups(rows, cols, d, k, &groups));
            }
            model.set_linear(layer, kind, q);
            layers.push(LayerRecord { name, recon_loss: loss, effective_bpv: bpv, seconds: secs });
            metrics.incr("linears_quantized", 1);
        }
        metrics.incr("blocks_done", 1);
    }

    // dense residuals into the container (only meaningful for VQ methods)
    let has_vq = !vq_model.linears.is_empty();
    if has_vq {
        vq_model.dense.insert(
            "embed".into(),
            (vec![model.embed.rows(), model.embed.cols()], model.embed.to_f32()),
        );
        vq_model.dense.insert(
            "head".into(),
            (vec![model.head.rows(), model.head.cols()], model.head.to_f32()),
        );
        vq_model.dense.insert(
            "final_norm".into(),
            // detlint: allow(precision-cast, HLO artifact stores dense tensors as f32 by format)
            (vec![model.final_norm.len()], model.final_norm.iter().map(|&v| v as f32).collect()),
        );
        for (i, l) in model.layers.iter().enumerate() {
            vq_model.dense.insert(
                format!("layers.{i}.ln_attn"),
                // detlint: allow(precision-cast, HLO artifact stores dense tensors as f32 by format)
                (vec![l.ln_attn.len()], l.ln_attn.iter().map(|&v| v as f32).collect()),
            );
            vq_model.dense.insert(
                format!("layers.{i}.ln_ffn"),
                // detlint: allow(precision-cast, HLO artifact stores dense tensors as f32 by format)
                (vec![l.ln_ffn.len()], l.ln_ffn.iter().map(|&v| v as f32).collect()),
            );
        }
    }

    Ok(PipelineReport {
        method: cfg.method.name(),
        layers,
        metrics,
        total_weights,
        vq_model: if has_vq { Some(vq_model) } else { None },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokens::synthetic_stream;
    use crate::eval::perplexity;
    use crate::model::forward::tests::tiny_model;

    fn fast_pipeline(method: Method) -> PipelineConfig {
        let mut cfg = PipelineConfig::new(method);
        cfg.calib_sequences = 4;
        cfg.calib_seq_len = 24;
        // CI runs the suite once with GPTVQ_TEST_THREADS=4 to push every
        // pipeline test through the parallel paths
        cfg.n_threads = crate::util::test_threads();
        cfg
    }

    fn fast_gptvq() -> GptvqConfig {
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 10;
        g.update_iters = 3;
        g.group_size = 256;
        g.n_threads = 0; // inherit the pipeline's thread count
        g
    }

    #[test]
    fn rtn_pipeline_runs() {
        let mut m = tiny_model(41);
        let s = synthetic_stream(4_000, 1);
        let rep =
            quantize_model(&mut m, &s, &fast_pipeline(Method::Rtn { bits: 4, group_size: 16 }))
                .unwrap();
        assert_eq!(rep.layers.len(), 2 * 7);
        assert!(rep.total_weights > 0);
        assert!(rep.vq_model.is_none());
        assert!(rep.weights_per_second() > 0.0);
    }

    #[test]
    fn gptvq_pipeline_produces_container_and_consistent_weights() {
        let mut m = tiny_model(42);
        let orig = m.clone();
        let s = synthetic_stream(4_000, 2);
        let rep =
            quantize_model(&mut m, &s, &fast_pipeline(Method::Gptvq(fast_gptvq()))).unwrap();
        let vq = rep.vq_model.expect("container");
        assert_eq!(vq.linears.len(), 2 * 7);
        // container decodes to exactly the weights installed in the model
        let lin = &vq.linears["layers.0.attn.wq"];
        let decoded = lin.decode(); // paper layout [out, in]
        let installed = m.linear(0, crate::model::LinearKind::Wq); // [in, out]
        let diff = decoded.transpose().sub(installed).max_abs();
        assert!(diff < 1e-6, "container/model divergence {diff}");
        // weights actually changed
        assert!(orig.linear(0, crate::model::LinearKind::Wq) != installed);
    }

    #[test]
    fn gptq_beats_rtn_on_quantized_ppl() {
        // the canonical sanity: error feedback should not be worse at
        // equal bits (tiny random-ish model, loose check on recon loss)
        let s = synthetic_stream(6_000, 3);
        let mut m_rtn = tiny_model(43);
        let rep_rtn = quantize_model(
            &mut m_rtn,
            &s,
            &fast_pipeline(Method::Rtn { bits: 2, group_size: 16 }),
        )
        .unwrap();
        let mut m_gptq = tiny_model(43);
        let rep_gptq = quantize_model(
            &mut m_gptq,
            &s,
            &fast_pipeline(Method::Gptq { bits: 2, group_size: 16 }),
        )
        .unwrap();
        let loss_rtn: f64 = rep_rtn.layers.iter().map(|l| l.recon_loss).sum();
        let loss_gptq: f64 = rep_gptq.layers.iter().map(|l| l.recon_loss).sum();
        assert!(loss_gptq <= loss_rtn * 1.01, "gptq {loss_gptq} vs rtn {loss_rtn}");
    }

    #[test]
    fn sequential_mode_runs() {
        let mut m = tiny_model(44);
        let s = synthetic_stream(4_000, 4);
        let mut cfg = fast_pipeline(Method::Gptq { bits: 3, group_size: 16 });
        cfg.sequential = true;
        let rep = quantize_model(&mut m, &s, &cfg).unwrap();
        assert_eq!(rep.layers.len(), 14);
        assert!(rep.metrics.seconds("calibration") > 0.0);
    }

    #[test]
    fn threaded_matches_single_threaded() {
        // 1 vs 4 threads at both levels (linear fan-out AND the in-matrix
        // engine, which inherits via n_threads == 0): bitwise-equal
        // quantized weights and identical report ordering
        let s = synthetic_stream(4_000, 5);
        let mut m1 = tiny_model(45);
        let mut cfg = fast_pipeline(Method::Gptvq(fast_gptvq()));
        cfg.n_threads = 1;
        let rep1 = quantize_model(&mut m1, &s, &cfg).unwrap();
        let mut m4 = tiny_model(45);
        cfg.n_threads = 4;
        let rep4 = quantize_model(&mut m4, &s, &cfg).unwrap();
        for layer in 0..2 {
            for kind in crate::model::LinearKind::ALL {
                let a = m1.linear(layer, kind);
                let b = m4.linear(layer, kind);
                assert_eq!(a, b, "layer {layer} {kind:?} differs across thread counts");
            }
        }
        let names1: Vec<&str> = rep1.layers.iter().map(|l| l.name.as_str()).collect();
        let names4: Vec<&str> = rep4.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names1, names4, "report ordering must not depend on thread count");
        for (a, b) in rep1.layers.iter().zip(&rep4.layers) {
            assert_eq!(a.recon_loss, b.recon_loss, "{}", a.name);
            assert_eq!(a.effective_bpv, b.effective_bpv, "{}", a.name);
        }
    }

    #[test]
    fn layer_records_follow_canonical_order() {
        // regression: completion-order pushes made reports nondeterministic
        // under threading; records must enumerate LinearKind::ALL per layer
        let s = synthetic_stream(4_000, 8);
        let mut m = tiny_model(48);
        let mut cfg = fast_pipeline(Method::Rtn { bits: 4, group_size: 16 });
        cfg.n_threads = 4;
        let rep = quantize_model(&mut m, &s, &cfg).unwrap();
        let want: Vec<String> = (0..2)
            .flat_map(|l| {
                crate::model::LinearKind::ALL.iter().map(move |&k| Model::linear_name(l, k))
            })
            .collect();
        let got: Vec<String> = rep.layers.iter().map(|r| r.name.clone()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn gptvq_damp_comes_from_method_config() {
        // regression: the pipeline dampened `h` with PipelineConfig::damp
        // but factored `u` with GptvqConfig::damp — when the two differed,
        // the sweep and the loss/codebook-update disagreed on the Hessian.
        // With the fix, pipeline damp is irrelevant to the GPTVQ arm.
        let s = synthetic_stream(4_000, 7);
        let mut cfg = fast_pipeline(Method::Gptvq(fast_gptvq()));
        cfg.damp = 1.0; // absurd pipeline-level damp; method damp is 0.01
        let mut m_a = tiny_model(47);
        let rep_a = quantize_model(&mut m_a, &s, &cfg).unwrap();
        cfg.damp = 0.01;
        let mut m_b = tiny_model(47);
        let rep_b = quantize_model(&mut m_b, &s, &cfg).unwrap();
        for kind in crate::model::LinearKind::ALL {
            assert_eq!(m_a.linear(0, kind), m_b.linear(0, kind), "{kind:?}");
        }
        for (a, b) in rep_a.layers.iter().zip(&rep_b.layers) {
            assert_eq!(a.recon_loss, b.recon_loss, "{}", a.name);
        }
    }

    #[test]
    fn f32_pipeline_perplexity_tracks_f64_within_guardrail() {
        // the end-to-end accuracy guardrail of `--precision f32`: quantize
        // the same tiny model at both widths and compare the perplexity
        // proxy plus per-layer recon losses against the pinned tolerance
        let s = synthetic_stream(6_000, 9);
        let run = |precision: Precision| {
            let mut g = fast_gptvq();
            g.precision = precision;
            let mut cfg = fast_pipeline(Method::Gptvq(g));
            cfg.precision = precision;
            let mut m = tiny_model(49);
            let rep = quantize_model(&mut m, &s, &cfg).unwrap();
            (perplexity(&m, &s, 2, 24).ppl, rep)
        };
        let (ppl64, rep64) = run(Precision::F64);
        let (ppl32, rep32) = run(Precision::F32);
        assert!(ppl32.is_finite() && ppl32 > 1.0);
        let tol = crate::quant::gptvq::F32_LOSS_REL_TOL;
        // perplexity compounds per-layer differences through the forward
        // pass, so its guardrail is twice the per-layer loss tolerance
        let ppl_rel = (ppl64 - ppl32).abs() / ppl64;
        assert!(ppl_rel <= 2.0 * tol, "f32 ppl {ppl32} drifted {ppl_rel:.4} rel from f64 {ppl64}");
        let (l64, l32): (f64, f64) = (
            rep64.layers.iter().map(|l| l.recon_loss).sum(),
            rep32.layers.iter().map(|l| l.recon_loss).sum(),
        );
        let loss_rel = (l64 - l32).abs() / (1e-12 + l64.abs());
        assert!(loss_rel <= tol, "f32 total loss {l32} drifted {loss_rel:.4} rel from f64 {l64}");
    }

    #[test]
    fn quantized_model_still_evaluates() {
        let mut m = tiny_model(46);
        let s = synthetic_stream(6_000, 6);
        quantize_model(&mut m, &s, &fast_pipeline(Method::Gptvq(fast_gptvq()))).unwrap();
        let rep = perplexity(&m, &s, 2, 24);
        assert!(rep.ppl.is_finite() && rep.ppl > 1.0);
    }
}
