//! L3 coordinator: the quantization pipeline that turns a trained FP
//! checkpoint + calibration corpus into a quantized model.
//!
//! Responsibilities (DESIGN.md §2): calibration streaming and per-layer
//! Hessian accumulation, method dispatch (RTN / GPTQ / GPTVQ / k-means
//! baselines), worker-thread fan-out across the linears of a block,
//! progress metrics, and packing the result into the GVQMODL1 container.

pub mod hessians;
pub mod metrics;
pub mod pipeline;

pub use hessians::{collect_hessians, collect_hessians_on, HessianCache};
pub use metrics::PipelineMetrics;
pub use pipeline::{quantize_model, Method, PipelineConfig, PipelineReport};
