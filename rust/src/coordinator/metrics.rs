//! Pipeline metrics: per-stage wall time and per-layer quantization
//! statistics, printed as the coordinator's progress report.

use std::collections::BTreeMap;
use std::time::Instant;

/// Aggregated pipeline metrics.
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    stage_seconds: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl PipelineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a named stage (accumulates across calls).
    pub fn stage<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        *self.stage_seconds.entry(name.to_string()).or_default() += t.elapsed().as_secs_f64();
        out
    }

    pub fn add_seconds(&mut self, name: &str, secs: f64) {
        *self.stage_seconds.entry(name.to_string()).or_default() += secs;
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.stage_seconds.get(name).copied().unwrap_or(0.0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn total_seconds(&self) -> f64 {
        self.stage_seconds.values().sum()
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut out = String::from("pipeline metrics:\n");
        for (name, secs) in &self.stage_seconds {
            out.push_str(&format!("  {name:<24} {secs:>9.2}s\n"));
        }
        for (name, v) in &self.counters {
            out.push_str(&format!("  {name:<24} {v:>9}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_accumulate() {
        let mut m = PipelineMetrics::new();
        let v = m.stage("work", || 42);
        assert_eq!(v, 42);
        m.add_seconds("work", 1.5);
        assert!(m.seconds("work") >= 1.5);
        m.incr("layers", 3);
        m.incr("layers", 4);
        assert_eq!(m.counter("layers"), 7);
        assert!(m.render().contains("work"));
    }

    #[test]
    fn unknown_names_are_zero() {
        let m = PipelineMetrics::new();
        assert_eq!(m.seconds("nope"), 0.0);
        assert_eq!(m.counter("nope"), 0);
    }
}
