//! `gptvq` — the launcher CLI.
//!
//! Subcommands:
//!   quantize    quantize a trained checkpoint (RTN/GPTQ/GPTVQ/kmeans),
//!               report perplexity before/after, optionally save GVQMODL1
//!   eval        perplexity + zero-shot probes of an FP or packed model
//!   sqnr        Figure-2 style SQNR analysis across quantizer dims
//!   serve       Engine-scheduled continuous-batched generation over a
//!               packed model (--backend dense|fused-vq selects decoded
//!               weights or the fused LUT decode-matmul path; --policy
//!               fifo|round-robin|shortest picks the scheduler;
//!               --spec-draft K enables speculative multi-token decode;
//!               --step-budget N caps slots decoded per step;
//!               --step-mode batched|per-slot picks one ragged batched
//!               forward per step vs the reference per-slot loop;
//!               --prefill-chunk N admits long prompts in N-token slices;
//!               --queue-cap N bounds the admission queue (0 = unbounded),
//!               --deadline-steps N expires requests after N engine steps,
//!               --kv-page N pools slot KV into shared pages of N rows
//!               (0 = contiguous per-slot caches), --kv-pages N bounds
//!               the arena (0 = unbounded; sheds with KvExhausted),
//!               --kv-store f64|int8 picks dense or group-quantized pages,
//!               --loadgen replaces the fixed prompt set with a seeded
//!               open-loop Poisson/heavy-tail traffic generator:
//!               --arrival-rate R --loadgen-seed S --loadgen-requests N
//!               --burst-every/--burst-len/--burst-mult shape bursts,
//!               --slo-ttft-steps N sets the TTFT SLO target)
//!   info        model/artifact inventory
//!
//! Examples:
//!   gptvq quantize --preset small --method gptvq --d 2 --bits 2 --overhead 0.25
//!   gptvq quantize --preset small --threads 8   # parallel engine; same output
//!   gptvq quantize --preset small --precision f32  # f32 hot loops, f64 accounting
//!   gptvq eval --preset small
//!   gptvq serve --preset small --model out.gvq --requests 8 --backend fused-vq

use gptvq::config::Cli;
use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::read_tokens;
use gptvq::error::{Error, Result};
use gptvq::eval::{evaluate_task, load_task, perplexity, sqnr_model};
use gptvq::model::kvpool::KvStoreKind;
use gptvq::model::Model;
use gptvq::quant::bpv::centroids_for;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::quant::vq::seed::SeedMethod;
use gptvq::report::{fmt_f, Table};
use gptvq::serve::{
    generate, model_from_container, offered_tokens_per_step, run_open_loop, DecodePolicy,
    Engine, Fifo, GenRequest, LoadGenConfig, OneToken, RoundRobin, Scheduler, SelfSpeculative,
    ServeBackend, ShortestRemaining, StepMode,
};
use gptvq::tensor::Precision;
use gptvq::vqformat::VqModel;

/// Parse `--precision {f64,f32}` (default f64 — the exact reference path).
fn precision_from_cli(cli: &Cli) -> Result<Precision> {
    cli.get_or("precision", "f64").parse()
}

fn usage() -> ! {
    eprintln!(
        "usage: gptvq <quantize|eval|sqnr|serve|info> [--artifacts DIR] [--preset NAME] ...\n\
         run with a subcommand; see rust/src/main.rs docs for options"
    );
    std::process::exit(2);
}

fn method_from_cli(cli: &Cli) -> Result<Method> {
    let name = cli.get_or("method", "gptvq");
    let bits = cli.get_usize("bits", 2)? as u32;
    let d = cli.get_usize("d", 2)?;
    let overhead = cli.get_f64("overhead", 0.25)?;
    match name.as_str() {
        "rtn" => Ok(Method::Rtn { bits, group_size: cli.get_usize("group-size", 128)? }),
        "gptq" => Ok(Method::Gptq { bits, group_size: cli.get_usize("group-size", 128)? }),
        "kmeans" => Ok(Method::Kmeans {
            d,
            k: centroids_for(d, bits),
            group_size: cli.get_usize("group-size", 2048)?,
            data_aware: cli.get_bool("data-aware", false),
            iters: cli.get_usize("em-iters", 100)?,
        }),
        "gptvq" => {
            let mut cfg = GptvqConfig::for_setting(d, bits, overhead);
            cfg.em_iters = cli.get_usize("em-iters", 100)?;
            cfg.update_iters = cli.get_usize("update-iters", 25)?;
            if let Some(gs) = cli.get("group-size") {
                cfg.group_size = gs.parse().map_err(|e| Error::Config(format!("group-size: {e}")))?;
            }
            if let Some(ns) = cli.get("scale-block") {
                cfg.scale_block =
                    Some(ns.parse().map_err(|e| Error::Config(format!("scale-block: {e}")))?);
            }
            if cli.get_or("seed-method", "mahalanobis") == "kmeans++" {
                cfg.seed_method = SeedMethod::KmeansPlusPlus;
            }
            if cli.get_bool("svd", false) {
                cfg.svd_rank_frac = Some(0.5);
            }
            if cli.get_or("codebook-bits", "8") == "16" {
                cfg.codebook_bits = 16;
            }
            cfg.n_threads = 0; // inherit the pipeline's --threads value
            // --precision governs the in-matrix engine and (below, via
            // PipelineConfig) Hessian collection
            cfg.precision = precision_from_cli(cli)?;
            Ok(Method::Gptvq(cfg))
        }
        other => Err(Error::Config(format!("unknown method {other}"))),
    }
}

fn cmd_quantize(cli: &Cli) -> Result<()> {
    let dir = cli.get_or("artifacts", "artifacts");
    let preset = cli.get_or("preset", "small");
    let mut model = Model::load(&dir, &preset)?;
    let fp_model = model.clone();
    let train = read_tokens(format!("{dir}/corpus_train.bin"))?;
    let valid = read_tokens(format!("{dir}/corpus_valid.bin"))?;

    let method = method_from_cli(cli)?;
    let mut pcfg = PipelineConfig::new(method);
    pcfg.calib_sequences = cli.get_usize("calib-seqs", 32)?;
    pcfg.calib_seq_len = cli.get_usize("calib-len", model.cfg.max_seq)?;
    pcfg.sequential = cli.get_bool("sequential", false);
    // --threads governs the linear fan-out, Hessian collection, and the
    // in-matrix GPTVQ engine; output is bitwise identical for any value.
    // Default: all available cores.
    pcfg.n_threads =
        cli.get_usize("threads", gptvq::util::effective_threads(0))?;
    // --precision f32 runs the quantization hot loops (Hessian X^T X,
    // EM, sweep, codebook-update matmuls) in single precision; Cholesky
    // and reported losses stay f64. Default f64.
    pcfg.precision = precision_from_cli(cli)?;

    let eval_seqs = cli.get_usize("eval-seqs", 16)?;
    let eval_len = model.cfg.max_seq;

    println!("quantizing preset={preset} with {}", pcfg.method.name());
    let report = quantize_model(&mut model, &train, &pcfg)?;
    println!("{}", report.metrics.render());
    println!(
        "quantized {} weights across {} linears at {:.1} weights/s, mean bpv {:.3}",
        report.total_weights,
        report.layers.len(),
        report.weights_per_second(),
        report.mean_effective_bpv()
    );

    let fp_ppl = perplexity(&fp_model, &valid, eval_seqs, eval_len);
    let q_ppl = perplexity(&model, &valid, eval_seqs, eval_len);
    let mut t = Table::new("quantize result", &["model", "ppl", "bpv"]);
    t.row(&["FP32".into(), fmt_f(fp_ppl.ppl), "32".into()]);
    t.row(&[report.method.clone(), fmt_f(q_ppl.ppl), fmt_f(report.mean_effective_bpv())]);
    t.emit("quantize");

    if let Some(out) = cli.get("out") {
        match &report.vq_model {
            Some(vq) => {
                vq.save(out)?;
                println!("wrote packed model to {out}");
            }
            None => println!("--out ignored: method does not produce a VQ container"),
        }
    }
    Ok(())
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let dir = cli.get_or("artifacts", "artifacts");
    let preset = cli.get_or("preset", "small");
    let mut model = Model::load(&dir, &preset)?;
    if let Some(packed) = cli.get("model") {
        let vq = VqModel::load(packed)?;
        model = model_from_container(&model, &vq)?;
        println!("loaded packed model {packed}");
    }
    let valid = read_tokens(format!("{dir}/corpus_valid.bin"))?;
    let rep = perplexity(&model, &valid, cli.get_usize("eval-seqs", 16)?, model.cfg.max_seq);
    println!("perplexity: {:.4} over {} tokens", rep.ppl, rep.tokens_scored);

    let max_items = cli.get_usize("task-items", 50)?;
    let mut t = Table::new("zero-shot probes", &["task", "accuracy"]);
    for name in ["cloze", "pair", "induction"] {
        let path = format!("{dir}/task_{name}.bin");
        if std::path::Path::new(&path).exists() {
            let task = load_task(&path)?;
            let acc = evaluate_task(&model, &task, max_items);
            t.row(&[name.into(), format!("{acc:.3}")]);
        }
    }
    if t.n_rows() > 0 {
        t.emit("eval_tasks");
    }
    Ok(())
}

fn cmd_sqnr(cli: &Cli) -> Result<()> {
    use gptvq::quant::bpv::group_size_for_overhead;
    use gptvq::quant::kmeans::kmeans_vq_quantize;
    use gptvq::quant::uniform::rtn_quantize;

    let dir = cli.get_or("artifacts", "artifacts");
    let preset = cli.get_or("preset", "small");
    let model = Model::load(&dir, &preset)?;

    // Figure 2: pure grid fits at equal 0.25-bpv overhead (the figure
    // isolates representational accuracy; no error feedback here)
    let bits = cli.get_usize("bits", 2)? as u32;
    let mut t = Table::new("SQNR vs quantizer dimensionality (Fig 2)", &["quantizer", "sqnr dB"]);
    let targets = model.quant_targets();
    let layer_subset: Vec<_> = targets.into_iter().take(cli.get_usize("max-layers", 28)?).collect();

    let mut pairs_orig = Vec::new();
    let mut pairs_uni = Vec::new();
    for &(l, k) in &layer_subset {
        let w = model.linear(l, k).transpose();
        let q = rtn_quantize(&w, bits, 64).dequantize();
        pairs_orig.push(w);
        pairs_uni.push(q);
    }
    let refs: Vec<(&_, &_)> = pairs_orig.iter().zip(pairs_uni.iter()).collect();
    t.row(&["uniform".into(), fmt_f(sqnr_model(&refs))]);

    for d in [1usize, 2, 4] {
        let k = centroids_for(d, bits);
        let gs = group_size_for_overhead(d, k, 8, None, 0.25)
            .ok_or_else(|| Error::msg("unreachable overhead"))?;
        let iters = cli.get_usize("em-iters", 40)?;
        let mut pairs_q = Vec::new();
        for &(l, kind) in &layer_subset {
            let w = model.linear(l, kind).transpose();
            pairs_q.push(kmeans_vq_quantize(&w, d, k, gs, 256, None, iters, 0));
        }
        let refs: Vec<(&_, &_)> = pairs_orig.iter().zip(pairs_q.iter()).collect();
        t.row(&[format!("VQ {d}D"), fmt_f(sqnr_model(&refs))]);
    }
    t.emit("sqnr");
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let dir = cli.get_or("artifacts", "artifacts");
    let preset = cli.get_or("preset", "small");
    let model = Model::load(&dir, &preset)?;
    // --backend picks the execution mode for packed models: "dense"
    // decodes the container at load, "fused-vq" runs the LUT
    // decode-matmul straight from packed indices + int8 codebooks.
    let backend_name = cli.get_or("backend", "dense");
    let backend = match (cli.get("model"), backend_name.as_str()) {
        (Some(packed), "fused-vq" | "fused") => ServeBackend::fused(&model, VqModel::load(packed)?),
        (Some(packed), "dense") => ServeBackend::dense_from_container(&model, &VqModel::load(packed)?)?,
        (None, "dense") => ServeBackend::Dense(model),
        (None, "fused-vq" | "fused") => {
            return Err(Error::Config("--backend fused-vq requires --model <packed.gvq>".into()))
        }
        (_, other) => return Err(Error::Config(format!("unknown backend {other}"))),
    };
    // --policy selects admission + per-step slot allocation; schedulers
    // change wall time and tail latency, never the emitted tokens.
    let policy_name = cli.get_or("policy", "fifo");
    let scheduler: Box<dyn Scheduler> = match policy_name.as_str() {
        "fifo" => Box::new(Fifo::new()),
        "round-robin" | "rr" => Box::new(RoundRobin::new()),
        "shortest" | "shortest-remaining" | "srpt" => Box::new(ShortestRemaining::new()),
        other => return Err(Error::Config(format!("unknown --policy {other}"))),
    };
    // --spec-draft K drafts K tokens per step and verifies them in one
    // batched forward; 0 (default) keeps the one-token decode loop.
    let spec_draft = cli.get_usize("spec-draft", 0)?;
    if spec_draft > 0 && matches!(backend, ServeBackend::Dense(_)) {
        // on dense the draft path IS the target path: ~2x FLOPs and a
        // second KV cache per slot for identical output (see the
        // SelfSpeculative docs) — useful for parity checks only
        eprintln!(
            "warning: --spec-draft on the dense backend is the parity harness, not a speed win \
             (the wall-clock win is --backend fused-vq)"
        );
    }
    let decode: Box<dyn DecodePolicy> = if spec_draft > 0 {
        Box::new(SelfSpeculative::new(spec_draft))
    } else {
        Box::new(OneToken::new())
    };
    // --step-mode: "batched" (default) runs every scheduled slot through
    // ONE ragged batched forward per step; "per-slot" is the reference
    // loop (one forward per slot) — identical tokens, more weight passes.
    let step_mode = match cli.get_or("step-mode", "batched").as_str() {
        "batched" => StepMode::Batched,
        "per-slot" | "perslot" => StepMode::PerSlot,
        other => return Err(Error::Config(format!("unknown --step-mode {other}"))),
    };
    let n_requests = cli.get_usize("requests", 4)?;
    let new_tokens = cli.get_usize("new-tokens", 32)?;
    // --deadline-steps N expires a request N engine steps after submit
    // (0 = no deadline); --queue-cap N sheds submits past N queued
    // requests (0 = unbounded, the legacy contract).
    let deadline_steps = cli.get_usize("deadline-steps", 0)?;
    // --kv-page N routes slot KV through a shared paged arena (pages of
    // N rows per layer; 0 = contiguous per-slot caches); --kv-pages N
    // bounds the arena so overload is shed in the page domain
    // (KvExhausted); --kv-store picks the page format: "f64" is bitwise
    // identical to contiguous, "int8" is ≥4× denser with bounded drift.
    let kv_store_name = cli.get_or("kv-store", "f64");
    let kv_store = KvStoreKind::parse(&kv_store_name)
        .ok_or_else(|| Error::Config(format!("unknown --kv-store {kv_store_name} (f64|int8)")))?;
    let backend_label = backend.name();
    let payload_mb = backend.payload_bytes() as f64 / 1e6;
    let mut engine = Engine::new(backend, cli.get_usize("max-batch", 4)?)
        .with_scheduler(scheduler)
        .with_decode(decode)?
        .with_step_budget(cli.get_usize("step-budget", 0)?)
        .with_step_mode(step_mode)
        // --prefill-chunk N admits long prompts in N-token slices across
        // steps (0 = whole-prompt prefill); chunks charge the step budget
        .with_prefill_chunk(cli.get_usize("prefill-chunk", 0)?)
        .with_queue_cap(cli.get_usize("queue-cap", 0)?)
        .with_kv_page(cli.get_usize("kv-page", 0)?)
        .with_kv_pages(cli.get_usize("kv-pages", 0)?)
        .with_kv_store(kv_store);
    let stats = if cli.get_bool("loadgen", false) {
        // Open-loop traffic: seeded Poisson arrivals with heavy-tailed
        // lengths keep submitting regardless of completions, so overload
        // behaviour (shedding, expiry, goodput) is actually exercised.
        let lg = LoadGenConfig {
            seed: cli.get_usize("loadgen-seed", 7)? as u64,
            rate: cli.get_f64("arrival-rate", 0.5)?,
            requests: cli.get_usize("loadgen-requests", n_requests.max(16))?,
            burst_every: cli.get_usize("burst-every", 64)? as u64,
            burst_len: cli.get_usize("burst-len", 16)? as u64,
            burst_mult: cli.get_f64("burst-mult", 4.0)?,
            deadline_steps,
            ..LoadGenConfig::default()
        };
        let arrivals = generate(&lg);
        println!(
            "loadgen: {} requests at rate {:.2}/step (seed {}), offered {:.2} tokens/step",
            arrivals.len(),
            lg.rate,
            lg.seed,
            offered_tokens_per_step(&arrivals),
        );
        run_open_loop(&mut engine, &arrivals)?
    } else {
        let prompts = ["The man went to", "Every child and", "This important work", "A good day"];
        for id in 0..n_requests {
            let req = GenRequest::new(
                id as u64,
                prompts[id % prompts.len()].as_bytes().to_vec(),
                new_tokens,
            )
            .with_deadline_steps(deadline_steps);
            engine.submit(req)?;
        }
        engine.run_to_completion()?
    };
    println!(
        "served {} requests ({} backend, {} scheduler, {} decode, {:.2} MB payload), \
         {} tokens in {:.2}s — {:.1} tok/s, {:.2} tokens/step",
        stats.requests,
        backend_label,
        engine.scheduler_name(),
        engine.policy_name(),
        payload_mb,
        stats.total_tokens,
        stats.total_seconds,
        stats.tokens_per_second(),
        stats.tokens_per_step(),
    );
    println!(
        "latency p50 {:.3}s / p95 {:.3}s / p99 {:.3}s — ttft p50 {:.3}s / p95 {:.3}s — \
         queue wait p50 {:.3}s / p95 {:.3}s",
        stats.p50_latency(),
        stats.p95_latency(),
        stats.p99_latency(),
        stats.ttft_percentile(50.0),
        stats.ttft_percentile(95.0),
        stats.queue_wait_percentile(50.0),
        stats.queue_wait_percentile(95.0),
    );
    println!(
        "step mode {} — {} engine steps, {} decode calls, {} prefill chunks",
        match step_mode {
            StepMode::Batched => "batched",
            StepMode::PerSlot => "per-slot",
        },
        stats.engine_steps,
        stats.decode_calls,
        stats.prefill_chunks,
    );
    // Overload report: goodput counts only tokens of requests that ran
    // to completion; shed/expired/cancelled account for every request
    // that did not. SLO attainment is the fraction of first tokens
    // arriving within --slo-ttft-steps engine steps.
    let slo_target = cli.get_usize("slo-ttft-steps", 8)?;
    println!(
        "overload: shed {} ({} kv) / expired {} / cancelled {} — goodput {} tokens \
         ({:.2} tokens/step, {:.1} tok/s), completion rate {:.1}%",
        stats.shed,
        stats.shed_kv,
        stats.expired,
        stats.cancelled,
        stats.goodput_tokens,
        stats.goodput_per_step(),
        stats.goodput_tokens_per_second(),
        stats.slo_completion_rate() * 100.0,
    );
    if let Some(kv) = engine.kv_stats() {
        println!(
            "kv arena: {} store, {} rows/page ({} B/page), {} pages capacity, peak {} allocated, \
             {} free at drain",
            kv.kind.name(),
            kv.page_rows,
            kv.page_bytes,
            kv.total_pages,
            kv.peak_allocated,
            kv.free_list,
        );
    }
    println!(
        "slo: ttft p50 {:.1} / p99 {:.1} steps — {:.1}% within {}-step target",
        stats.ttft_steps_percentile(50.0),
        stats.ttft_steps_percentile(99.0),
        stats.slo_attainment(slo_target) * 100.0,
        slo_target,
    );
    if let Some(rate) = stats.acceptance_rate() {
        println!(
            "speculative decode: draft {} → {:.1}% of {} drafted tokens accepted",
            spec_draft,
            rate * 100.0,
            stats.spec_drafted,
        );
    }
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let dir = cli.get_or("artifacts", "artifacts");
    let mut t = Table::new("models", &["preset", "params", "d_model", "layers", "valid ppl"]);
    for preset in ["tiny", "small", "base"] {
        let meta = format!("{dir}/model_{preset}.meta");
        if !std::path::Path::new(&meta).exists() {
            continue;
        }
        let text = std::fs::read_to_string(&meta)?;
        let get = |k: &str| {
            text.lines()
                .find_map(|l| l.strip_prefix(&format!("{k}=")))
                .unwrap_or("?")
                .to_string()
        };
        t.row(&[preset.into(), get("params"), get("d_model"), get("n_layers"), get("valid_ppl")]);
    }
    t.emit("info");
    match gptvq::runtime::load_manifest(format!("{dir}/manifest.txt")) {
        Ok(m) => println!("{} AOT artifacts in manifest", m.len()),
        Err(_) => println!("no manifest found in {dir}"),
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cli = Cli::parse(&args);
    if let Some(cfg_file) = cli.get("config").map(|s| s.to_string()) {
        if let Err(e) = cli.load_config_file(&cfg_file) {
            eprintln!("failed to load --config {cfg_file}: {e}");
            std::process::exit(2);
        }
    }
    let result = match cli.command.as_deref() {
        Some("quantize") => cmd_quantize(&cli),
        Some("eval") => cmd_eval(&cli),
        Some("sqnr") => cmd_sqnr(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("info") => cmd_info(&cli),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
