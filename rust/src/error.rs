//! Crate-wide error type.
//!
//! Display/Error impls are hand-rolled (no `thiserror`): the offline
//! build must compile with zero external dependencies.

use std::fmt;

/// All failure modes surfaced by the gptvq library.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Format { path: String, msg: String },
    Shape(String),
    Linalg(String),
    Config(String),
    Runtime(String),
    Msg(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Format { path, msg } => write!(f, "format error in {path}: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Linalg(msg) => write!(f, "linear algebra failure: {msg}"),
            Error::Config(msg) => write!(f, "configuration error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime (PJRT/XLA) error: {msg}"),
            Error::Msg(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn format(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Format { path: path.into(), msg: msg.into() }
    }
    pub fn msg(msg: impl Into<String>) -> Self {
        Error::Msg(msg.into())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_variants() {
        assert_eq!(Error::Shape("2x2 vs 3x3".into()).to_string(), "shape mismatch: 2x2 vs 3x3");
        assert_eq!(Error::msg("plain").to_string(), "plain");
        let f = Error::format("a.bin", "truncated");
        assert_eq!(f.to_string(), "format error in a.bin: truncated");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
