//! Crate-wide error type.

use thiserror::Error;

/// All failure modes surfaced by the gptvq library.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("format error in {path}: {msg}")]
    Format { path: String, msg: String },

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("linear algebra failure: {0}")]
    Linalg(String),

    #[error("configuration error: {0}")]
    Config(String),

    #[error("runtime (PJRT/XLA) error: {0}")]
    Runtime(String),

    #[error("{0}")]
    Msg(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn format(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Format { path: path.into(), msg: msg.into() }
    }
    pub fn msg(msg: impl Into<String>) -> Self {
        Error::Msg(msg.into())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}
