//! Quantization library: uniform (RTN / GPTQ) baselines and the paper's
//! GPTVQ vector-quantization method with all its components.
//!
//! Weight layout convention throughout this module is the **paper layout**:
//! `W` is `[rows = output channels, cols = input channels]`, the layer
//! computes `W @ X` with `X [in, N]`, and the Hessian of the layerwise
//! reconstruction loss is `H = X X^T [in, in]` — shared by all rows.
//! (The rust transformer stores weights `[in, out]`; `model::` transposes
//! at the boundary.)

pub mod bpv;
pub mod gptq;
pub mod gptvq;
pub mod hessian;
pub mod kmeans;
pub mod uniform;
pub mod vq;

pub use bpv::BpvBreakdown;
pub use gptvq::{GptvqConfig, GptvqResult};
pub use hessian::{HessianEstimator, XtxBatch};
