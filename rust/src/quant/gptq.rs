//! GPTQ (Frantar et al., 2022): uniform quantization with Hessian-aware
//! error feedback — the baseline GPTVQ generalizes (paper §3.1).
//!
//! Column-by-column, left to right: quantize column `q` on the group's
//! uniform grid, scale the residual by `1/U[q,q]` (U = upper Cholesky
//! factor of the dampened `H^{-1}`), and propagate the error into all
//! remaining columns. Updates are buffered per `block_size` columns and
//! flushed to the tail lazily, exactly like the reference implementation.

use crate::quant::uniform::{fit_minmax, quantize_value, UniformGroup};
use crate::tensor::Matrix;

/// GPTQ result: dequantized weights plus the grid metadata.
#[derive(Debug, Clone)]
pub struct GptqResult {
    /// Quantized-then-dequantized weights in paper layout [out, in].
    pub qweight: Matrix,
    /// Grid width in bits.
    pub bits: u32,
    /// Input channels per quantization group.
    pub group_size: usize,
    /// Per-(row, group) grid parameters, row-major.
    pub groups: Vec<UniformGroup>,
}

impl GptqResult {
    /// Paper accounting: b bits per weight + 16-bit scale per group.
    pub fn bits_per_value(&self) -> f64 {
        self.bits as f64 + 16.0 / self.group_size as f64
    }
}

/// Run GPTQ on `w [out, in]` given the upper Cholesky factor `u` of the
/// dampened inverse Hessian (`hessian::HessianEstimator::inverse_factor`).
///
/// `group_size` groups consecutive input channels (per row) on a shared
/// min-max grid, fitted on the *current* (error-compensated) weights when
/// the column sweep enters the group — the standard GPTQ "act-order off,
/// groups on the fly" behaviour.
pub fn gptq_quantize(w: &Matrix, u: &Matrix, bits: u32, group_size: usize, block_size: usize) -> GptqResult {
    let (r, c) = (w.rows(), w.cols());
    assert_eq!(u.rows(), c, "inverse factor dim");
    let gs = group_size.min(c).max(1);
    let block = block_size.min(c).max(1);
    let gpr = c.div_ceil(gs);

    let mut work = w.clone(); // error-compensated weights, mutated in place
    let mut q = Matrix::zeros(r, c);
    let mut groups: Vec<UniformGroup> = vec![UniformGroup { scale: 1.0, zero: 0.0 }; r * gpr];

    let mut i = 0;
    while i < c {
        let iend = (i + block).min(c);
        let bw = iend - i;
        // per-column scaled errors for this block: E[:, j] = (w - q)/U[qq]
        let mut err = Matrix::zeros(r, bw);

        for col in i..iend {
            // (re)fit grids at group boundaries, on compensated weights
            if col % gs == 0 {
                let g = col / gs;
                let c1 = (col + gs).min(c);
                for row in 0..r {
                    groups[row * gpr + g] = fit_minmax(&work.row(row)[col..c1], bits);
                }
            }
            let g = col / gs;
            let d = u.get(col, col);
            for row in 0..r {
                let v = work.get(row, col);
                let (_, deq) = quantize_value(v, &groups[row * gpr + g], bits);
                q.set(row, col, deq);
                err.set(row, col - i, (v - deq) / d);
            }
            // propagate inside the block: W[:, col+1..iend] -= err_col * U[col, col+1..iend]
            let urow = u.row(col);
            for row in 0..r {
                let e = err.get(row, col - i);
                if e == 0.0 {
                    continue;
                }
                let wrow = work.row_mut(row);
                for t in col + 1..iend {
                    wrow[t] -= e * urow[t];
                }
            }
        }

        // flush to the tail: W[:, iend..] -= E @ U[i..iend, iend..]
        if iend < c {
            for row in 0..r {
                // accumulate this row's update
                let erow = err.row(row);
                let wrow_start = iend;
                for (bj, e) in erow.iter().enumerate() {
                    if *e == 0.0 {
                        continue;
                    }
                    let urow = u.row(i + bj);
                    let wrow = work.row_mut(row);
                    for t in wrow_start..c {
                        wrow[t] -= e * urow[t];
                    }
                }
            }
        }
        i = iend;
    }

    GptqResult { qweight: q, bits, group_size: gs, groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hessian::HessianEstimator;
    use crate::quant::uniform::rtn_quantize;
    use crate::tensor::{matmul, matmul_a_bt};
    use crate::util::prop::check;
    use crate::util::Rng;

    /// Reconstruction loss tr((W-Q) H (W-Q)^T).
    fn recon_loss(w: &Matrix, q: &Matrix, h: &Matrix) -> f64 {
        let e = w.sub(q);
        let eh = matmul(&e, h);
        let ehet = matmul_a_bt(&eh, &e);
        (0..e.rows()).map(|i| ehet.get(i, i)).sum()
    }

    fn setup(rng: &mut Rng, r: usize, c: usize, n: usize) -> (Matrix, Matrix, HessianEstimator) {
        let w = Matrix::from_fn(r, c, |_, _| rng.gaussian());
        // correlated activations make the Hessian non-trivial
        let base = Matrix::from_fn(n, c, |_, _| rng.gaussian());
        let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.3 * rng.gaussian() });
        let x = matmul(&base, &mix);
        let mut est = HessianEstimator::new(c);
        est.update(&x);
        (w, x, est)
    }

    #[test]
    fn beats_rtn_on_hessian_loss() {
        check("gptq <= rtn in H-weighted loss", 8, |rng| {
            let (r, c) = (4 + rng.below(8), 16 + 8 * rng.below(5));
            let (w, _x, est) = setup(rng, r, c, 4 * c);
            let h = est.dampened(0.01);
            let u = est.inverse_factor(0.01).map_err(|e| e.to_string())?;
            let gptq = gptq_quantize(&w, &u, 3, 16, 8);
            let rtn = rtn_quantize(&w, 3, 16).dequantize();
            let lg = recon_loss(&w, &gptq.qweight, &h);
            let lr = recon_loss(&w, &rtn, &h);
            if lg <= lr * 1.02 {
                Ok(())
            } else {
                Err(format!("gptq loss {lg} > rtn loss {lr}"))
            }
        });
    }

    #[test]
    fn identity_hessian_matches_rtn_when_grids_align() {
        // with H = I there is no correlation to exploit; GPTQ still uses
        // error feedback inside groups but the first column quantization
        // equals RTN's
        let mut rng = Rng::new(1);
        let w = Matrix::from_fn(3, 8, |_, _| rng.gaussian());
        let u = Matrix::identity(8);
        let res = gptq_quantize(&w, &u, 4, 8, 4);
        let rtn = rtn_quantize(&w, 4, 8).dequantize();
        for row in 0..3 {
            assert!((res.qweight.get(row, 0) - rtn.get(row, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn block_size_invariance() {
        // the lazy-flush blocking is an implementation detail: results
        // must be identical for any block size
        check("block invariance", 6, |rng| {
            let (r, c) = (3, 24);
            let (w, _x, est) = setup(rng, r, c, 96);
            let u = est.inverse_factor(0.01).map_err(|e| e.to_string())?;
            let a = gptq_quantize(&w, &u, 3, 8, 4);
            let b = gptq_quantize(&w, &u, 3, 8, 24);
            crate::util::prop::assert_close(
                a.qweight.as_slice(),
                b.qweight.as_slice(),
                1e-9,
                1e-9,
                "block",
            )
        });
    }

    #[test]
    fn codes_reconstruct_on_grid() {
        let mut rng = Rng::new(2);
        let (w, _x, est) = setup(&mut rng, 4, 16, 64);
        let u = est.inverse_factor(0.01).unwrap();
        let res = gptq_quantize(&w, &u, 2, 16, 8);
        // every output value must be on its group's 4-level grid
        let gpr = res.qweight.cols().div_ceil(res.group_size);
        for row in 0..4 {
            for col in 0..16 {
                let g = &res.groups[row * gpr + col / res.group_size];
                let code = (res.qweight.get(row, col) - g.zero) / g.scale;
                assert!((code - code.round()).abs() < 1e-9, "off grid: {code}");
                assert!((0.0..=3.0).contains(&code.round()));
            }
        }
    }

    #[test]
    fn higher_bits_lower_loss() {
        let mut rng = Rng::new(3);
        let (w, _x, est) = setup(&mut rng, 6, 32, 128);
        let h = est.dampened(0.01);
        let u = est.inverse_factor(0.01).unwrap();
        let l2 = recon_loss(&w, &gptq_quantize(&w, &u, 2, 16, 16).qweight, &h);
        let l3 = recon_loss(&w, &gptq_quantize(&w, &u, 3, 16, 16).qweight, &h);
        let l4 = recon_loss(&w, &gptq_quantize(&w, &u, 4, 16, 16).qweight, &h);
        assert!(l3 < l2 && l4 < l3, "{l2} {l3} {l4}");
    }
}
