//! Uniform (integer-grid) quantization: the RTN baseline and the grid
//! machinery shared by GPTQ.
//!
//! Group-wise asymmetric min-max quantization in the GPTQ/OmniQuant style:
//! each row of `W [r, c]` is split into groups of `group_size` consecutive
//! input channels; each group gets a (scale, zero-point) pair stored in 16
//! bits each, giving the `W<b>@g<gs>` settings of the paper's tables
//! (e.g. W2@g128 = 2-bit weights + 16-bit scale per 128 weights
//! = 2.125 bpv with a 16-bit zero amortized alongside).

use crate::tensor::Matrix;

/// Parameters of one uniform quantization group.
#[derive(Debug, Clone, Copy)]
pub struct UniformGroup {
    /// grid step
    pub scale: f64,
    /// float zero-point (asymmetric min-max)
    pub zero: f64,
}

/// A uniformly quantized matrix: integer codes plus per-group parameters.
#[derive(Debug, Clone)]
pub struct UniformQuantized {
    /// matrix rows (paper layout [out, in])
    pub rows: usize,
    /// matrix columns
    pub cols: usize,
    /// grid width in bits
    pub bits: u32,
    /// input channels per group
    pub group_size: usize,
    /// codes[r * cols + c] in [0, 2^bits)
    pub codes: Vec<u16>,
    /// group parameters, row-major over (row, group)
    pub groups: Vec<UniformGroup>,
}

/// Fit asymmetric min-max (scale, zero) for one slice of values.
pub fn fit_minmax(vals: &[f64], bits: u32) -> UniformGroup {
    let levels = ((1u32 << bits) - 1) as f64;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() {
        return UniformGroup { scale: 1.0, zero: 0.0 };
    }
    // grid must contain zero-ish range even for constant groups
    if hi - lo < 1e-30 {
        return UniformGroup { scale: 1.0, zero: lo };
    }
    let scale = (hi - lo) / levels;
    UniformGroup { scale, zero: lo }
}

/// Quantize a single value on a group's grid; returns (code, dequantized).
#[inline]
pub fn quantize_value(v: f64, g: &UniformGroup, bits: u32) -> (u16, f64) {
    let levels = ((1u32 << bits) - 1) as f64;
    let code = ((v - g.zero) / g.scale).round().clamp(0.0, levels);
    (code as u16, g.zero + code * g.scale)
}

/// Round-to-nearest quantization of a weight matrix (the RTN baseline).
pub fn rtn_quantize(w: &Matrix, bits: u32, group_size: usize) -> UniformQuantized {
    let (r, c) = (w.rows(), w.cols());
    let gs = group_size.min(c).max(1);
    let groups_per_row = c.div_ceil(gs);
    let mut codes = vec![0u16; r * c];
    let mut groups = Vec::with_capacity(r * groups_per_row);
    for row in 0..r {
        let wrow = w.row(row);
        for g in 0..groups_per_row {
            let c0 = g * gs;
            let c1 = (c0 + gs).min(c);
            let params = fit_minmax(&wrow[c0..c1], bits);
            for col in c0..c1 {
                let (code, _) = quantize_value(wrow[col], &params, bits);
                codes[row * c + col] = code;
            }
            groups.push(params);
        }
    }
    UniformQuantized { rows: r, cols: c, bits, group_size: gs, codes, groups }
}

impl UniformQuantized {
    /// Number of (scale, zero) groups per row.
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Dequantize back to a dense matrix.
    pub fn dequantize(&self) -> Matrix {
        let gpr = self.groups_per_row();
        Matrix::from_fn(self.rows, self.cols, |r, c| {
            let g = &self.groups[r * gpr + c / self.group_size];
            g.zero + self.codes[r * self.cols + c] as f64 * g.scale
        })
    }

    /// Bits per value including 16-bit scale + 16-bit zero per group
    /// (matches the paper's accounting: W2@g128 -> 2.125 bpv counts the
    /// scale; the zero-point is folded into the same 16-bit budget by
    /// storing zero as an integer offset in `bits` bits + sharing).
    pub fn bits_per_value(&self) -> f64 {
        // paper accounting: b + 16/group_size
        self.bits as f64 + 16.0 / self.group_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Rng;

    #[test]
    fn codes_in_range_and_reconstruction_close() {
        check("rtn codes bounded, error <= scale/2", 20, |rng| {
            let r = 1 + rng.below(8);
            let c = 1 + rng.below(40);
            let bits = [2u32, 3, 4][rng.below(3)];
            let gs = [8usize, 16, 128][rng.below(3)];
            let w = Matrix::from_fn(r, c, |_, _| rng.gaussian() * 3.0);
            let q = rtn_quantize(&w, bits, gs);
            let deq = q.dequantize();
            let maxcode = (1u32 << bits) - 1;
            for code in &q.codes {
                if *code as u32 > maxcode {
                    return Err(format!("code {code} > {maxcode}"));
                }
            }
            let gpr = q.groups_per_row();
            for row in 0..r {
                for col in 0..c {
                    let g = &q.groups[row * gpr + col / q.group_size];
                    let err = (w.get(row, col) - deq.get(row, col)).abs();
                    if err > 0.5 * g.scale + 1e-12 {
                        return Err(format!("err {err} > half-scale {}", 0.5 * g.scale));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grid_endpoints_exact() {
        // min and max of each group must be representable exactly
        let w = Matrix::from_vec(1, 4, vec![-1.0, 0.25, 0.5, 3.0]).unwrap();
        let q = rtn_quantize(&w, 2, 4);
        let deq = q.dequantize();
        assert!((deq.get(0, 0) - -1.0).abs() < 1e-12);
        assert!((deq.get(0, 3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn constant_group_is_exact() {
        let w = Matrix::from_vec(1, 8, vec![0.7; 8]).unwrap();
        let q = rtn_quantize(&w, 2, 8);
        let deq = q.dequantize();
        for c in 0..8 {
            assert!((deq.get(0, c) - 0.7).abs() < 1e-12);
        }
    }

    #[test]
    fn higher_bits_reduce_error() {
        let mut rng = Rng::new(3);
        let w = Matrix::from_fn(8, 64, |_, _| rng.gaussian());
        let mut errs = Vec::new();
        for bits in [2, 3, 4, 8] {
            let q = rtn_quantize(&w, bits, 64);
            errs.push(w.sub(&q.dequantize()).frob_norm_sq());
        }
        for i in 1..errs.len() {
            assert!(errs[i] < errs[i - 1], "{errs:?}");
        }
    }

    #[test]
    fn smaller_groups_reduce_error() {
        let mut rng = Rng::new(4);
        let w = Matrix::from_fn(4, 128, |_, _| rng.gaussian() * (1.0 + rng.uniform() * 4.0));
        let big = rtn_quantize(&w, 3, 128);
        let small = rtn_quantize(&w, 3, 16);
        assert!(
            w.sub(&small.dequantize()).frob_norm_sq() < w.sub(&big.dequantize()).frob_norm_sq()
        );
    }

    #[test]
    fn bpv_accounting() {
        let w = Matrix::zeros(4, 256);
        let q = rtn_quantize(&w, 2, 128);
        assert!((q.bits_per_value() - 2.125).abs() < 1e-12);
        let q = rtn_quantize(&w, 2, 64);
        assert!((q.bits_per_value() - 2.25).abs() < 1e-12);
        let q = rtn_quantize(&w, 3, 128);
        assert!((q.bits_per_value() - 3.125).abs() < 1e-12);
    }

    #[test]
    fn ragged_last_group() {
        let mut rng = Rng::new(5);
        let w = Matrix::from_fn(2, 100, |_, _| rng.gaussian());
        let q = rtn_quantize(&w, 4, 64); // groups: 64 + 36
        assert_eq!(q.groups_per_row(), 2);
        let deq = q.dequantize();
        assert_eq!(deq.cols(), 100);
    }
}
