//! Per-layer Hessian estimation from calibration activations.
//!
//! For the layerwise reconstruction loss (paper eq. 1) the Hessian is
//! `H = 2 X X^T` with `X [in, N]` the layer inputs over the calibration
//! set. We accumulate it batch by batch (the coordinator streams batches),
//! then dampen `H += lambda * mean(diag(H)) * I` exactly as GPTQ does, and
//! hand the GPTQ/GPTVQ loops the upper Cholesky factor of `H^{-1}`.

use crate::error::Result;
use crate::linalg::cholesky_upper_of_inverse;
use crate::tensor::{matmul_at_b_on, Matrix, Matrix32, Precision};
use crate::util::WorkerPool;

/// One calibration batch's `x^T x` product, computed at the selected
/// precision but **not yet folded** into an estimator.
///
/// Splitting the product from the accumulation lets the calibration
/// collector run per-sequence products on pool workers and then
/// [`HessianEstimator::absorb`] them on the coordinator in fixed
/// sequence order — executing the exact same accumulation operations,
/// in the exact same order, as the serial sequence walk, so parallel
/// calibration stays bitwise identical to the serial path.
#[derive(Debug, Clone)]
pub struct XtxBatch {
    /// Activation rows that produced this product.
    rows: usize,
    /// The product, in the width it was computed at.
    data: XtxData,
}

#[derive(Debug, Clone)]
enum XtxData {
    /// Reference-path product (f64 kernel).
    F64(Matrix),
    /// `--precision f32` product, widened during absorption exactly as
    /// [`HessianEstimator::update_prec`] widens it.
    F32(Matrix32),
}

impl XtxBatch {
    /// Compute `x^T x` for one activation batch at `precision` on a
    /// borrowed pool, without touching any estimator.
    pub fn compute(x: &Matrix, precision: Precision, pool: &WorkerPool) -> XtxBatch {
        let data = match precision {
            Precision::F64 => XtxData::F64(matmul_at_b_on(x, x, pool)),
            Precision::F32 => {
                // detlint: allow(precision-cast, explicit f32-precision Hessian option behind the loss guardrail)
                let x32: Matrix32 = x.convert();
                XtxData::F32(matmul_at_b_on(&x32, &x32, pool))
            }
        };
        XtxBatch { rows: x.rows(), data }
    }

    /// Input dimensionality of the underlying activation batch.
    pub fn dim(&self) -> usize {
        match &self.data {
            XtxData::F64(m) => m.rows(),
            XtxData::F32(m) => m.rows(),
        }
    }
}

/// Streaming accumulator for `H = 2/N * sum_batches X_b X_b^T`.
///
/// The 2/N normalization does not change the GPTQ/GPTVQ solutions (the
/// update rule is scale-invariant in H) but keeps magnitudes sane.
#[derive(Debug, Clone)]
pub struct HessianEstimator {
    dim: usize,
    h: Matrix,
    n_samples: usize,
}

impl HessianEstimator {
    /// Fresh estimator for a `dim`-dimensional input site.
    pub fn new(dim: usize) -> Self {
        HessianEstimator { dim, h: Matrix::zeros(dim, dim), n_samples: 0 }
    }

    /// Input dimensionality of the site.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total activation rows accumulated so far.
    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    /// Add a batch of activations `x [n, dim]` (row = one token's input
    /// vector). Accumulates `x^T x`.
    pub fn update(&mut self, x: &Matrix) {
        self.update_threaded(x, 1);
    }

    /// `update` with the `x^T x` product computed on the shared threaded
    /// matmul path (bitwise identical for any thread count — per-element
    /// accumulation order over samples is unchanged).
    pub fn update_threaded(&mut self, x: &Matrix, n_threads: usize) {
        self.update_prec(x, Precision::F64, n_threads);
    }

    /// `update_threaded` with a selectable compute width for the `x^T x`
    /// product — the Hessian-accumulation arm of `--precision f32`.
    /// Standalone-use wrapper around [`HessianEstimator::update_prec_on`].
    pub fn update_prec(&mut self, x: &Matrix, precision: Precision, n_threads: usize) {
        self.update_prec_on(x, precision, &WorkerPool::new(n_threads));
    }

    /// `update_prec` with the product running on a borrowed
    /// [`WorkerPool`] — the form every pool-holding caller (pipeline
    /// calibration, benches) uses.
    ///
    /// At [`Precision::F32`] the batch is narrowed once, the product runs
    /// through the f32 kernel (half the memory traffic, twice the SIMD
    /// lanes), and the result is widened into the f64 master accumulator,
    /// so cross-batch accumulation — and everything downstream of it
    /// (damping, Cholesky) — stays double precision. Deterministic for
    /// any pool width at either precision.
    pub fn update_prec_on(&mut self, x: &Matrix, precision: Precision, pool: &WorkerPool) {
        assert_eq!(x.cols(), self.dim, "activation dim mismatch");
        let batch = XtxBatch::compute(x, precision, pool);
        self.absorb(&batch);
    }

    /// Fold one precomputed [`XtxBatch`] into the accumulator. This is
    /// the accumulation half of [`HessianEstimator::update_prec_on`],
    /// performing operation-for-operation the same f64 additions, so
    /// `absorb(compute(x))` ≡ `update_prec(x)` bitwise — the property
    /// the parallel calibration collector's fixed-order reduction
    /// relies on.
    pub fn absorb(&mut self, batch: &XtxBatch) {
        assert_eq!(batch.dim(), self.dim, "xtx batch dim mismatch");
        match &batch.data {
            XtxData::F64(xtx) => self.h.add_assign(xtx),
            XtxData::F32(xtx32) => {
                for (hv, &xv) in self.h.as_mut_slice().iter_mut().zip(xtx32.as_slice()) {
                    *hv += xv as f64;
                }
            }
        }
        self.n_samples += batch.rows;
    }

    /// The normalized, *undamped* Hessian `2/N sum x x^T`.
    pub fn hessian(&self) -> Matrix {
        let mut h = self.h.clone();
        if self.n_samples > 0 {
            h.scale(2.0 / self.n_samples as f64);
        }
        h
    }

    /// Dampened Hessian: `H + lambda * mean(diag(H)) * I`, plus handling of
    /// dead inputs (zero diagonal -> unit diagonal, as in GPTQ).
    pub fn dampened(&self, lambda: f64) -> Matrix {
        let mut h = self.hessian();
        let n = self.dim;
        let mut diag_mean = 0.0;
        for i in 0..n {
            diag_mean += h.get(i, i);
        }
        diag_mean /= n.max(1) as f64;
        let damp = lambda * diag_mean;
        for i in 0..n {
            let d = h.get(i, i);
            if d == 0.0 {
                // dead input channel: its weight never matters; pin to 1
                h.set(i, i, 1.0);
            } else {
                h.set(i, i, d + damp);
            }
        }
        h
    }

    /// Upper Cholesky factor `U` of `H^{-1}` (`H^{-1} = U^T U`) after
    /// damping — the object Algorithm 1 consumes (line 7).
    pub fn inverse_factor(&self, lambda: f64) -> Result<Matrix> {
        let h = self.dampened(lambda);
        cholesky_upper_of_inverse(&h)
    }
}

/// Per-coordinate assignment weights for a set of columns, derived from
/// the inverse-Hessian Cholesky factor: `w_q = 1 / U[q,q]^2`.
///
/// GPTQ's scalar error term is `(w - q) / U[q,q]`; squaring gives the
/// quadratic weight used in the VQ distance (paper eq. 4, diagonal
/// variant). Constant across rows (H is shared by all rows).
pub fn column_weights(u: &Matrix, cols: std::ops::Range<usize>) -> Vec<f64> {
    cols.map(|q| {
        let d = u.get(q, q);
        1.0 / (d * d).max(1e-30)
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::prop::{assert_close, check};
    use crate::util::Rng;

    #[test]
    fn accumulates_xtx() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut est = HessianEstimator::new(2);
        est.update(&x);
        // 2/N * X^T X with N=2
        let want = [
            2.0 / 2.0 * (1.0 + 9.0),
            2.0 / 2.0 * (2.0 + 12.0),
            2.0 / 2.0 * (2.0 + 12.0),
            2.0 / 2.0 * (4.0 + 16.0),
        ];
        assert_close(est.hessian().as_slice(), &want, 1e-12, 1e-12, "xtx").unwrap();
    }

    #[test]
    fn batch_split_invariance() {
        check("H(batch) == H(split batches)", 10, |rng| {
            let d = 2 + rng.below(6);
            let n = 8 + rng.below(20);
            let x = Matrix::from_fn(n, d, |_, _| rng.gaussian());
            let mut whole = HessianEstimator::new(d);
            whole.update(&x);
            let mut split = HessianEstimator::new(d);
            let cut = 1 + rng.below(n - 1);
            split.update(&x.slice_rows(0, cut));
            split.update(&x.slice_rows(cut, n));
            assert_close(
                whole.hessian().as_slice(),
                split.hessian().as_slice(),
                1e-10,
                1e-10,
                "split",
            )
        });
    }

    #[test]
    fn dampened_is_pd_even_with_dead_inputs() {
        let mut rng = Rng::new(1);
        let d = 6;
        // column 3 is always zero (dead input)
        let x = Matrix::from_fn(40, d, |_, c| if c == 3 { 0.0 } else { rng.gaussian() });
        let mut est = HessianEstimator::new(d);
        est.update(&x);
        let u = est.inverse_factor(0.01).unwrap();
        assert_eq!(u.rows(), d);
        // factor reconstructs the inverse of the dampened H
        let h = est.dampened(0.01);
        let rec = matmul(&u.transpose(), &u);
        let prod = matmul(&h, &rec);
        let eye = Matrix::identity(d);
        assert_close(prod.as_slice(), eye.as_slice(), 1e-6, 1e-6, "H Hinv == I").unwrap();
    }

    #[test]
    fn f32_accumulation_tracks_f64_hessian() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(128, 8, |_, _| rng.gaussian());
        let mut e64 = HessianEstimator::new(8);
        e64.update(&x);
        let mut e32 = HessianEstimator::new(8);
        e32.update_prec(&x, Precision::F32, crate::util::test_threads());
        assert_eq!(e32.n_samples(), 128);
        for (a, b) in e64.hessian().as_slice().iter().zip(e32.hessian().as_slice()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
        // damping + Cholesky still run in f64 off the f32-accumulated H
        e32.inverse_factor(0.01).unwrap();
    }

    #[test]
    fn compute_absorb_split_matches_update_prec_bitwise() {
        // the contract parallel calibration rests on: computing batch
        // products on pool workers and absorbing them in order performs
        // the exact accumulation ops of the direct update path
        let mut rng = Rng::new(5);
        let pool = WorkerPool::new(4);
        for precision in [Precision::F64, Precision::F32] {
            let xs: Vec<Matrix> =
                (0..3).map(|_| Matrix::from_fn(24, 6, |_, _| rng.gaussian())).collect();
            let mut direct = HessianEstimator::new(6);
            let mut split = HessianEstimator::new(6);
            for x in &xs {
                direct.update_prec(x, precision, 1);
                split.absorb(&XtxBatch::compute(x, precision, &pool));
            }
            assert_eq!(
                direct.hessian().as_slice(),
                split.hessian().as_slice(),
                "{precision:?}"
            );
            assert_eq!(direct.n_samples(), split.n_samples());
        }
    }

    #[test]
    fn update_prec_f64_is_the_reference_path() {
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(32, 4, |_, _| rng.gaussian());
        let mut a = HessianEstimator::new(4);
        a.update(&x);
        let mut b = HessianEstimator::new(4);
        b.update_prec(&x, Precision::F64, 1);
        assert_eq!(a.hessian().as_slice(), b.hessian().as_slice());
    }

    #[test]
    fn column_weights_positive_and_match_diag() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(64, 4, |_, _| rng.gaussian());
        let mut est = HessianEstimator::new(4);
        est.update(&x);
        let u = est.inverse_factor(0.01).unwrap();
        let w = column_weights(&u, 0..4);
        assert_eq!(w.len(), 4);
        for (q, &wq) in w.iter().enumerate() {
            assert!(wq > 0.0);
            let d = u.get(q, q);
            assert!((wq - 1.0 / (d * d)).abs() < 1e-9 * wq);
        }
    }
}
