//! k-means VQ baselines (paper §2.2, Table 1): clustering the weights
//! directly — optionally with layer-input (Hessian-diagonal) weighting —
//! but *without* GPTQ-style error feedback. These are the methods the
//! paper shows to be insufficient at low bitwidths.

use crate::quant::vq::em::em_diag;
use crate::quant::vq::seed::seed_mahalanobis;
use crate::quant::vq::{decode, Codebook};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Quantize `w [out, in]` with plain (or data-aware) k-means VQ.
///
/// * `d`, `k` — VQ dimension and centroids per codebook
/// * `group_size` — target weights per codebook (snapped to row strips)
/// * `max_group_cols` — span width (256 in the paper)
/// * `h` — `Some(dampened Hessian)` for the data-aware variant: points are
///   weighted by `diag(H)` of their columns (the layer-input statistics);
///   `None` clusters on weights alone
/// * `iters` — EM iterations
pub fn kmeans_vq_quantize(
    w: &Matrix,
    d: usize,
    k: usize,
    group_size: usize,
    max_group_cols: usize,
    h: Option<&Matrix>,
    iters: usize,
    rng_seed: u64,
) -> Matrix {
    let (r, c) = (w.rows(), w.cols());
    assert!(c % d == 0, "columns must divide by d");
    let mut q = Matrix::zeros(r, c);
    let mut _rng = Rng::new(rng_seed);

    let mut col0 = 0;
    while col0 < c {
        let span = max_group_cols.min(c - col0);
        let span = span - (span % d);
        let col1 = col0 + span;
        let g_r = ((group_size as f64 / span as f64).round() as usize).clamp(1, r);

        let mut row0 = 0;
        while row0 < r {
            let row1 = (row0 + g_r).min(r);
            let gr = row1 - row0;
            let strips = span / d;
            let n = gr * strips;
            let mut pts = Matrix::zeros(n, d);
            let mut hw = Matrix::zeros(n, d);
            for rr in 0..gr {
                for j in 0..strips {
                    for t in 0..d {
                        let cabs = col0 + j * d + t;
                        pts.set(rr * strips + j, t, w.get(row0 + rr, cabs));
                        let weight = match h {
                            Some(hm) => hm.get(cabs, cabs).max(1e-12),
                            None => 1.0,
                        };
                        hw.set(rr * strips + j, t, weight);
                    }
                }
            }
            let seed_cb = seed_mahalanobis(&pts, k).unwrap_or_else(|_| {
                // degenerate data: fall back to first k points
                let mut cents = Vec::with_capacity(k * d);
                for m in 0..k {
                    cents.extend_from_slice(pts.row(m % n.max(1)));
                }
                Codebook::from_centroids(d, cents)
            });
            let em = em_diag(&pts, &hw, seed_cb, iters);
            let dec = decode(&em.codebook, &em.assignments);
            for rr in 0..gr {
                for j in 0..strips {
                    for t in 0..d {
                        q.set(row0 + rr, col0 + j * d + t, dec.get(rr * strips + j, t));
                    }
                }
            }
            row0 = row1;
        }
        col0 = col1;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hessian::HessianEstimator;
    use crate::quant::vq::update::recon_loss;
    use crate::tensor::matmul;
    use crate::util::Rng;

    fn setup(rng: &mut Rng, r: usize, c: usize) -> (Matrix, Matrix) {
        let w = Matrix::from_fn(r, c, |_, _| rng.gaussian());
        let base = Matrix::from_fn(4 * c, c, |_, _| rng.gaussian());
        let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.3 * rng.gaussian() });
        let x = matmul(&base, &mix);
        let mut est = HessianEstimator::new(c);
        est.update(&x);
        (w, est.dampened(0.01))
    }

    #[test]
    fn covers_matrix_and_reduces_with_k() {
        let mut rng = Rng::new(1);
        let (w, _h) = setup(&mut rng, 16, 32);
        let q4 = kmeans_vq_quantize(&w, 2, 4, 256, 32, None, 15, 0);
        let q64 = kmeans_vq_quantize(&w, 2, 64, 256, 32, None, 15, 0);
        let e4 = w.sub(&q4).frob_norm_sq();
        let e64 = w.sub(&q64).frob_norm_sq();
        assert!(e64 < e4, "more centroids must reduce error: {e64} vs {e4}");
    }

    #[test]
    fn data_aware_beats_plain_on_hessian_loss() {
        // Table 1 shape: including input data improves the weighted loss
        let mut rng = Rng::new(2);
        let (w, h) = setup(&mut rng, 24, 48);
        let plain = kmeans_vq_quantize(&w, 2, 8, 512, 48, None, 25, 0);
        let aware = kmeans_vq_quantize(&w, 2, 8, 512, 48, Some(&h), 25, 0);
        let lp = recon_loss(&w, &plain, &h);
        let la = recon_loss(&w, &aware, &h);
        assert!(la <= lp * 1.05, "data-aware {la} should be <= plain {lp}");
    }

    #[test]
    fn d1_equals_scalar_clustering() {
        let mut rng = Rng::new(3);
        let (w, _) = setup(&mut rng, 8, 16);
        let q = kmeans_vq_quantize(&w, 1, 16, 128, 16, None, 25, 0);
        // with k=16 over <=128 scalars the error must be small
        let rel = w.sub(&q).frob_norm_sq() / w.frob_norm_sq();
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn exact_when_k_covers_all_distinct_values() {
        // 4 distinct scalar values, k=4, 1D: zero error
        let w = Matrix::from_fn(4, 8, |r, _| r as f64);
        let q = kmeans_vq_quantize(&w, 1, 4, 32, 8, None, 30, 0);
        assert!(w.sub(&q).frob_norm_sq() < 1e-18);
    }
}
