//! Post-hoc codebook update (paper §3.3, eq. 7, Table 9).
//!
//! With assignments (and scales) frozen, the layerwise reconstruction loss
//! `||WX - QX||_F^2 = tr((W-Q) H (W-Q)^T)` is a convex quadratic in the
//! codebook entries. The paper minimizes it by gradient descent (faster
//! than the closed form, equally good); we add backtracking line search so
//! no learning-rate tuning is needed:
//!
//!   dL/dQ       = -2 (W - Q) H
//!   dL/dC[m,t]  = sum over positions assigned to m of s_pos * dL/dQ[pos]
//!
//! The Hessian form means no calibration activations need to be retained.

use crate::quant::vq::{decode_groups_on, VqGroup};
use crate::tensor::{matmul_on, Element, Matrix, MatrixG, Precision};
use crate::util::{parallel_map, WorkerPool};

/// Reconstruction loss tr((W-Q) H (W-Q)^T).
pub fn recon_loss(w: &Matrix, q: &Matrix, h: &Matrix) -> f64 {
    loss_and_eh(w, q, h).0
}

/// `recon_loss` with the dominating `E @ H` product row-parallelized
/// (bitwise identical to the single-threaded loss for any thread count).
/// Standalone-use wrapper around [`recon_loss_on`].
pub fn recon_loss_threaded(w: &Matrix, q: &Matrix, h: &Matrix, n_threads: usize) -> f64 {
    recon_loss_on(w, q, h, &WorkerPool::new(n_threads))
}

/// `recon_loss` with the dominating `E @ H` product running on a
/// borrowed [`WorkerPool`] (bitwise identical for any pool width).
pub fn recon_loss_on(w: &Matrix, q: &Matrix, h: &Matrix, pool: &WorkerPool) -> f64 {
    loss_and_eh_on(w, q, h, pool).0
}

/// One-pass loss + `E H` (E = W - Q). The matmul dominates the update
/// loop's cost, and `dL/dQ = -2 E H` reuses the same product — computing
/// both at once halves the matmuls per GD iteration (§Perf).
pub fn loss_and_eh(w: &Matrix, q: &Matrix, h: &Matrix) -> (f64, Matrix) {
    loss_and_eh_on(w, q, h, WorkerPool::inline())
}

/// `loss_and_eh` over the shared threaded matmul path. Standalone-use
/// wrapper around [`loss_and_eh_on`].
pub fn loss_and_eh_threaded(w: &Matrix, q: &Matrix, h: &Matrix, n_threads: usize) -> (f64, Matrix) {
    loss_and_eh_on(w, q, h, &WorkerPool::new(n_threads))
}

/// `loss_and_eh` with the matmul running on a borrowed [`WorkerPool`].
pub fn loss_and_eh_on(w: &Matrix, q: &Matrix, h: &Matrix, pool: &WorkerPool) -> (f64, Matrix) {
    let e = w.sub(q);
    loss_and_eh_in(&e, h, pool)
}

/// Loss + `E H` from a precomputed error matrix, generic over the compute
/// width. Each row's product terms accumulate sequentially in `E`'s width
/// and the per-row sums are widened into an f64 total, so the `f64`
/// instantiation is exactly the historical computation and the `f32` one
/// differs only by single-precision rounding.
fn loss_and_eh_in<E: Element>(e: &MatrixG<E>, h: &MatrixG<E>, pool: &WorkerPool) -> (f64, MatrixG<E>) {
    let eh = matmul_on(e, h, pool);
    let mut total = 0.0;
    for r in 0..e.rows() {
        let mut row_sum = E::ZERO;
        for (x, y) in e.row(r).iter().zip(eh.row(r)) {
            row_sum += *x * *y;
        }
        // detlint: allow(precision-cast, exact widening: proxy loss totals accumulate in pinned f64)
        total += row_sum.to_f64();
    }
    (total, eh)
}

/// `w_e - q` with `q` narrowed element-wise during the subtraction, so a
/// line-search probe costs one allocation at either width. For `E = f64`
/// the narrowing is the identity and this is exactly `w.sub(&q)`.
fn sub_narrowed<E: Element>(w_e: &MatrixG<E>, q: &Matrix) -> MatrixG<E> {
    debug_assert_eq!((w_e.rows(), w_e.cols()), (q.rows(), q.cols()));
    let data: Vec<E> = w_e
        .as_slice()
        .iter()
        .zip(q.as_slice())
        // detlint: allow(precision-cast, decoded q is pinned f64; narrowed once to E for the residual)
        .map(|(&a, &b)| a - E::from_f64(b))
        .collect();
    MatrixG::from_vec(w_e.rows(), w_e.cols(), data).expect("shape preserved")
}

/// Outcome of the codebook update.
#[derive(Debug, Clone)]
pub struct UpdateStats {
    /// loss entering the update (in the update's compute width)
    pub loss_before: f64,
    /// loss after the accepted GD steps (same width; the engine's
    /// authoritative final loss is recomputed in f64)
    pub loss_after: f64,
    /// GD iterations executed before convergence/rejection
    pub iterations: usize,
}

/// Gradient of the loss w.r.t. every group's codebook, given dL/dQ.
/// Groups touch disjoint weight tiles, so they fan across workers with a
/// fixed result slot each (thread-count independent). Gradients are
/// accumulated in f64 regardless of the compute width of `dq`, keeping
/// the descent direction stable on the f32 path.
fn codebook_grads<E: Element>(groups: &[VqGroup], dq: &MatrixG<E>, pool: &WorkerPool) -> Vec<Vec<f64>> {
    parallel_map(pool, pool.n_threads(), groups.len(), |gi| {
        let g = &groups[gi];
        let d = g.codebook.d;
        let mut grad = vec![0.0; g.codebook.k * d];
        let strips = g.strips();
        for r in g.row0..g.row1 {
            let lr = r - g.row0;
            for j in 0..strips {
                let a = g.assignments[lr * strips + j] as usize;
                for t in 0..d {
                    let c = g.col0 + j * d + t;
                    let s = g.scales.scale_at(lr, c - g.col0);
                    // detlint: allow(precision-cast, exact widening: centroid gradients accumulate in pinned f64)
                    grad[a * d + t] += s * dq.get(r, c).to_f64();
                }
            }
        }
        grad
    })
}

/// Run gradient descent on all codebooks of one weight matrix.
///
/// `w` original weights (paper layout), `h` dampened Hessian, `groups`
/// quantized groups (assignments and scales fixed; centroids mutated).
pub fn codebook_update(w: &Matrix, h: &Matrix, groups: &mut [VqGroup], iters: usize) -> UpdateStats {
    codebook_update_on(w, h, groups, iters, WorkerPool::inline(), Precision::F64)
}

/// `codebook_update` with the per-iteration matmul and per-group gradient
/// accumulation parallelized (bitwise identical for any thread count).
/// Standalone-use wrapper around [`codebook_update_on`].
pub fn codebook_update_threaded(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    iters: usize,
    n_threads: usize,
) -> UpdateStats {
    codebook_update_on(w, h, groups, iters, &WorkerPool::new(n_threads), Precision::F64)
}

/// `codebook_update_threaded` with a selectable compute width for the
/// dominating per-probe `E @ H` matmul (the codebook-update arm of
/// `--precision f32`). [`Precision::F64`] is the exact reference path.
/// Standalone-use wrapper around [`codebook_update_on`].
pub fn codebook_update_prec(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    iters: usize,
    n_threads: usize,
    precision: Precision,
) -> UpdateStats {
    codebook_update_on(w, h, groups, iters, &WorkerPool::new(n_threads), precision)
}

/// The pool-borrowing codebook update: per-probe loss matmul, line-search
/// decode, and per-group gradient accumulation all run on `pool`
/// (bitwise identical for any pool width). This is the engine's entry.
pub fn codebook_update_on(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    iters: usize,
    pool: &WorkerPool,
    precision: Precision,
) -> UpdateStats {
    match precision {
        Precision::F64 => codebook_update_g::<f64>(w, h, groups, iters, pool),
        Precision::F32 => codebook_update_g::<f32>(w, h, groups, iters, pool),
    }
}

/// The generic update loop. Centroids, learning rate, and gradient
/// accumulation stay f64 at every precision; the element width `E` decides
/// where the per-probe loss matmul runs. For `E = f64` the conversions
/// are identities and the loop executes the historical double-precision
/// computation operation for operation; for `E = f32` the line search
/// accepts/rejects on single-precision losses (the final authoritative
/// loss in `GptvqStats` is always recomputed in f64 by the engine).
fn codebook_update_g<E: Element>(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    iters: usize,
    pool: &WorkerPool,
) -> UpdateStats {
    let (rows, cols) = (w.rows(), w.cols());
    // detlint: allow(precision-cast, the single documented f64->E narrowing at update entry (PR 3 boundary))
    let w_e: MatrixG<E> = w.convert();
    // detlint: allow(precision-cast, the single documented f64->E narrowing at update entry (PR 3 boundary))
    let h_e: MatrixG<E> = h.convert();
    let q = decode_groups_on(rows, cols, groups, pool);
    // eh doubles as the gradient source of the next iteration (§Perf:
    // one matmul per accepted step instead of two)
    let (loss_before, mut eh) = loss_and_eh_in(&sub_narrowed(&w_e, &q), &h_e, pool);
    let mut loss = loss_before;

    // initial step: normalize by the Hessian's largest diagonal entry as a
    // curvature proxy; backtracking handles the rest
    let hmax = (0..cols).fold(1e-30f64, |m, i| m.max(h.get(i, i)));
    let mut lr = 0.5 / hmax;
    let mut iterations = 0;

    for _ in 0..iters {
        iterations += 1;
        // dL/dQ = -2 (W - Q) H = -2 eh; we descend so apply C -= lr * grad
        let mut dq = eh.clone();
        // detlint: allow(precision-cast, exact constant: -2.0 is representable in every Element width)
        dq.scale(E::from_f64(-2.0));
        let grads = codebook_grads(groups, &dq, pool);

        // backtracking line search on the true loss
        let saved: Vec<Vec<f64>> = groups.iter().map(|g| g.codebook.centroids.clone()).collect();
        let mut accepted = false;
        for _try in 0..6 {
            for (g, grad) in groups.iter_mut().zip(&grads) {
                for (c, gr) in g.codebook.centroids.iter_mut().zip(grad) {
                    *c -= lr * gr;
                }
            }
            let q = decode_groups_on(rows, cols, groups, pool);
            let (new_loss, new_eh) = loss_and_eh_in(&sub_narrowed(&w_e, &q), &h_e, pool);
            if new_loss <= loss {
                loss = new_loss;
                eh = new_eh;
                lr *= 1.2; // reward progress
                accepted = true;
                break;
            }
            // revert and shrink
            for (g, s) in groups.iter_mut().zip(&saved) {
                g.codebook.centroids.copy_from_slice(s);
            }
            lr *= 0.25;
        }
        if !accepted {
            break; // centroids already reverted; `loss` is current
        }
    }

    UpdateStats { loss_before, loss_after: loss, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::scales::unit_scales;
    use crate::quant::vq::{assign_diag, Codebook};
    use crate::tensor::matmul;
    use crate::util::prop::check;
    use crate::util::Rng;

    /// Build a single full-matrix group from a codebook via assignment.
    fn make_group(w: &Matrix, cb: Codebook) -> VqGroup {
        let (r, c) = (w.rows(), w.cols());
        let d = cb.d;
        let strips = c / d;
        let mut pts = Matrix::zeros(r * strips, d);
        for row in 0..r {
            for j in 0..strips {
                for t in 0..d {
                    pts.set(row * strips + j, t, w.get(row, j * d + t));
                }
            }
        }
        let h1 = Matrix::from_fn(r * strips, d, |_, _| 1.0);
        let assignments = assign_diag(&pts, &cb, &h1);
        VqGroup {
            row0: 0,
            row1: r,
            col0: 0,
            col1: c,
            codebook: cb,
            assignments,
            scales: unit_scales(r, c),
        }
    }

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.gaussian());
        let mut h = matmul(&b, &b.transpose());
        for i in 0..n {
            h.set(i, i, h.get(i, i) + 0.5);
        }
        h
    }

    #[test]
    fn update_never_increases_loss() {
        check("codebook update monotone", 8, |rng| {
            let (r, c, d, k) = (4 + rng.below(4), 8 + 2 * rng.below(5), 2, 4);
            let c_aligned = c - (c % d);
            let w = Matrix::from_fn(r, c_aligned, |_, _| rng.gaussian());
            let h = spd(rng, c_aligned);
            let cb = Codebook::from_centroids(d, rng.gaussian_vec(k * d));
            let mut groups = vec![make_group(&w, cb)];
            let stats = codebook_update(&w, &h, &mut groups, 15);
            if stats.loss_after <= stats.loss_before + 1e-9 {
                Ok(())
            } else {
                Err(format!("{} -> {}", stats.loss_before, stats.loss_after))
            }
        });
    }

    #[test]
    fn threaded_update_matches_single_threaded_bitwise() {
        let mut rng = Rng::new(14);
        // several groups + a big-enough matrix so both parallel paths
        // (matmul row bands, per-group gradients) genuinely engage
        let w = Matrix::from_fn(32, 128, |_, _| rng.gaussian());
        let h = spd(&mut rng, 128);
        let run = |nt: usize, rng_seed: u64| {
            let mut rr = Rng::new(rng_seed);
            let mut groups: Vec<VqGroup> = (0..4)
                .map(|s| {
                    let sub = Matrix::from_fn(8, 128, |r, c| w.get(s * 8 + r, c));
                    let cb = Codebook::from_centroids(2, rr.gaussian_vec(8));
                    let mut g = make_group(&sub, cb);
                    g.row0 = s * 8;
                    g.row1 = (s + 1) * 8;
                    g
                })
                .collect();
            let stats = codebook_update_threaded(&w, &h, &mut groups, 10, nt);
            (stats, groups)
        };
        let (s1, g1) = run(1, 99);
        for nt in [2, 4] {
            let (sn, gn) = run(nt, 99);
            assert_eq!(sn.loss_after, s1.loss_after, "{nt} threads");
            for (a, b) in gn.iter().zip(&g1) {
                assert_eq!(a.codebook.centroids, b.codebook.centroids, "{nt} threads");
            }
        }
    }

    #[test]
    fn update_substantially_reduces_bad_codebook_loss() {
        let mut rng = Rng::new(11);
        let w = Matrix::from_fn(8, 16, |_, _| rng.gaussian());
        let h = spd(&mut rng, 16);
        // deliberately bad codebook (all centroids near 10)
        let cb = Codebook::from_centroids(2, (0..8).map(|i| 10.0 + i as f64 * 0.01).collect());
        let mut groups = vec![make_group(&w, cb)];
        let stats = codebook_update(&w, &h, &mut groups, 50);
        assert!(
            stats.loss_after < 0.5 * stats.loss_before,
            "{} -> {}",
            stats.loss_before,
            stats.loss_after
        );
    }

    #[test]
    fn perfect_codebook_stays_put() {
        // if Q already equals W the gradient is zero
        let w = Matrix::from_vec(2, 2, vec![1.0, 2.0, 1.0, 2.0]).unwrap();
        let h = Matrix::identity(2);
        let cb = Codebook::from_centroids(2, vec![1.0, 2.0]);
        let mut groups = vec![make_group(&w, cb)];
        let stats = codebook_update(&w, &h, &mut groups, 5);
        assert!(stats.loss_before < 1e-18);
        assert!(stats.loss_after < 1e-18);
        assert!((groups[0].codebook.centroid(0)[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recon_loss_matches_naive() {
        check("tr form == frobenius of E X", 8, |rng| {
            let (r, c, n) = (3, 6, 40);
            let w = Matrix::from_fn(r, c, |_, _| rng.gaussian());
            let q = Matrix::from_fn(r, c, |_, _| rng.gaussian());
            let x = Matrix::from_fn(c, n, |_, _| rng.gaussian());
            // H = X X^T (unnormalized)
            let h = matmul(&x, &x.transpose());
            let lhs = recon_loss(&w, &q, &h);
            let e = w.sub(&q);
            let ex = matmul(&e, &x);
            let rhs = ex.frob_norm_sq();
            if (lhs - rhs).abs() < 1e-6 * (1.0 + rhs) {
                Ok(())
            } else {
                Err(format!("{lhs} vs {rhs}"))
            }
        });
    }

    #[test]
    fn scales_are_respected_in_gradient() {
        // with a scale of 2 on all weights, the decoded Q doubles; the
        // update must still converge toward W
        let mut rng = Rng::new(12);
        let w = Matrix::from_fn(2, 4, |_, _| rng.gaussian());
        let h = Matrix::identity(4);
        let cb = Codebook::from_centroids(2, vec![0.1, 0.1, -0.1, -0.1]);
        let mut g = make_group(&w, cb);
        // double all scales by hacking the offset (z=1 in log2 space)
        g.scales.z = 1.0;
        let mut groups = vec![g];
        let stats = codebook_update(&w, &h, &mut groups, 60);
        // assignments are frozen (2 centroids for 8 weights), so the
        // optimum is the scale-weighted cluster mean — substantial but
        // not total loss reduction
        assert!(
            stats.loss_after < stats.loss_before * 0.9,
            "{} -> {}",
            stats.loss_before,
            stats.loss_after
        );
    }
}
