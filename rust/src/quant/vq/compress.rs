//! Codebook compression (paper §3.3, Table 8): int8 codebook quantization
//! and SVD-based rank reduction of the codebook tensor (1D VQ only — the
//! paper found SVD ineffective for d > 1).

use crate::error::Result;
use crate::linalg::svd_thin;
use crate::quant::vq::update::recon_loss_on;
use crate::quant::vq::{decode_groups_on, VqGroup};
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, matmul_on, Matrix};
use crate::util::WorkerPool;

/// Quantize one codebook's centroids to signed 8-bit integers with
/// symmetric min-max (paper: "signed 8-bit, symmetric min-max"). Returns
/// the scale used; centroids are replaced by their dequantized values.
pub fn quantize_codebook_int8(centroids: &mut [f64]) -> f64 {
    let mx = centroids.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    if mx == 0.0 {
        return 1.0;
    }
    let scale = mx / 127.0;
    for c in centroids.iter_mut() {
        let q = (*c / scale).round().clamp(-127.0, 127.0);
        *c = q * scale;
    }
    scale
}

/// Apply int8 quantization to every group's codebook (the default
/// post-processing; Table 8 shows 8-bit codebooks + halved group size beat
/// fp16 codebooks at equal overhead).
pub fn quantize_all_codebooks_int8(groups: &mut [VqGroup]) -> Vec<f64> {
    groups
        .iter_mut()
        .map(|g| quantize_codebook_int8(&mut g.codebook.centroids))
        .collect()
}

/// Statistics from the SVD compression step.
#[derive(Debug, Clone)]
pub struct SvdStats {
    /// rank actually stored (thin-SVD clamped to min(n_groups, k))
    pub rank: usize,
    /// layer loss entering the compression
    pub loss_before: f64,
    /// layer loss after factor fine-tuning
    pub loss_after: f64,
    /// gradient-descent iterations spent on the factors
    pub gd_iterations: usize,
}

/// SVD codebook compression for 1D VQ (paper §3.3).
///
/// Stacks all `N_G` codebooks of one weight matrix into `C [N_G, k]`,
/// sorts each codebook (reassigning indices), factorizes `C ≈ U'' V'^T`
/// with rank `k * rank_frac`, then fine-tunes the factors by gradient
/// descent on the layerwise loss (same objective as `codebook_update`).
/// Only `U''` carries per-group storage cost, halving codebook overhead
/// at `rank_frac = 0.5`.
pub fn svd_compress_1d(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    rank_frac: f64,
    gd_iters: usize,
) -> Result<SvdStats> {
    svd_compress_1d_on(w, h, groups, rank_frac, gd_iters, WorkerPool::inline())
}

/// [`svd_compress_1d`] with the per-iteration decode, loss, and `E @ H`
/// gradient matmul running on a borrowed [`WorkerPool`] (bitwise
/// identical for any pool width). This is the engine's entry.
pub fn svd_compress_1d_on(
    w: &Matrix,
    h: &Matrix,
    groups: &mut [VqGroup],
    rank_frac: f64,
    gd_iters: usize,
    pool: &WorkerPool,
) -> Result<SvdStats> {
    assert!(!groups.is_empty());
    let d = groups[0].codebook.d;
    assert_eq!(d, 1, "svd compression applies to 1D VQ only");
    let k = groups[0].codebook.k;
    let ng = groups.len();
    let (rows, cols) = (w.rows(), w.cols());

    let q0 = decode_groups_on(rows, cols, groups, pool);
    let loss_before = recon_loss_on(w, &q0, h, pool);

    // 1. sort every codebook ascending and remap assignments
    for g in groups.iter_mut() {
        let mut order: Vec<usize> = (0..k).collect();
        let cents = g.codebook.centroids.clone();
        order.sort_by(|&a, &b| cents[a].total_cmp(&cents[b]));
        let mut remap = vec![0u32; k];
        for (new_i, &old_i) in order.iter().enumerate() {
            g.codebook.centroids[new_i] = cents[old_i];
            remap[old_i] = new_i as u32;
        }
        for a in g.assignments.iter_mut() {
            *a = remap[*a as usize];
        }
    }

    // 2. stack into C [N_G, k] and factorize
    let c_mat = Matrix::from_fn(ng, k, |g, m| groups[g].codebook.centroids[m]);
    let svd = svd_thin(&c_mat)?;
    let rank = ((k as f64 * rank_frac).round() as usize).clamp(1, svd.s.len());
    // U'' = U Σ truncated, V' = V truncated
    let mut u = Matrix::zeros(ng, rank);
    for g in 0..ng {
        for r in 0..rank {
            u.set(g, r, svd.u.get(g, r) * svd.s[r]);
        }
    }
    let mut v = Matrix::zeros(k, rank);
    for m in 0..k {
        for r in 0..rank {
            v.set(m, r, svd.v.get(m, r));
        }
    }

    // 3. GD on the factors: C_hat = U V^T, dL/dC -> dL/dU = dL/dC V,
    //    dL/dV = dL/dC^T U, with backtracking like codebook_update.
    let write_back = |groups: &mut [VqGroup], u: &Matrix, v: &Matrix| {
        let c_hat = matmul_a_bt(u, v); // [ng, k]
        for (gi, g) in groups.iter_mut().enumerate() {
            g.codebook.centroids.copy_from_slice(c_hat.row(gi));
        }
    };
    write_back(groups, &u, &v);
    let mut q = decode_groups_on(rows, cols, groups, pool);
    let mut loss = recon_loss_on(w, &q, h, pool);

    let hmax = (0..cols).fold(1e-30f64, |m, i| m.max(h.get(i, i)));
    let mut lr = 0.25 / hmax;
    let mut gd_iterations = 0;
    for _ in 0..gd_iters {
        gd_iterations += 1;
        let e = w.sub(&q);
        let mut dq = matmul_on(&e, h, pool);
        dq.scale(-2.0);
        // dL/dC [ng, k]: scatter dq through assignments and scales
        let mut dc = Matrix::zeros(ng, k);
        for (gi, g) in groups.iter().enumerate() {
            let strips = g.strips();
            for r in g.row0..g.row1 {
                let lr_ = r - g.row0;
                for j in 0..strips {
                    let a = g.assignments[lr_ * strips + j] as usize;
                    let c = g.col0 + j;
                    let s = g.scales.scale_at(lr_, c - g.col0);
                    dc.set(gi, a, dc.get(gi, a) + s * dq.get(r, c));
                }
            }
        }
        let du = matmul(&dc, &v); // [ng, rank]
        let dv = matmul_at_b(&dc, &u); // [k, rank]

        let (u_save, v_save) = (u.clone(), v.clone());
        let mut accepted = false;
        for _try in 0..6 {
            for (uv, g) in u.as_mut_slice().iter_mut().zip(du.as_slice()) {
                *uv -= lr * g;
            }
            for (vv, g) in v.as_mut_slice().iter_mut().zip(dv.as_slice()) {
                *vv -= lr * g;
            }
            write_back(groups, &u, &v);
            q = decode_groups_on(rows, cols, groups, pool);
            let new_loss = recon_loss_on(w, &q, h, pool);
            if new_loss <= loss {
                loss = new_loss;
                lr *= 1.2;
                accepted = true;
                break;
            }
            u = u_save.clone();
            v = v_save.clone();
            write_back(groups, &u, &v);
            lr *= 0.25;
        }
        if !accepted {
            break; // final loss recomputed after int8 step below
        }
    }

    // 4. only U'' is stored quantized (paper); simulate by int8-quantizing
    //    the reconstructed codebooks per group
    quantize_all_codebooks_int8(groups);
    let qf = decode_groups_on(rows, cols, groups, pool);
    let loss_after = recon_loss_on(w, &qf, h, pool);

    Ok(SvdStats { rank, loss_before, loss_after, gd_iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::scales::unit_scales;
    use crate::quant::vq::{assign_diag, decode_groups, Codebook};
    use crate::util::prop::check;
    use crate::util::Rng;

    #[test]
    fn int8_quantization_bounded_error() {
        check("int8 codebook error <= scale/2", 15, |rng| {
            let n = 4 + rng.below(60);
            let mut c: Vec<f64> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
            let orig = c.clone();
            let scale = quantize_codebook_int8(&mut c);
            for (q, o) in c.iter().zip(&orig) {
                if (q - o).abs() > 0.5 * scale + 1e-12 {
                    return Err(format!("{o} -> {q} with scale {scale}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_zero_codebook_noop() {
        let mut c = vec![0.0; 8];
        let s = quantize_codebook_int8(&mut c);
        assert_eq!(s, 1.0);
        assert!(c.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int8_max_value_exact() {
        let mut c = vec![1.27, -1.27, 0.0];
        quantize_codebook_int8(&mut c);
        assert!((c[0] - 1.27).abs() < 1e-12);
        assert!((c[1] + 1.27).abs() < 1e-12);
    }

    fn build_1d_groups(rng: &mut Rng, rows: usize, cols: usize, k: usize, ng: usize) -> (Matrix, Vec<VqGroup>) {
        // groups split rows into `ng` strips over all columns
        let w = Matrix::from_fn(rows, cols, |_, _| rng.gaussian());
        let rpg = rows / ng;
        let mut groups = Vec::new();
        for gi in 0..ng {
            let row0 = gi * rpg;
            let row1 = if gi == ng - 1 { rows } else { row0 + rpg };
            let sub = w.slice_rows(row0, row1);
            let n = sub.rows() * sub.cols();
            let pts = Matrix::from_vec(n, 1, sub.as_slice().to_vec()).unwrap();
            let h1 = Matrix::from_fn(n, 1, |_, _| 1.0);
            let cb = Codebook::from_centroids(1, rng.gaussian_vec(k));
            let assignments = assign_diag(&pts, &cb, &h1);
            groups.push(VqGroup {
                row0,
                row1,
                col0: 0,
                col1: cols,
                codebook: cb,
                assignments,
                scales: unit_scales(row1 - row0, cols),
            });
        }
        (w, groups)
    }

    #[test]
    fn svd_sorting_preserves_decoded_weights() {
        let mut rng = Rng::new(21);
        let (w, mut groups) = build_1d_groups(&mut rng, 8, 8, 8, 2);
        let before = decode_groups(8, 8, &groups);
        // run with rank = full and 0 GD iters: sorting must not change Q
        let h = Matrix::identity(8);
        let stats = svd_compress_1d(&w, &h, &mut groups, 1.0, 0).unwrap();
        let after = decode_groups(8, 8, &groups);
        // full-rank + int8 only: small difference from int8 rounding
        let diff = before.sub(&after).max_abs();
        let max_scale = groups
            .iter()
            .map(|g| g.codebook.centroids.iter().fold(0.0f64, |m, v| m.max(v.abs())))
            .fold(0.0f64, f64::max);
        assert!(diff <= max_scale / 127.0 + 1e-9, "diff {diff}");
        // thin rank is bounded by the number of groups (2 here)
        assert_eq!(stats.rank, 2);
    }

    #[test]
    fn svd_half_rank_with_gd_recovers_loss() {
        let mut rng = Rng::new(22);
        // correlated codebooks across groups -> low-rank C is a good fit
        let (w, mut groups) = build_1d_groups(&mut rng, 16, 16, 8, 4);
        let h = Matrix::identity(16);
        let no_gd = {
            let mut gs = groups.clone();
            svd_compress_1d(&w, &h, &mut gs, 0.5, 0).unwrap()
        };
        let with_gd = svd_compress_1d(&w, &h, &mut groups, 0.5, 25).unwrap();
        assert_eq!(with_gd.rank, 4);
        assert!(
            with_gd.loss_after <= no_gd.loss_after + 1e-9,
            "gd {} vs no-gd {}",
            with_gd.loss_after,
            no_gd.loss_after
        );
    }

    #[test]
    fn svd_rejects_multidim() {
        let mut rng = Rng::new(23);
        let w = Matrix::from_fn(4, 4, |_, _| rng.gaussian());
        let h = Matrix::identity(4);
        let cb = Codebook::from_centroids(2, rng.gaussian_vec(8));
        let pts = Matrix::from_fn(8, 2, |r, c| w.get(r / 2, (r % 2) * 2 + c));
        let h1 = Matrix::from_fn(8, 2, |_, _| 1.0);
        let assignments = assign_diag(&pts, &cb, &h1);
        let mut groups = vec![VqGroup {
            row0: 0,
            row1: 4,
            col0: 0,
            col1: 4,
            codebook: cb,
            assignments,
            scales: unit_scales(4, 4),
        }];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            svd_compress_1d(&w, &h, &mut groups, 0.5, 1)
        }));
        assert!(result.is_err(), "should assert on d != 1");
    }
}
