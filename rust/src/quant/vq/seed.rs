//! EM seeding methods (paper §4.3, Table 6): the paper's fast
//! "Mahalanobis" initialization and the k-means++ baseline.

use crate::error::Result;
use crate::linalg::mahalanobis_distances;
use crate::quant::vq::{weighted_dist_diag, Codebook};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Seeding strategy selector (ablated in Table 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeedMethod {
    /// The paper's fast sorted-Mahalanobis-distance seeding.
    Mahalanobis,
    /// Hessian-weighted k-means++ (Arthur & Vassilvitskii, 2007).
    KmeansPlusPlus,
}

/// Mahalanobis seeding: sort points by Mahalanobis distance to the data
/// mean and take `k` points equally spaced through the sorted list — cheap
/// and (per the paper) on par with k-means++ quality.
pub fn seed_mahalanobis(points: &Matrix, k: usize) -> Result<Codebook> {
    let (n, d) = (points.rows(), points.cols());
    assert!(n > 0);
    let dists = mahalanobis_distances(points)?;
    let mut order: Vec<usize> = (0..n).collect();
    // total order: a NaN distance (degenerate covariance) sorts to the
    // tail deterministically instead of panicking the seeding
    order.sort_by(|&a, &b| dists[a].total_cmp(&dists[b]));
    let mut centroids = Vec::with_capacity(k * d);
    for m in 0..k {
        // equally spaced through the sorted list, inclusive of both ends
        let pos = if k == 1 { 0 } else { m * (n - 1) / (k - 1) };
        centroids.extend_from_slice(points.row(order[pos.min(n - 1)]));
    }
    Ok(Codebook::from_centroids(d, centroids))
}

/// k-means++ seeding (Arthur & Vassilvitskii, 2007) with Hessian-weighted
/// distances so it optimizes the same objective as the EM that follows.
pub fn seed_kmeanspp(points: &Matrix, hdiag: &Matrix, k: usize, rng: &mut Rng) -> Codebook {
    let (n, d) = (points.rows(), points.cols());
    assert!(n > 0);
    let mut centroids: Vec<f64> = Vec::with_capacity(k * d);
    let first = rng.below(n);
    centroids.extend_from_slice(points.row(first));
    let mut min_dist: Vec<f64> = (0..n)
        .map(|i| weighted_dist_diag(points.row(i), &centroids[0..d], hdiag.row(i)))
        .collect();
    for m in 1..k {
        let pick = rng.weighted_choice(&min_dist);
        let new_c = points.row(pick).to_vec();
        centroids.extend_from_slice(&new_c);
        if m + 1 < k {
            for i in 0..n {
                let dist = weighted_dist_diag(points.row(i), &new_c, hdiag.row(i));
                if dist < min_dist[i] {
                    min_dist[i] = dist;
                }
            }
        }
    }
    Codebook::from_centroids(d, centroids)
}

/// Dispatch helper.
pub fn seed(
    method: SeedMethod,
    points: &Matrix,
    hdiag: &Matrix,
    k: usize,
    rng: &mut Rng,
) -> Result<Codebook> {
    match method {
        SeedMethod::Mahalanobis => seed_mahalanobis(points, k),
        SeedMethod::KmeansPlusPlus => Ok(seed_kmeanspp(points, hdiag, k, rng)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::{assign_diag, assignment_error};
    use crate::util::prop::check;

    fn clustered_points(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        // two well-separated clusters
        Matrix::from_fn(n, d, |r, _| rng.gaussian() * 0.2 + if r % 2 == 0 { -3.0 } else { 3.0 })
    }

    #[test]
    fn mahalanobis_returns_k_centroids_from_data() {
        check("seeds are data points", 10, |rng| {
            let d = [1, 2, 4][rng.below(3)];
            let n = 20 + rng.below(100);
            let k = 2 + rng.below(6);
            let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
            let cb = seed_mahalanobis(&pts, k).map_err(|e| e.to_string())?;
            if cb.k != k || cb.d != d {
                return Err("wrong shape".into());
            }
            for m in 0..k {
                let c = cb.centroid(m);
                let found = (0..n).any(|i| {
                    pts.row(i).iter().zip(c).all(|(a, b)| (a - b).abs() < 1e-12)
                });
                if !found {
                    return Err(format!("centroid {m} is not a data point"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mahalanobis_spans_inner_to_outer() {
        let mut rng = Rng::new(3);
        let pts = Matrix::from_fn(500, 2, |_, _| rng.gaussian());
        let cb = seed_mahalanobis(&pts, 8).unwrap();
        // first centroid should be near the mean, last in the far tail
        let norm = |c: &[f64]| (c[0] * c[0] + c[1] * c[1]).sqrt();
        assert!(norm(cb.centroid(0)) < norm(cb.centroid(7)));
    }

    #[test]
    fn kmeanspp_centroids_are_distinct_for_clustered_data() {
        let mut rng = Rng::new(4);
        let pts = clustered_points(&mut rng, 200, 2);
        let h = Matrix::from_fn(200, 2, |_, _| 1.0);
        let cb = seed_kmeanspp(&pts, &h, 2, &mut rng);
        // one centroid per cluster: they must be far apart
        let c0 = cb.centroid(0);
        let c1 = cb.centroid(1);
        let dist: f64 = c0.iter().zip(c1).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(dist > 4.0, "centroids too close: {dist}");
    }

    #[test]
    fn both_seeds_give_finite_objective() {
        check("seed objective finite", 8, |rng| {
            let d = [1, 2][rng.below(2)];
            let n = 30 + rng.below(50);
            let k = 4;
            let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
            let h = Matrix::from_fn(n, d, |_, _| rng.range(0.5, 1.5));
            for method in [SeedMethod::Mahalanobis, SeedMethod::KmeansPlusPlus] {
                let cb = seed(method, &pts, &h, k, rng).map_err(|e| e.to_string())?;
                let a = assign_diag(&pts, &cb, &h);
                let err = assignment_error(&pts, &cb, &h, &a);
                if !err.is_finite() {
                    return Err(format!("{method:?}: non-finite objective"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn k_equals_one() {
        let mut rng = Rng::new(5);
        let pts = Matrix::from_fn(10, 2, |_, _| rng.gaussian());
        let cb = seed_mahalanobis(&pts, 1).unwrap();
        assert_eq!(cb.k, 1);
    }

    #[test]
    fn k_larger_than_n_repeats_points() {
        let mut rng = Rng::new(6);
        let pts = Matrix::from_fn(3, 1, |_, _| rng.gaussian());
        let cb = seed_mahalanobis(&pts, 8).unwrap();
        assert_eq!(cb.k, 8); // must not panic; duplicates are fine
    }

    #[test]
    fn mahalanobis_seeding_tolerates_nan_points() {
        // NaN-tolerance regression for the seeding sort: one poisoned
        // weight row used to panic the partial_cmp().unwrap() distance
        // comparator; under total_cmp seeding completes with k centroids
        // drawn from the (deterministically ordered) point list
        let mut rng = Rng::new(7);
        let mut pts = Matrix::from_fn(16, 2, |_, _| rng.gaussian());
        pts.set(5, 0, f64::NAN);
        let cb = seed_mahalanobis(&pts, 4).expect("NaN point must not panic seeding");
        assert_eq!(cb.k, 4);
        assert_eq!(cb.centroids.len(), 4 * 2);
    }
}
