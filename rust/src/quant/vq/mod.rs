//! Vector-quantization machinery: codebooks, Hessian-weighted assignment
//! (paper eq. 4), EM initialization (§3.2), seeding (§4.3), blockwise data
//! normalization (§3.2), codebook update (§3.3) and codebook compression
//! (§3.3).
//!
//! The assignment/EM hot path is precision-generic: [`CodebookG`],
//! [`assign_diag`], and the distance kernels are parameterized over
//! [`Element`] so the GPTVQ engine can run them in `f32`
//! (`--precision f32`) with the `f64` instantiation remaining the exact
//! reference computation. Group bookkeeping ([`VqGroup`], scales, the
//! packed container) stays `f64`: codebooks are widened back at the
//! precision boundary, which is lossless for values produced in `f32`.

pub mod compress;
pub mod em;
pub mod scales;
pub mod seed;
pub mod update;

use crate::tensor::{Element, Matrix, MatrixG};
use crate::util::WorkerPool;

use scales::BlockScales;

/// One quantized weight group: a (row-strip × column-span) tile of the
/// weight matrix sharing a codebook (paper §3.2 "group of weights").
#[derive(Debug, Clone)]
pub struct VqGroup {
    /// first row of the tile in the paper-layout weight matrix
    pub row0: usize,
    /// one past the last row of the tile
    pub row1: usize,
    /// first column of the tile
    pub col0: usize,
    /// one past the last column of the tile
    pub col1: usize,
    /// the codebook shared by every weight of the tile (always f64;
    /// the f32 path widens back at the precision boundary)
    pub codebook: Codebook,
    /// assignments, row-major over (row, strip): strip j covers columns
    /// [col0 + j*d, col0 + (j+1)*d)
    pub assignments: Vec<u32>,
    /// blockwise normalization scales in group-local coordinates
    pub scales: BlockScales,
}

impl VqGroup {
    /// Number of d-column strips in the span.
    pub fn strips(&self) -> usize {
        (self.col1 - self.col0) / self.codebook.d
    }

    /// Number of rows in the group's strip.
    pub fn group_rows(&self) -> usize {
        self.row1 - self.row0
    }

    /// Number of weights in the group (the paper's `l`).
    pub fn len(&self) -> usize {
        self.group_rows() * (self.col1 - self.col0)
    }

    /// True when the group covers no weights.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decoded weight at matrix coordinates (r, c) inside this group.
    #[inline]
    pub fn decode_at(&self, r: usize, c: usize) -> f64 {
        let d = self.codebook.d;
        let lr = r - self.row0;
        let lc = c - self.col0;
        let strip = lc / d;
        let t = lc % d;
        let a = self.assignments[lr * self.strips() + strip] as usize;
        self.codebook.centroid(a)[t] * self.scales.scale_at(lr, lc)
    }

    /// Write this group's decoded weights into `out` (paper layout).
    pub fn decode_into(&self, out: &mut Matrix) {
        for r in self.row0..self.row1 {
            for c in self.col0..self.col1 {
                out.set(r, c, self.decode_at(r, c));
            }
        }
    }
}

/// Decode a full set of groups into a dense [rows, cols] matrix.
pub fn decode_groups(rows: usize, cols: usize, groups: &[VqGroup]) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    for g in groups {
        g.decode_into(&mut out);
    }
    out
}

/// [`decode_groups`] with the output split into contiguous row bands
/// across the lanes of a borrowed [`WorkerPool`]. Groups are disjoint
/// (row-strip × column-span) tiles and every decoded element is a pure
/// function of its group, so the result is bitwise identical to the
/// serial decode for every pool width; small matrices run inline.
///
/// This is the decode that sits inside the codebook-update line search
/// (one full-matrix decode per GD probe — the §3.3 hot loop) and the
/// SVD compression path.
pub fn decode_groups_on(
    rows: usize,
    cols: usize,
    groups: &[VqGroup],
    pool: &WorkerPool,
) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    // ~4 scalar ops per decoded element (index math + lookup + scale)
    let nt = pool.threads_for(rows.saturating_mul(cols).saturating_mul(4));
    if nt <= 1 {
        for g in groups {
            g.decode_into(&mut out);
        }
        return out;
    }
    crate::util::parallel_row_bands(pool, out.as_mut_slice(), rows, cols, nt, |row0, band| {
        let band_rows = band.len() / cols;
        let r1 = row0 + band_rows;
        for g in groups {
            for r in g.row0.max(row0)..g.row1.min(r1) {
                for c in g.col0..g.col1 {
                    band[(r - row0) * cols + c] = g.decode_at(r, c);
                }
            }
        }
    });
    out
}

/// Standalone-use wrapper around [`decode_groups_on`] taking a thread
/// count (0 = all cores) instead of a borrowed pool.
pub fn decode_groups_threaded(
    rows: usize,
    cols: usize,
    groups: &[VqGroup],
    n_threads: usize,
) -> Matrix {
    decode_groups_on(rows, cols, groups, &WorkerPool::new(n_threads))
}

/// A VQ codebook: `k` centroids of dimension `d`, stored row-major [k, d],
/// generic over the element width. [`Codebook`] (= `CodebookG<f64>`) is
/// the canonical form stored in [`VqGroup`]s and containers; the `f32`
/// instantiation lives only inside the single-precision EM/assignment
/// fast path.
#[derive(Debug, Clone)]
pub struct CodebookG<E: Element> {
    /// VQ dimension (coordinates per centroid).
    pub d: usize,
    /// Number of centroids.
    pub k: usize,
    /// Centroid coordinates, row-major [k, d].
    pub centroids: Vec<E>,
}

/// The canonical double-precision codebook.
pub type Codebook = CodebookG<f64>;

impl<E: Element> CodebookG<E> {
    /// All-zero codebook of `k` centroids of dimension `d`.
    pub fn new(d: usize, k: usize) -> CodebookG<E> {
        CodebookG { d, k, centroids: vec![E::ZERO; k * d] }
    }

    /// Wrap a flat centroid buffer (length must be a multiple of `d`).
    pub fn from_centroids(d: usize, centroids: Vec<E>) -> CodebookG<E> {
        assert_eq!(centroids.len() % d, 0);
        let k = centroids.len() / d;
        CodebookG { d, k, centroids }
    }

    /// Centroid `m` as a `d`-length slice.
    #[inline]
    pub fn centroid(&self, m: usize) -> &[E] {
        &self.centroids[m * self.d..(m + 1) * self.d]
    }

    /// Centroid `m`, mutably.
    #[inline]
    pub fn centroid_mut(&mut self, m: usize) -> &mut [E] {
        &mut self.centroids[m * self.d..(m + 1) * self.d]
    }

    /// Copy into another element width (the precision boundary of the
    /// f32 EM path; `f32 -> f64` widening is exact).
    pub fn convert<F: Element>(&self) -> CodebookG<F> {
        CodebookG {
            d: self.d,
            k: self.k,
            // detlint: allow(precision-cast, CodebookG::convert is itself a boundary helper like Element::convert)
            centroids: self.centroids.iter().map(|&v| F::from_f64(v.to_f64())).collect(),
        }
    }

    /// Index bits per weight (`log2 k / d`), the paper's `b`.
    pub fn bits_per_dim(&self) -> f64 {
        (self.k as f64).log2() / self.d as f64
    }
}

/// Hessian-weighted squared distance between a point and a centroid with
/// diagonal weights (paper eq. 4, diagonal variant — the default; the
/// paper reports no difference vs the full sub-Hessian).
#[inline]
pub fn weighted_dist_diag<E: Element>(x: &[E], c: &[E], h: &[E]) -> E {
    let mut acc = E::ZERO;
    for i in 0..x.len() {
        let diff = x[i] - c[i];
        acc += h[i] * diff * diff;
    }
    acc
}

/// Full sub-Hessian distance `(x-c)^T H (x-c)` for small d.
pub fn weighted_dist_full(x: &[f64], c: &[f64], h: &Matrix) -> f64 {
    let d = x.len();
    let mut acc = 0.0;
    for i in 0..d {
        let di = x[i] - c[i];
        for j in 0..d {
            acc += di * h.get(i, j) * (x[j] - c[j]);
        }
    }
    acc
}

/// Assign every point (row of `points [n, d]`) to its Hessian-weighted
/// nearest centroid. `hdiag [n, d]` carries per-point diagonal weights.
/// Ties break to the lowest index (matching `jnp.argmin` / the L1 kernel).
/// Precision-generic: the `f64` instantiation is the reference path, the
/// `f32` one serves `--precision f32`.
pub fn assign_diag<E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
) -> Vec<u32> {
    assert_eq!(points.cols(), cb.d);
    assert_eq!(points.rows(), hdiag.rows());
    assert_eq!(points.cols(), hdiag.cols());
    // §Perf: the EM E-step is the 4D hot spot; fixed-d kernels let the
    // compiler unroll and vectorize the distance accumulation.
    match cb.d {
        1 => assign_diag_fixed::<1, E>(points, cb, hdiag),
        2 => assign_diag_fixed::<2, E>(points, cb, hdiag),
        4 => assign_diag_fixed::<4, E>(points, cb, hdiag),
        _ => assign_diag_generic(points, cb, hdiag),
    }
}

fn assign_diag_fixed<const D: usize, E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
) -> Vec<u32> {
    let n = points.rows();
    let cents = &cb.centroids;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x: &[E] = points.row(i);
        let h: &[E] = hdiag.row(i);
        let mut xa = [E::ZERO; D];
        let mut ha = [E::ZERO; D];
        xa.copy_from_slice(&x[..D]);
        ha.copy_from_slice(&h[..D]);
        let mut best = 0u32;
        let mut best_d = E::INFINITY;
        for (m, c) in cents.chunks_exact(D).enumerate() {
            let mut dist = E::ZERO;
            for t in 0..D {
                let diff = xa[t] - c[t];
                dist += ha[t] * diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = m as u32;
            }
        }
        out.push(best);
    }
    out
}

/// `assign_diag` with the points split into contiguous bands across up to
/// `n_threads` workers. Each point's argmin is independent, so the result
/// is identical for every thread count; small inputs run inline.
/// Standalone-use wrapper around [`assign_diag_on`].
pub fn assign_diag_threaded<E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
    n_threads: usize,
) -> Vec<u32> {
    let pool = WorkerPool::new(n_threads);
    let cap = pool.n_threads();
    assign_diag_on(points, cb, hdiag, &pool, cap)
}

/// `assign_diag` banded across the lanes of a borrowed [`WorkerPool`],
/// capped at `n_runners` (the engine's inner-budget knob when several
/// strips share the pool). Each point's argmin is independent, so the
/// result is identical for every pool width and cap; inputs below the
/// grain run inline.
pub fn assign_diag_on<E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
    pool: &WorkerPool,
    n_runners: usize,
) -> Vec<u32> {
    let n = points.rows();
    let nt = pool.threads_for(n * cb.k * cb.d).min(n_runners).min(n.max(1));
    if nt <= 1 {
        return assign_diag(points, cb, hdiag);
    }
    let band = n.div_ceil(nt);
    let n_bands = n.div_ceil(band);
    let bands = crate::util::parallel_map(pool, nt, n_bands, |bi| {
        let r0 = bi * band;
        let r1 = (r0 + band).min(n);
        assign_diag(&points.slice_rows(r0, r1), cb, &hdiag.slice_rows(r0, r1))
    });
    bands.concat()
}

fn assign_diag_generic<E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
) -> Vec<u32> {
    let n = points.rows();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = points.row(i);
        let h = hdiag.row(i);
        let mut best = 0u32;
        let mut best_d = E::INFINITY;
        for m in 0..cb.k {
            let dist = weighted_dist_diag(x, cb.centroid(m), h);
            if dist < best_d {
                best_d = dist;
                best = m as u32;
            }
        }
        out.push(best);
    }
    out
}

/// Assignment with full d×d sub-Hessians (one per point, usually shared
/// refs per column strip).
pub fn assign_full(points: &Matrix, cb: &Codebook, hfull: &[&Matrix]) -> Vec<u32> {
    assert_eq!(points.rows(), hfull.len());
    let n = points.rows();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = points.row(i);
        let mut best = 0u32;
        let mut best_d = f64::INFINITY;
        for m in 0..cb.k {
            let dist = weighted_dist_full(x, cb.centroid(m), hfull[i]);
            if dist < best_d {
                best_d = dist;
                best = m as u32;
            }
        }
        out.push(best);
    }
    out
}

/// Decode assignments back into points [n, d].
pub fn decode<E: Element>(cb: &CodebookG<E>, assignments: &[u32]) -> MatrixG<E> {
    let n = assignments.len();
    let mut out = MatrixG::zeros(n, cb.d);
    for (i, &a) in assignments.iter().enumerate() {
        out.row_mut(i).copy_from_slice(cb.centroid(a as usize));
    }
    out
}

/// Total Hessian-weighted quantization error of an assignment (the EM
/// objective, paper eq. 5, diagonal variant), accumulated in the element
/// width.
pub fn assignment_error<E: Element>(
    points: &MatrixG<E>,
    cb: &CodebookG<E>,
    hdiag: &MatrixG<E>,
    assignments: &[u32],
) -> E {
    let mut total = E::ZERO;
    for i in 0..points.rows() {
        total += weighted_dist_diag(points.row(i), cb.centroid(assignments[i] as usize), hdiag.row(i));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Rng;

    fn rand_setup(rng: &mut Rng, n: usize, d: usize, k: usize) -> (Matrix, Codebook, Matrix) {
        let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
        let cb = Codebook::from_centroids(d, rng.gaussian_vec(k * d));
        let h = Matrix::from_fn(n, d, |_, _| rng.range(0.1, 2.0));
        (pts, cb, h)
    }

    #[test]
    fn assignment_is_argmin() {
        check("assign == brute argmin", 20, |rng| {
            let d = [1, 2, 4][rng.below(3)];
            let k = 2 + rng.below(14);
            let n = 1 + rng.below(60);
            let (pts, cb, h) = rand_setup(rng, n, d, k);
            let got = assign_diag(&pts, &cb, &h);
            for i in 0..n {
                let mine = got[i] as usize;
                for m in 0..k {
                    let dm = weighted_dist_diag(pts.row(i), cb.centroid(m), h.row(i));
                    let dmine = weighted_dist_diag(pts.row(i), cb.centroid(mine), h.row(i));
                    if dm < dmine - 1e-12 {
                        return Err(format!("point {i}: {m} beats {mine}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn threaded_assignment_matches_single_threaded() {
        let mut rng = Rng::new(21);
        // 8192*16*2 = 262k > PAR_GRAIN, so the fan-out actually engages
        let (pts, cb, h) = rand_setup(&mut rng, 8_192, 2, 16);
        let single = assign_diag(&pts, &cb, &h);
        for nt in [2, 3, 4, 8] {
            assert_eq!(assign_diag_threaded(&pts, &cb, &h, nt), single, "{nt} threads");
        }
    }

    #[test]
    fn f32_assignment_matches_f64_on_separated_clusters() {
        // away from decision boundaries the two widths must agree exactly
        let mut rng = Rng::new(22);
        let cb = Codebook::from_centroids(2, vec![-3.0, -3.0, 3.0, 3.0, -3.0, 3.0, 3.0, -3.0]);
        let pts = Matrix::from_fn(200, 2, |r, c| cb.centroid(r % 4)[c] + 0.3 * rng.gaussian());
        let h = Matrix::from_fn(200, 2, |_, _| rng.range(0.5, 2.0));
        let a64 = assign_diag(&pts, &cb, &h);
        let a32 = assign_diag::<f32>(&pts.convert(), &cb.convert(), &h.convert());
        assert_eq!(a64, a32);
    }

    #[test]
    fn f32_threaded_assignment_matches_single_threaded() {
        // determinism contract at f32: banding never changes an argmin
        let mut rng = Rng::new(23);
        let (pts, cb, h) = rand_setup(&mut rng, 8_192, 2, 16);
        let pts32: crate::tensor::Matrix32 = pts.convert();
        let cb32: CodebookG<f32> = cb.convert();
        let h32: crate::tensor::Matrix32 = h.convert();
        let single = assign_diag(&pts32, &cb32, &h32);
        for nt in [2, 4, 8] {
            assert_eq!(assign_diag_threaded(&pts32, &cb32, &h32, nt), single, "{nt} threads");
        }
    }

    fn random_tiling(rng: &mut Rng, rows: usize, cols: usize, d: usize, k: usize) -> Vec<VqGroup> {
        // tile the matrix into (row-strip × column-span) groups with
        // random codebooks/assignments and non-trivial scales
        let mut groups = Vec::new();
        let span = 8;
        let strip = 6;
        let mut c0 = 0;
        while c0 < cols {
            let c1 = (c0 + span).min(cols);
            let mut r0 = 0;
            while r0 < rows {
                let r1 = (r0 + strip).min(rows);
                let cb = Codebook::from_centroids(d, rng.gaussian_vec(k * d));
                let strips = (c1 - c0) / d;
                let assignments: Vec<u32> =
                    (0..(r1 - r0) * strips).map(|_| rng.below(k) as u32).collect();
                let mut scales = crate::quant::vq::scales::unit_scales(r1 - r0, c1 - c0);
                scales.z = 1.0; // doubled scales: exercise the scale path
                groups.push(VqGroup { row0: r0, row1: r1, col0: c0, col1: c1, codebook: cb, assignments, scales });
                r0 = r1;
            }
            c0 = c1;
        }
        groups
    }

    #[test]
    fn threaded_decode_matches_serial_decode_bitwise() {
        // satellite parity: decode_groups_threaded vs decode_groups at
        // 1/2/4/8 lanes, ragged tiles + scales included
        let mut rng = Rng::new(31);
        let (rows, cols, d, k) = (29, 22, 2, 8);
        let groups = random_tiling(&mut rng, rows, cols, d, k);
        let serial = decode_groups(rows, cols, &groups);
        for nt in [1, 2, 4, 8] {
            let threaded = decode_groups_threaded(rows, cols, &groups, nt);
            assert_eq!(serial, threaded, "{nt} lanes");
        }
        // shared-pool form too (the engine's actual call shape)
        let pool = crate::util::WorkerPool::new(4);
        assert_eq!(serial, decode_groups_on(rows, cols, &groups, &pool));
    }

    #[test]
    fn exact_centroids_assign_to_themselves() {
        let mut rng = Rng::new(1);
        let cb = Codebook::from_centroids(2, rng.gaussian_vec(16));
        let pts = Matrix::from_fn(8, 2, |r, c| cb.centroid(r)[c]);
        let h = Matrix::from_fn(8, 2, |_, _| 1.0);
        let a = assign_diag(&pts, &cb, &h);
        assert_eq!(a, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn hessian_weighting_flips_decision() {
        // mirrors the python kernel test: weights decide which axis matters
        let pts = Matrix::from_vec(1, 2, vec![1.0, 1.0]).unwrap();
        let cb = Codebook::from_centroids(2, vec![1.5, 0.0, 0.0, 1.2]);
        let hx = Matrix::from_vec(1, 2, vec![10.0, 0.1]).unwrap();
        let hy = Matrix::from_vec(1, 2, vec![0.1, 10.0]).unwrap();
        assert_eq!(assign_diag(&pts, &cb, &hx), vec![0]);
        assert_eq!(assign_diag(&pts, &cb, &hy), vec![1]);
    }

    #[test]
    fn full_equals_diag_for_diagonal_hessian() {
        check("full(diag(h)) == diag(h)", 10, |rng| {
            let d = [1, 2, 4][rng.below(3)];
            let (pts, cb, h) = rand_setup(rng, 20, d, 8);
            let diag_assign = assign_diag(&pts, &cb, &h);
            let hmats: Vec<Matrix> = (0..20)
                .map(|i| Matrix::from_fn(d, d, |a, b| if a == b { h.get(i, a) } else { 0.0 }))
                .collect();
            let hrefs: Vec<&Matrix> = hmats.iter().collect();
            let full_assign = assign_full(&pts, &cb, &hrefs);
            if diag_assign == full_assign {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    fn decode_roundtrip() {
        let cb = Codebook::from_centroids(2, vec![0.0, 1.0, 10.0, 11.0]);
        let dec = decode(&cb, &[1, 0, 1]);
        assert_eq!(dec.row(0), &[10.0, 11.0]);
        assert_eq!(dec.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn assignment_error_zero_for_exact() {
        let cb = Codebook::from_centroids(1, vec![-1.0, 1.0]);
        let pts = Matrix::from_vec(2, 1, vec![-1.0, 1.0]).unwrap();
        let h = Matrix::from_vec(2, 1, vec![1.0, 1.0]).unwrap();
        let a = assign_diag(&pts, &cb, &h);
        assert_eq!(assignment_error(&pts, &cb, &h, &a), 0.0);
    }

    #[test]
    fn bits_per_dim() {
        assert_eq!(Codebook::new(2, 16).bits_per_dim(), 2.0);
        assert_eq!(Codebook::new(1, 8).bits_per_dim(), 3.0);
        assert_eq!(Codebook::new(4, 256).bits_per_dim(), 2.0);
    }
}
