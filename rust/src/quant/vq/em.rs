//! Hessian-weighted EM codebook initialization (paper §3.2, eq. 5).
//!
//! E-step: assign each point to the centroid minimizing the weighted
//! distance (eq. 4). M-step: closed-form weighted mean; with diagonal
//! weights the pseudo-inverse solve `(Σ H_i)^+ (Σ H_i x_i)` reduces to a
//! per-coordinate division, and with full d×d sub-Hessians we use the
//! symmetric pseudo-inverse from `linalg`. Empty clusters are re-seeded to
//! the point with the worst current error (standard k-means practice), so
//! codebook capacity is never silently wasted.

use crate::error::Result;
use crate::linalg::pinv_symmetric;
use crate::quant::vq::{
    assign_diag_on, assignment_error, weighted_dist_diag, Codebook, CodebookG,
};
use crate::tensor::{Element, Matrix, MatrixG};
use crate::util::WorkerPool;

/// Outcome of an EM run, generic over the compute width. [`EmResult`]
/// (= `EmResultG<f64>`) is the reference instantiation.
#[derive(Debug, Clone)]
pub struct EmResultG<E: Element> {
    /// The refined codebook.
    pub codebook: CodebookG<E>,
    /// Final point-to-centroid assignment.
    pub assignments: Vec<u32>,
    /// Final weighted objective (paper eq. 5), widened to f64.
    pub objective: f64,
    /// Iterations actually executed (early stop on convergence).
    pub iterations_run: usize,
}

/// The double-precision EM outcome.
pub type EmResult = EmResultG<f64>;

/// Diagonal-Hessian EM (the default path; the paper reports parity with
/// the full sub-Hessian variant).
pub fn em_diag(points: &Matrix, hdiag: &Matrix, seed_cb: Codebook, iters: usize) -> EmResult {
    em_diag_threaded(points, hdiag, seed_cb, iters, 1)
}

/// `em_diag` with the E-step assignment fanned across up to `n_threads`
/// workers. The M-step and convergence bookkeeping are unchanged, and the
/// threaded assignment is point-independent, so the result is identical
/// for every thread count. Standalone-use wrapper around [`em_diag_on`].
pub fn em_diag_threaded<E: Element>(
    points: &MatrixG<E>,
    hdiag: &MatrixG<E>,
    seed_cb: CodebookG<E>,
    iters: usize,
    n_threads: usize,
) -> EmResultG<E> {
    let pool = WorkerPool::new(n_threads);
    let cap = pool.n_threads();
    em_diag_on(points, hdiag, seed_cb, iters, &pool, cap)
}

/// `em_diag` with the E-step assignment banded across the lanes of a
/// borrowed [`WorkerPool`], capped at `n_runners` (the engine's inner
/// budget when several strips share the pool). The M-step and
/// convergence bookkeeping are unchanged, and the threaded assignment is
/// point-independent, so the result is identical for every pool width.
/// Used by the GPTVQ engine when a span has fewer row strips than pool
/// lanes (e.g. one giant group).
///
/// Precision-generic: the `f64` instantiation is the reference EM, the
/// `f32` one is the `--precision f32` fast path (same algorithm, wider
/// early-stop tolerance [`Element::EM_REL_TOL`] so it does not iterate
/// below single-precision rounding noise).
pub fn em_diag_on<E: Element>(
    points: &MatrixG<E>,
    hdiag: &MatrixG<E>,
    seed_cb: CodebookG<E>,
    iters: usize,
    pool: &WorkerPool,
    n_runners: usize,
) -> EmResultG<E> {
    let (n, d) = (points.rows(), points.cols());
    let k = seed_cb.k;
    let mut cb = seed_cb;
    let mut assignments = assign_diag_on(points, &cb, hdiag, pool, n_runners);
    // detlint: allow(precision-cast, exact widening: the EM objective is reported in pinned f64)
    let mut last_obj = assignment_error(points, &cb, hdiag, &assignments).to_f64();
    let mut iterations_run = 0;

    for _ in 0..iters {
        iterations_run += 1;
        // M-step: per-coordinate weighted mean
        let mut num = vec![E::ZERO; k * d];
        let mut den = vec![E::ZERO; k * d];
        for i in 0..n {
            let a = assignments[i] as usize;
            let x = points.row(i);
            let h = hdiag.row(i);
            for j in 0..d {
                num[a * d + j] += h[j] * x[j];
                den[a * d + j] += h[j];
            }
        }
        let mut counts = vec![0usize; k];
        for &a in &assignments {
            counts[a as usize] += 1;
        }
        for m in 0..k {
            if counts[m] == 0 {
                continue; // handled below
            }
            let c = cb.centroid_mut(m);
            for j in 0..d {
                if den[m * d + j] > E::ZERO {
                    c[j] = num[m * d + j] / den[m * d + j];
                }
                // zero total weight on a coordinate: keep previous value
            }
        }
        // re-seed empty clusters at the worst-error points
        reseed_empty(&mut cb, points, hdiag, &assignments, &counts);

        // E-step
        assignments = assign_diag_on(points, &cb, hdiag, pool, n_runners);
        // detlint: allow(precision-cast, exact widening: the EM objective is reported in pinned f64)
        let obj = assignment_error(points, &cb, hdiag, &assignments).to_f64();
        // converged: further sweeps are no-ops (§Perf — saves most of the
        // 100-iteration budget on easy groups with no quality change)
        if (last_obj - obj).abs() <= E::EM_REL_TOL * (1.0 + last_obj) {
            last_obj = obj;
            break;
        }
        last_obj = obj;
    }

    EmResultG { codebook: cb, assignments, objective: last_obj, iterations_run }
}

/// Full sub-Hessian EM: each point carries (a reference to) its d×d
/// inverse sub-Hessian weight matrix. M-step solves
/// `c = (Σ_i H_i)^+ (Σ_i H_i x_i)` per cluster (paper eq. 6).
pub fn em_full(points: &Matrix, hfull: &[&Matrix], seed_cb: Codebook, iters: usize) -> Result<EmResult> {
    use crate::quant::vq::assign_full;
    let (n, d) = (points.rows(), points.cols());
    let k = seed_cb.k;
    let mut cb = seed_cb;
    let mut assignments = assign_full(points, &cb, hfull);
    let mut iterations_run = 0;

    for _ in 0..iters {
        iterations_run += 1;
        // M-step per cluster
        for m in 0..k {
            let members: Vec<usize> = (0..n).filter(|&i| assignments[i] as usize == m).collect();
            if members.is_empty() {
                continue;
            }
            let mut hsum = Matrix::zeros(d, d);
            let mut hx = vec![0.0; d];
            for &i in &members {
                hsum.add_assign(hfull[i]);
                let v = hfull[i].matvec(points.row(i));
                for j in 0..d {
                    hx[j] += v[j];
                }
            }
            let pinv = pinv_symmetric(&hsum, 1e-12)?;
            let c_new = pinv.matvec(&hx);
            cb.centroid_mut(m).copy_from_slice(&c_new);
        }
        assignments = assign_full(points, &cb, hfull);
    }

    // report the diagonal-equivalent objective for comparability
    let obj: f64 = (0..n)
        .map(|i| {
            crate::quant::vq::weighted_dist_full(
                points.row(i),
                cb.centroid(assignments[i] as usize),
                hfull[i],
            )
        })
        .sum();
    Ok(EmResult { codebook: cb, assignments, objective: obj, iterations_run })
}

fn reseed_empty<E: Element>(
    cb: &mut CodebookG<E>,
    points: &MatrixG<E>,
    hdiag: &MatrixG<E>,
    assignments: &[u32],
    counts: &[usize],
) {
    let empties: Vec<usize> = (0..cb.k).filter(|&m| counts[m] == 0).collect();
    if empties.is_empty() {
        return;
    }
    // rank points by their current error, take the worst ones
    let mut errs: Vec<(E, usize)> = (0..points.rows())
        .map(|i| {
            let e = weighted_dist_diag(
                points.row(i),
                cb.centroid(assignments[i] as usize),
                hdiag.row(i),
            );
            (e, i)
        })
        .collect();
    // total_cmp: a NaN error (degenerate weights) must not panic the sort
    errs.sort_by(|a, b| b.0.total_cmp(&a.0));
    for (slot, m) in empties.into_iter().enumerate() {
        if slot < errs.len() {
            let i = errs[slot].1;
            cb.centroid_mut(m).copy_from_slice(points.row(i));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::assign_diag;
    use crate::quant::vq::seed::{seed_kmeanspp, seed_mahalanobis};
    use crate::util::prop::check;
    use crate::util::Rng;

    fn rand_pts(rng: &mut Rng, n: usize, d: usize) -> (Matrix, Matrix) {
        let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
        let h = Matrix::from_fn(n, d, |_, _| rng.range(0.2, 2.0));
        (pts, h)
    }

    #[test]
    fn em_monotonically_improves_over_seed() {
        check("EM objective <= seed objective", 10, |rng| {
            let d = [1, 2, 4][rng.below(3)];
            let n = 64 + rng.below(128);
            let k = 4 + rng.below(8);
            let (pts, h) = rand_pts(rng, n, d);
            let seed_cb = seed_mahalanobis(&pts, k).map_err(|e| e.to_string())?;
            let a0 = assign_diag(&pts, &seed_cb, &h);
            let obj0 = assignment_error(&pts, &seed_cb, &h, &a0);
            let res = em_diag(&pts, &h, seed_cb, 30);
            if res.objective <= obj0 + 1e-9 {
                Ok(())
            } else {
                Err(format!("EM worsened: {} -> {}", obj0, res.objective))
            }
        });
    }

    #[test]
    fn threaded_em_matches_single_threaded_bitwise() {
        let mut rng = Rng::new(13);
        // 8192*16*2 = 262k > PAR_GRAIN: the threaded E-step really fans out
        let (pts, h) = rand_pts(&mut rng, 8_192, 2);
        let seed_cb = seed_mahalanobis(&pts, 16).unwrap();
        let single = em_diag_threaded(&pts, &h, seed_cb.clone(), 10, 1);
        for nt in [2, 4, 8] {
            let multi = em_diag_threaded(&pts, &h, seed_cb.clone(), 10, nt);
            assert_eq!(multi.assignments, single.assignments, "{nt} threads");
            assert_eq!(multi.codebook.centroids, single.codebook.centroids, "{nt} threads");
            assert_eq!(multi.objective, single.objective, "{nt} threads");
        }
    }

    #[test]
    fn em_recovers_well_separated_clusters() {
        let mut rng = Rng::new(7);
        let centers = [[-5.0, -5.0], [5.0, 5.0], [-5.0, 5.0], [5.0, -5.0]];
        let pts = Matrix::from_fn(400, 2, |r, c| centers[r % 4][c] + 0.1 * rng.gaussian());
        let h = Matrix::from_fn(400, 2, |_, _| 1.0);
        let seed_cb = seed_kmeanspp(&pts, &h, 4, &mut rng);
        let res = em_diag(&pts, &h, seed_cb, 50);
        // every centroid should sit within 0.5 of one of the true centers
        for m in 0..4 {
            let c = res.codebook.centroid(m);
            let ok = centers
                .iter()
                .any(|t| ((t[0] - c[0]).powi(2) + (t[1] - c[1]).powi(2)).sqrt() < 0.5);
            assert!(ok, "centroid {m} at {c:?} not near any true center");
        }
        assert!(res.objective / 400.0 < 0.05);
    }

    #[test]
    fn identity_hessian_em_is_kmeans() {
        // with h = 1 the M-step is the plain mean
        let pts = Matrix::from_vec(4, 1, vec![0.0, 1.0, 10.0, 11.0]).unwrap();
        let h = Matrix::from_fn(4, 1, |_, _| 1.0);
        let seed_cb = Codebook::from_centroids(1, vec![0.0, 10.0]);
        let res = em_diag(&pts, &h, seed_cb, 10);
        let mut cents: Vec<f64> = (0..2).map(|m| res.codebook.centroid(m)[0]).collect();
        cents.sort_by(|a, b| a.total_cmp(b));
        assert!((cents[0] - 0.5).abs() < 1e-9);
        assert!((cents[1] - 10.5).abs() < 1e-9);
    }

    #[test]
    fn weighted_mstep_biases_toward_heavy_points() {
        // two points in one cluster; the heavier-weighted dominates
        let pts = Matrix::from_vec(2, 1, vec![0.0, 1.0]).unwrap();
        let h = Matrix::from_vec(2, 1, vec![9.0, 1.0]).unwrap();
        let seed_cb = Codebook::from_centroids(1, vec![0.5]);
        let res = em_diag(&pts, &h, seed_cb, 5);
        let c = res.codebook.centroid(0)[0];
        assert!((c - 0.1).abs() < 1e-9, "weighted mean should be 0.1, got {c}");
    }

    #[test]
    fn empty_clusters_get_reseeded() {
        let mut rng = Rng::new(8);
        let pts = Matrix::from_fn(100, 2, |_, _| rng.gaussian());
        let h = Matrix::from_fn(100, 2, |_, _| 1.0);
        // all seeds far away: everything assigns to nearest, some clusters empty
        let seed_cb = Codebook::from_centroids(2, vec![100.0, 100.0, 101.0, 101.0, 0.0, 0.0, 102.0, 102.0]);
        let res = em_diag(&pts, &h, seed_cb, 20);
        let mut counts = vec![0usize; 4];
        for &a in &res.assignments {
            counts[a as usize] += 1;
        }
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 2, "reseeding should activate clusters: {counts:?}");
    }

    #[test]
    fn full_hessian_em_matches_diag_for_diagonal_input() {
        let mut rng = Rng::new(9);
        let (pts, h) = rand_pts(&mut rng, 60, 2);
        let seed_cb = seed_mahalanobis(&pts, 4).unwrap();
        let diag_res = em_diag(&pts, &h, seed_cb.clone(), 10);
        let hmats: Vec<Matrix> = (0..60)
            .map(|i| Matrix::from_fn(2, 2, |a, b| if a == b { h.get(i, a) } else { 0.0 }))
            .collect();
        let hrefs: Vec<&Matrix> = hmats.iter().collect();
        let full_res = em_full(&pts, &hrefs, seed_cb, 10).unwrap();
        // objectives should match closely (same optimum)
        let rel = (diag_res.objective - full_res.objective).abs() / (1.0 + diag_res.objective);
        assert!(rel < 0.05, "diag {} vs full {}", diag_res.objective, full_res.objective);
    }

    #[test]
    fn more_iterations_never_hurt() {
        let mut rng = Rng::new(10);
        let (pts, h) = rand_pts(&mut rng, 256, 2);
        let seed_cb = seed_mahalanobis(&pts, 16).unwrap();
        let r5 = em_diag(&pts, &h, seed_cb.clone(), 5);
        let r50 = em_diag(&pts, &h, seed_cb, 50);
        assert!(r50.objective <= r5.objective + 1e-9);
    }
}
