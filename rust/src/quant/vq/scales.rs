//! Blockwise data normalization (paper §3.2): per-sub-row max-abs scales,
//! quantized to 4 bits in log2 space with a shared float offset.
//!
//! For each block (sub-row) of `block_size` weights the scale is
//! `s = max|w|`; scales are stored as 4-bit codes on a uniform grid in
//! log2 space (`a` = grid step, `z` = float offset, shared per group so
//! their overhead is negligible — the paper's `b_s/N_s` term counts only
//! the 4 bits per block). The weights are divided by the *decoded* scale
//! before codebook initialization/assignment and multiplied back at decode.

use crate::tensor::Matrix;

/// Bits per stored block-scale code (the paper's 4-bit log2 grid).
pub const SCALE_BITS: u32 = 4;
const LEVELS: u32 = (1 << SCALE_BITS) - 1;

/// Blockwise log2-quantized scales for one weight group.
#[derive(Debug, Clone)]
pub struct BlockScales {
    /// weights per scale block (sub-row)
    pub block_size: usize,
    /// rows of the owning group
    pub rows: usize,
    /// columns of the owning group
    pub cols: usize,
    /// 4-bit codes, one per block, row-major over (row, block)
    pub codes: Vec<u8>,
    /// log2-grid step (shared)
    pub a: f64,
    /// log2-grid offset (shared float, the paper's z)
    pub z: f64,
}

impl BlockScales {
    /// Number of scale blocks per row.
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(self.block_size)
    }

    /// Decoded scale for element (r, c).
    #[inline]
    pub fn scale_at(&self, r: usize, c: usize) -> f64 {
        let b = c / self.block_size;
        let code = self.codes[r * self.blocks_per_row() + b] as f64;
        (self.z + code * self.a).exp2()
    }

    /// Scale-bit overhead per weight (the paper's `b_s/N_s`).
    pub fn bits_per_value(&self) -> f64 {
        SCALE_BITS as f64 / self.block_size as f64
    }
}

/// Fit blockwise scales on `w [rows, cols]` (a weight group in paper
/// layout) and return them together with the normalized weights
/// `w ./ decoded_scale`.
pub fn fit_block_scales(w: &Matrix, block_size: usize) -> (BlockScales, Matrix) {
    let (rows, cols) = (w.rows(), w.cols());
    let bs = block_size.min(cols).max(1);
    let bpr = cols.div_ceil(bs);

    // raw log2 scales per block
    let mut log_scales = vec![0.0f64; rows * bpr];
    for r in 0..rows {
        let row = w.row(r);
        for b in 0..bpr {
            let c0 = b * bs;
            let c1 = (c0 + bs).min(cols);
            let mut mx = 0.0f64;
            for &v in &row[c0..c1] {
                mx = mx.max(v.abs());
            }
            // guard all-zero blocks: unit scale
            log_scales[r * bpr + b] = if mx > 0.0 { mx.log2() } else { 0.0 };
        }
    }

    // shared 4-bit grid over the observed log-range
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &ls in &log_scales {
        lo = lo.min(ls);
        hi = hi.max(ls);
    }
    if !lo.is_finite() {
        lo = 0.0;
        hi = 0.0;
    }
    let a = if hi - lo < 1e-12 { 1.0 } else { (hi - lo) / LEVELS as f64 };
    let z = lo;

    let codes: Vec<u8> = log_scales
        .iter()
        .map(|&ls| (((ls - z) / a).round().clamp(0.0, LEVELS as f64)) as u8)
        .collect();

    let scales = BlockScales { block_size: bs, rows, cols, codes, a, z };

    let normalized = Matrix::from_fn(rows, cols, |r, c| w.get(r, c) / scales.scale_at(r, c));
    (scales, normalized)
}

/// Identity scales (scaling disabled — the paper skips normalization for
/// 1D 2-bit VQ where it hurts).
pub fn unit_scales(rows: usize, cols: usize) -> BlockScales {
    BlockScales { block_size: cols.max(1), rows, cols, codes: vec![0; rows], a: 1.0, z: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Rng;

    #[test]
    fn normalized_blocks_bounded_near_one() {
        check("max|normalized block| close to 1", 10, |rng| {
            let rows = 1 + rng.below(6);
            let cols = 16 * (1 + rng.below(4));
            // heavy-tailed weights spanning magnitudes
            let w = Matrix::from_fn(rows, cols, |_, _| {
                rng.gaussian() * 10f64.powi(rng.below(3) as i32 - 1)
            });
            let (scales, norm) = fit_block_scales(&w, 16);
            for r in 0..rows {
                for b in 0..scales.blocks_per_row() {
                    let c0 = b * 16;
                    let c1 = (c0 + 16).min(cols);
                    let mx = norm.row(r)[c0..c1].iter().fold(0.0f64, |m, v| m.max(v.abs()));
                    // 4-bit log grid: decoded scale within one grid-step
                    // factor of the true max-abs
                    let tol = scales.a.exp2() * 1.05;
                    if mx > tol {
                        return Err(format!("block ({r},{b}) max {mx} > {tol}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn denormalize_roundtrips() {
        check("w == normalized * scale", 10, |rng| {
            let rows = 1 + rng.below(4);
            let cols = 32;
            let w = Matrix::from_fn(rows, cols, |_, _| rng.gaussian());
            let (scales, norm) = fit_block_scales(&w, 8);
            for r in 0..rows {
                for c in 0..cols {
                    let back = norm.get(r, c) * scales.scale_at(r, c);
                    if (back - w.get(r, c)).abs() > 1e-9 * (1.0 + w.get(r, c).abs()) {
                        return Err(format!("roundtrip failed at ({r},{c})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn codes_are_4bit() {
        let mut rng = Rng::new(1);
        let w = Matrix::from_fn(8, 64, |_, _| rng.gaussian() * rng.range(0.01, 100.0));
        let (scales, _) = fit_block_scales(&w, 16);
        assert!(scales.codes.iter().all(|&c| c <= 15));
        assert_eq!(scales.codes.len(), 8 * 4);
    }

    #[test]
    fn zero_block_gets_unit_scale() {
        let w = Matrix::zeros(2, 16);
        let (scales, norm) = fit_block_scales(&w, 16);
        for r in 0..2 {
            assert_eq!(scales.scale_at(r, 0), 1.0);
            assert_eq!(norm.get(r, 0), 0.0);
        }
    }

    #[test]
    fn captures_orders_of_magnitude() {
        // blocks at 0.01, 1, 100: the log grid must track all three
        let mut w = Matrix::zeros(1, 48);
        for c in 0..16 {
            w.set(0, c, 0.01);
        }
        for c in 16..32 {
            w.set(0, c, 1.0);
        }
        for c in 32..48 {
            w.set(0, c, 100.0);
        }
        let (scales, norm) = fit_block_scales(&w, 16);
        for c in [0, 16, 32] {
            let v = norm.get(0, c).abs();
            assert!((0.5..=2.0).contains(&v), "normalized magnitude {v} at col {c}");
        }
        assert!(scales.scale_at(0, 0) < scales.scale_at(0, 16));
        assert!(scales.scale_at(0, 16) < scales.scale_at(0, 32));
    }

    #[test]
    fn unit_scales_are_identity() {
        let s = unit_scales(3, 20);
        for r in 0..3 {
            for c in 0..20 {
                assert_eq!(s.scale_at(r, c), 1.0);
            }
        }
    }

    #[test]
    fn overhead_accounting() {
        let mut rng = Rng::new(2);
        let w = Matrix::from_fn(2, 64, |_, _| rng.gaussian());
        let (s16, _) = fit_block_scales(&w, 16);
        assert!((s16.bits_per_value() - 0.25).abs() < 1e-12);
        let (s64, _) = fit_block_scales(&w, 64);
        assert!((s64.bits_per_value() - 0.0625).abs() < 1e-12);
    }
}
