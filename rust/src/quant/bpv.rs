//! Bits-per-value accounting (paper §3.2 "Total bits per value"):
//!
//!   bpv = log2(k) / d  * d  [index bits per weight = b]
//!       + k * d * b_c / l   [codebook overhead per weight]
//!       + b_s / N_s         [scale overhead per weight, if scaling]
//!
//! plus the solver the paper uses to pick group sizes that hit a target
//! overhead (0.125 or 0.25 bpv, matching uniform W@g128 / W@g64).

/// Full breakdown of a VQ setting's storage cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpvBreakdown {
    /// index bits per weight (`log2(k)/d * d / d` = b, bits per dim)
    pub index_bits: f64,
    /// codebook bits per weight (`k*d*b_c / l`)
    pub codebook_bits: f64,
    /// scale bits per weight (`b_s / N_s`, 0 when scaling off)
    pub scale_bits: f64,
}

impl BpvBreakdown {
    /// Total bits per value (index + codebook + scale).
    pub fn total(&self) -> f64 {
        self.index_bits + self.codebook_bits + self.scale_bits
    }
}

/// Number of centroids for `b` bits per dimension at VQ dimension `d`
/// (the paper's `k = 2^(d*b)`).
pub fn centroids_for(d: usize, bits_per_dim: u32) -> usize {
    1usize << (d as u32 * bits_per_dim)
}

/// Compute the breakdown for a concrete setting.
///
/// * `d` — VQ dimension, `k` — centroids per codebook,
/// * `codebook_bits` — storage per centroid coordinate (16 = fp16, 8 = int8),
/// * `group_size` — weights per codebook (the paper's `l`),
/// * `scale_block` — `Some(N_s)` if blockwise scaling (4-bit scales) is on.
pub fn breakdown(
    d: usize,
    k: usize,
    codebook_bits: u32,
    group_size: usize,
    scale_block: Option<usize>,
) -> BpvBreakdown {
    let index_bits = (k as f64).log2() / d as f64;
    let codebook_bits_pv = (k * d * codebook_bits as usize) as f64 / group_size as f64;
    let scale_bits = match scale_block {
        Some(ns) => crate::quant::vq::scales::SCALE_BITS as f64 / ns as f64,
        None => 0.0,
    };
    BpvBreakdown { index_bits, codebook_bits: codebook_bits_pv, scale_bits }
}

/// Solve for the group size `l` that hits `target_overhead` bits/value of
/// *non-index* storage (codebook + scales), mirroring the paper's setup
/// (§4.1 "we choose a group size such that a specific target overhead is
/// achieved"). Returns None if the target is unreachable (scale overhead
/// alone exceeds it).
pub fn group_size_for_overhead(
    d: usize,
    k: usize,
    codebook_bits: u32,
    scale_block: Option<usize>,
    target_overhead: f64,
) -> Option<usize> {
    let scale_bits = match scale_block {
        Some(ns) => crate::quant::vq::scales::SCALE_BITS as f64 / ns as f64,
        None => 0.0,
    };
    let budget = target_overhead - scale_bits;
    if budget <= 0.0 {
        return None;
    }
    let l = (k * d * codebook_bits as usize) as f64 / budget;
    Some(l.round() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_2d_2bit() {
        // paper §4.1: 2D VQ, 2 bits/dim, 8-bit codebook: overhead =
        // 2 * 2^(2*2) * 8 = 256 bits -> group of 2048 weights hits
        // 0.125 bpv overhead, total 2.125
        let k = centroids_for(2, 2);
        assert_eq!(k, 16);
        let bd = breakdown(2, k, 8, 2048, None);
        assert!((bd.index_bits - 2.0).abs() < 1e-12);
        assert!((bd.codebook_bits - 0.125).abs() < 1e-12);
        assert!((bd.total() - 2.125).abs() < 1e-12);
    }

    #[test]
    fn solver_inverts_breakdown() {
        for (d, b, cb_bits) in [(1usize, 2u32, 8u32), (2, 2, 8), (2, 3, 8), (4, 2, 8), (1, 3, 16)] {
            let k = centroids_for(d, b);
            for target in [0.125, 0.25] {
                if let Some(l) = group_size_for_overhead(d, k, cb_bits, None, target) {
                    let bd = breakdown(d, k, cb_bits, l, None);
                    assert!(
                        (bd.codebook_bits + bd.scale_bits - target).abs() < 0.01,
                        "d={d} b={b}: got {} want {target}",
                        bd.codebook_bits
                    );
                }
            }
        }
    }

    #[test]
    fn paper_table8_equal_overhead_rows() {
        // Table 8: d=1 b=2: gs=512 fp16 no-SVD vs gs=256 int8 -> both 2.125
        let k = centroids_for(1, 2);
        let fp16 = breakdown(1, k, 16, 512, None);
        let int8 = breakdown(1, k, 8, 256, None);
        assert!((fp16.total() - int8.total()).abs() < 1e-12);
        assert!((fp16.total() - 2.125).abs() < 1e-12);
        // d=2 b=2: gs=4096 fp16 vs gs=2048 int8 -> 2.125
        let k = centroids_for(2, 2);
        let fp16 = breakdown(2, k, 16, 4096, None);
        let int8 = breakdown(2, k, 8, 2048, None);
        assert!((fp16.total() - 2.125).abs() < 1e-12);
        assert!((int8.total() - 2.125).abs() < 1e-12);
    }

    #[test]
    fn scaling_overhead_counts() {
        // Table 11: 1D 3b gs=512 no scale == gs=1024 with scale (Ns=64)
        let k = centroids_for(1, 3);
        let no_scale = breakdown(1, k, 8, 512, None);
        let with_scale = breakdown(1, k, 8, 1024, Some(64));
        assert!((no_scale.total() - with_scale.total()).abs() < 1e-12);
    }

    #[test]
    fn solver_unreachable_target() {
        // scale overhead 4/16 = 0.25 already equals the target
        assert!(group_size_for_overhead(2, 16, 8, Some(16), 0.25).is_none());
    }

    #[test]
    fn solver_4d() {
        // 4D 2b: k=256, 8-bit codebook: k*d*8 = 8192 bits; 0.25 bpv -> 32768
        let k = centroids_for(4, 2);
        assert_eq!(k, 256);
        let l = group_size_for_overhead(4, k, 8, None, 0.25).unwrap();
        assert_eq!(l, 32768);
    }
}
