//! GPTVQ (paper §3.2, Algorithm 1): column-blocked vector quantization
//! with Hessian-aware error feedback.
//!
//! Structure per weight matrix `W [out, in]` (paper layout):
//!
//! 1. Column *spans* of at most 256 columns (paper §4.1) are processed
//!    left to right. Entering a span, one codebook per row strip is
//!    initialized with Hessian-weighted EM (seeded per §4.3) on the
//!    *current*, error-compensated weights — optionally after blockwise
//!    log2 scale normalization (§3.2).
//! 2. Inside the span, `d` columns at a time are vector-quantized with the
//!    weighted assignment rule (eq. 4); the d column errors, scaled by
//!    `1/U[q,q]`, are accumulated and propagated to the remaining columns
//!    through the Cholesky factor `U` of `H^{-1}` (eq. 3), with GPTQ's
//!    lazy block flush.
//! 3. Post-processing (§3.3): codebook update by GD on the layer loss,
//!    int8 codebook quantization, and (1D only) SVD codebook compression.
//!
//! The engine is parallel (paper §4.1 is explicitly throughput-minded):
//! every stage executes on one persistent [`WorkerPool`] created per
//! invocation (or borrowed via [`gptvq_quantize_on`]) — row strips fan
//! across pool lanes for EM init and the sweep's assignment step, error
//! propagation and the lazy flush run as row-banded slice axpy kernels,
//! the loss/codebook-update matmuls go through the shared pool path in
//! `tensor::ops`, and span pipelining overlaps the next span's EM init
//! with the current span's deferred tail flush. All of it keeps a
//! deterministic reduction order: neither `n_threads` nor the
//! pipelining schedule ever changes the output.
//!
//! It is also precision-generic ([`GptvqConfig::precision`]): the hot
//! loops — EM, sweep assignment, error propagation/lazy flush, the
//! codebook-update matmuls — are monomorphized over
//! [`crate::tensor::Element`] and can run in `f32` for throughput, while
//! the Cholesky-derived inputs, EM seeding, stored codebooks, and the
//! reported losses stay `f64`. The f32 path's accuracy is pinned by the
//! guardrail tests below ([`F32_LOSS_REL_TOL`]), and the determinism
//! contract holds at either width.

use std::sync::{Mutex, OnceLock};

use crate::error::Result;
use crate::quant::bpv::{breakdown, BpvBreakdown};
use crate::quant::hessian::column_weights;
use crate::quant::vq::compress::{quantize_all_codebooks_int8, svd_compress_1d_on};
use crate::quant::vq::em::em_diag_on;
use crate::quant::vq::scales::{fit_block_scales, unit_scales};
use crate::quant::vq::seed::{seed, SeedMethod};
use crate::quant::vq::update::{codebook_update_on, recon_loss_on};
use crate::quant::vq::{assign_diag, decode_groups_on, CodebookG, VqGroup};
use crate::tensor::{axpy, Element, Matrix, MatrixG, Precision};
use crate::util::{parallel_map, parallel_row_bands, Rng, Timer, WorkerPool};

/// Accuracy guardrail for the f32 fast path: the final (f64-accounted)
/// reconstruction loss of a `Precision::F32` run must stay within this
/// relative tolerance of the `Precision::F64` reference on the same
/// layer. Asserted by the engine test suite, the pipeline perplexity
/// proxy, the doc-test on [`gptvq_quantize`], and the throughput bench.
pub const F32_LOSS_REL_TOL: f64 = 0.05;

/// All knobs of the method, paper defaults pre-filled.
#[derive(Debug, Clone)]
pub struct GptvqConfig {
    /// VQ dimension d (1, 2 or 4)
    pub d: usize,
    /// index bits per dimension b; k = 2^(d*b)
    pub bits_per_dim: u32,
    /// target weights per codebook (the paper's l); actual group sizes
    /// snap to the row-strip geometry and are reported in the result
    pub group_size: usize,
    /// centroid storage width: 8 (int8, default) or 16 (fp16)
    pub codebook_bits: u32,
    /// Some(N_s): blockwise log2 scale normalization with 4-bit scales
    pub scale_block: Option<usize>,
    /// EM iterations for codebook init (paper default 100)
    pub em_iters: usize,
    /// EM seeding strategy (paper §4.3)
    pub seed_method: SeedMethod,
    /// GPTQ lazy-update block width B (paper/GPTQ default 128)
    pub block_size: usize,
    /// max columns per group span (paper: 256)
    pub max_group_cols: usize,
    /// codebook-update GD iterations (paper default 25; 0 disables)
    pub update_iters: usize,
    /// Hessian damping fraction (GPTQ default 0.01)
    pub damp: f64,
    /// Some(frac): SVD codebook compression to frac*k rank (1D only)
    pub svd_rank_frac: Option<f64>,
    /// base seed of the deterministic per-(span, strip) RNG streams
    pub rng_seed: u64,
    /// worker threads inside this matrix's quantization (EM init, sweep
    /// assignment, error propagation, codebook update). 0 = inherit the
    /// pipeline's thread count, or all cores when run standalone. Output
    /// is bitwise identical for every value.
    pub n_threads: usize,
    /// compute width of the hot loops (EM, sweep assignment, error
    /// propagation/lazy flush, codebook-update matmuls). `F64` (default)
    /// is the exact reference path; `F32` trades single-precision
    /// rounding in those stages for throughput while EM seeding, the
    /// Cholesky-derived inputs, and the final loss accounting stay f64.
    /// Either width keeps the bitwise thread-count determinism guarantee.
    /// Honored by standalone [`gptvq_quantize`] calls; inside the
    /// pipeline, `PipelineConfig::precision` overrides it so one knob
    /// governs collection and engine alike.
    pub precision: Precision,
    /// Span pipelining (default on): overlap the EM codebook init of
    /// span s+1 with span s's deferred tail flush on the worker pool.
    /// The dependency gate — span s+1's `work` columns must have
    /// received every flush from span s before they are snapshotted —
    /// is honored by construction, and the deferred flush replays the
    /// exact per-element operation order of the serial schedule, so the
    /// output is **bitwise identical** with pipelining on or off (tested
    /// at 1/2/4/8 threads, both precisions). `GPTVQ_SPAN_PIPELINE=0` is
    /// the process-wide escape hatch.
    pub span_pipeline: bool,
}

impl GptvqConfig {
    /// Paper-default configuration for a (d, bits-per-dim) setting with a
    /// group size hitting `target_overhead` bits/value of non-index cost.
    pub fn for_setting(d: usize, bits_per_dim: u32, target_overhead: f64) -> GptvqConfig {
        let k = crate::quant::bpv::centroids_for(d, bits_per_dim);
        let group_size =
            crate::quant::bpv::group_size_for_overhead(d, k, 8, None, target_overhead)
                .unwrap_or(2048);
        GptvqConfig {
            d,
            bits_per_dim,
            group_size,
            codebook_bits: 8,
            scale_block: None,
            em_iters: 100,
            seed_method: SeedMethod::Mahalanobis,
            block_size: 128,
            max_group_cols: 256,
            update_iters: 25,
            damp: 0.01,
            svd_rank_frac: None,
            rng_seed: 0xC0DEB00C,
            n_threads: 1,
            precision: Precision::F64,
            span_pipeline: true,
        }
    }

    /// Number of centroids `k = 2^(d * b)` of this setting.
    pub fn k(&self) -> usize {
        crate::quant::bpv::centroids_for(self.d, self.bits_per_dim)
    }
}

/// Quantization outcome for one weight matrix.
#[derive(Debug, Clone)]
pub struct GptvqResult {
    /// final dequantized weights, paper layout [out, in]
    pub qweight: Matrix,
    /// quantized groups (codebooks, assignments, scales) for packing
    pub groups: Vec<VqGroup>,
    /// nominal breakdown at the configured group size
    pub bpv: BpvBreakdown,
    /// effective bpv from the actual (geometry-snapped) group sizes
    pub effective_bpv: f64,
    /// timing and loss bookkeeping of this run
    pub stats: GptvqStats,
}

/// Timing and loss bookkeeping, reported by the coordinator and the
/// runtime-throughput bench.
#[derive(Debug, Clone, Default)]
pub struct GptvqStats {
    /// seconds spent in non-overlapped EM codebook initialization (with
    /// span pipelining on, EM of spans after the first runs inside the
    /// previous span's sweep window and is accounted there)
    pub em_seconds: f64,
    /// seconds spent in the column sweep (assignment + propagation,
    /// plus any span-pipelined EM/flush overlap region)
    pub sweep_seconds: f64,
    /// seconds spent in codebook update / compression
    pub update_seconds: f64,
    /// reconstruction loss after the sweep — always f64-accounted,
    /// whatever `GptvqConfig::precision` says
    pub loss_after_sweep: f64,
    /// final reconstruction loss after codebook update (f64-accounted)
    pub loss_after_update: f64,
    /// number of (row strip × span) groups produced
    pub n_groups: usize,
    /// total weights quantized
    pub n_weights: usize,
}

/// Row-strip geometry: how many rows share one codebook for a given span
/// width, snapping the paper's `l` to the matrix shape.
fn rows_per_group(target_l: usize, span: usize, rows: usize) -> usize {
    ((target_l as f64 / span as f64).round() as usize).clamp(1, rows)
}

/// Extract EM points + per-point weights for one strip of a span.
///
/// Points are rows of consecutive-`d`-column slices of `norm [strip_rows,
/// span]`; the weight of coordinate `t` of a point from strip-column `j`
/// is the GPTQ column weight of absolute column `col0 + j*d + t`.
fn strip_points(norm: &Matrix, d: usize, col_w: &[f64]) -> (Matrix, Matrix) {
    let (rows, span) = (norm.rows(), norm.cols());
    let strips = span / d;
    let n = rows * strips;
    let mut pts = Matrix::zeros(n, d);
    let mut hw = Matrix::zeros(n, d);
    for r in 0..rows {
        let row = norm.row(r);
        for j in 0..strips {
            let p = r * strips + j;
            for t in 0..d {
                pts.set(p, t, row[j * d + t]);
                hw.set(p, t, col_w[j * d + t]);
            }
        }
    }
    (pts, hw)
}

/// Process-wide span-pipelining switch: on unless `GPTVQ_SPAN_PIPELINE`
/// is set to `0`/`false`/`off` (read once). The escape hatch only picks
/// between two bitwise-identical schedules — it exists for debugging
/// and for measuring the overlap win, never for correctness.
fn span_pipeline_env() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(
            std::env::var("GPTVQ_SPAN_PIPELINE").as_deref(),
            Ok("0") | Ok("false") | Ok("off")
        )
    })
}

/// End column of the span starting at `col0` (paper: ≤256 columns,
/// snapped down to whole d-strips).
fn span_end(c: usize, d: usize, max_group_cols: usize, col0: usize) -> usize {
    let span = max_group_cols.min(c - col0);
    let span = span - (span % d);
    col0 + span
}

/// The row strips of a span: contiguous `g_r`-row slices covering all
/// `r` rows (last one ragged).
fn strip_rows_for(r: usize, g_r: usize) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    let mut row0 = 0;
    while row0 < r {
        v.push((row0, (row0 + g_r).min(r)));
        row0 = (row0 + g_r).min(r);
    }
    v
}

/// Gather rows `[row0, row1)` × columns `[col0, col1)` of the working
/// weights into an f64 matrix — the values EM init consumes.
///
/// The synchronous init path gathers each strip straight from `work`
/// (one copy, as PR 2 did); the span-pipelined prefetch gathers the
/// whole next span once (`row0 = 0, row1 = r`) *before* the deferred
/// tail flush starts, which is what lets EM run concurrently with it:
/// the flush mutates columns beyond the span only, and EM reads only
/// the snapshot. The gathered values are identical either way, so the
/// schedule changes no result.
fn gather_strip_f64<E: Element>(
    work: &MatrixG<E>,
    row0: usize,
    row1: usize,
    col0: usize,
    col1: usize,
) -> Matrix {
    let mut m = Matrix::zeros(row1 - row0, col1 - col0);
    for rr in row0..row1 {
        let src = &work.row(rr)[col0..col1];
        for (dst, sv) in m.row_mut(rr - row0).iter_mut().zip(src) {
            // detlint: allow(precision-cast, exact widening: codebook update reads sweep state in pinned f64)
            *dst = sv.to_f64();
        }
    }
    m
}

/// EM-initialize one row strip of a span from its already-gathered f64
/// weights `sub`: fit scales, gather weighted points, seed from the
/// strip's own deterministic RNG stream (`rng_seed ⊕ span-hash +
/// strip`), refine with EM in the compute width. Returns the group (f64
/// codebook) plus the E-width codebook the sweep assigns against.
/// Identical for any scheduling of strips.
#[allow(clippy::too_many_arguments)]
fn em_init_strip<E: Element>(
    cfg: &GptvqConfig,
    pool: &WorkerPool,
    inner_nt: usize,
    col0: usize,
    col1: usize,
    si: usize,
    row0: usize,
    row1: usize,
    sub: Matrix,
    col_w: &[f64],
) -> Result<(VqGroup, CodebookG<E>)> {
    let d = cfg.d;
    let k = cfg.k();
    let span = col1 - col0;
    let span_seed = cfg.rng_seed ^ (col0 as u64).wrapping_mul(0x9E3779B97F4A7C15);
    let mut rng = Rng::new(span_seed.wrapping_add(si as u64));
    let (scales, norm) = match cfg.scale_block {
        Some(ns) => fit_block_scales(&sub, ns),
        None => (unit_scales(row1 - row0, span), sub),
    };
    let (pts, hw) = strip_points(&norm, d, col_w);
    let seed_cb = seed(cfg.seed_method, &pts, &hw, k, &mut rng)?;
    // EM refines in the compute width E, but seeding (which runs through
    // the f64 eigendecomposition) and scale fitting stay double
    // precision; the refined codebook is widened back into the group
    // (lossless from f32). The E-width codebook is also returned so the
    // sweep assigns without re-narrowing.
    let em = em_diag_on(
        &pts.convert::<E>(),
        &hw.convert::<E>(),
        seed_cb.convert::<E>(),
        cfg.em_iters,
        pool,
        inner_nt,
    );
    let cb_e = em.codebook;
    let group = VqGroup {
        row0,
        row1,
        col0,
        col1,
        codebook: cb_e.convert::<f64>(),
        assignments: vec![0; (row1 - row0) * (span / d)],
        scales,
    };
    Ok((group, cb_e))
}

/// EM-initialize every strip of the span `[col0, col1)` on the pool,
/// each strip gathering its own rows straight from `work` (strips fan
/// across lanes; when a span has fewer strips than lanes the per-strip
/// EM E-step is banded with the leftover budget `inner_nt`).
fn em_init_span<E: Element>(
    cfg: &GptvqConfig,
    pool: &WorkerPool,
    col0: usize,
    col1: usize,
    strip_rows: &[(usize, usize)],
    work: &MatrixG<E>,
    col_w: &[f64],
) -> Vec<Result<(VqGroup, CodebookG<E>)>> {
    let nt = pool.n_threads();
    let inner_nt = (nt / strip_rows.len().max(1)).max(1);
    parallel_map(pool, nt, strip_rows.len(), |si| {
        let (row0, row1) = strip_rows[si];
        let sub = gather_strip_f64(work, row0, row1, col0, col1);
        em_init_strip::<E>(cfg, pool, inner_nt, col0, col1, si, row0, row1, sub, col_w)
    })
}

/// Apply one block's scaled error columns to `work` columns
/// `[from, to)` through the Cholesky rows: GPTQ's lazy flush, row-banded
/// across the pool with the u-row slice hoisted out of the row loop and
/// one contiguous axpy per (error column, row).
///
/// This single kernel is shared by the in-sweep flush (up to the
/// deferral horizon) and the deferred tail flush of span pipelining
/// ([`far_flush`]), so the two schedules execute the identical
/// per-element operation sequence **by construction** — the axpy is
/// element-wise independent, so splitting a block's flush range at the
/// horizon and deferring the far part changes no bit.
fn flush_block<E: Element>(
    pool: &WorkerPool,
    work: &mut MatrixG<E>,
    u_e: &MatrixG<E>,
    err: &MatrixG<E>,
    bcol0: usize, // absolute column of the block's first error column
    from: usize,
    to: usize,
) {
    let (r, c) = (work.rows(), work.cols());
    if from >= to {
        return;
    }
    let bw = err.cols();
    let nr = pool.threads_for(r * bw * (to - from));
    parallel_row_bands(pool, work.as_mut_slice(), r, c, nr, |band_r0, band| {
        let band_rows = band.len() / c;
        for bj in 0..bw {
            let urow = &u_e.row(bcol0 + bj)[from..to];
            for i in 0..band_rows {
                let e = err.get(band_r0 + i, bj);
                if e == E::ZERO {
                    continue;
                }
                axpy(&mut band[i * c + from..i * c + to], -e, urow);
            }
        }
    });
}

/// Apply a span's deferred tail flush: every block's retained error
/// columns, in block order then column order, propagated to columns
/// `[from, c)` — each block through the same [`flush_block`] kernel the
/// in-sweep flush used, which is what makes the span-pipelining parity
/// guarantee structural rather than a property of two loops staying in
/// sync.
fn far_flush<E: Element>(
    pool: &WorkerPool,
    work: &mut MatrixG<E>,
    u_e: &MatrixG<E>,
    span_errs: &[(usize, MatrixG<E>)],
    col0: usize,
    from: usize,
) {
    let c = work.cols();
    for (bi, err) in span_errs {
        flush_block(pool, work, u_e, err, col0 + bi, from, c);
    }
}

/// Run GPTVQ on one weight matrix.
///
/// * `w` — weights in paper layout [out, in]
/// * `u` — upper Cholesky factor of the dampened inverse Hessian
///   ([`crate::quant::HessianEstimator::inverse_factor`])
/// * `h` — the dampened Hessian itself (for the codebook-update loss)
///
/// `u` and `h` must be derived from the *same* dampened Hessian
/// (i.e. the same `damp`), or the sweep and the loss/codebook-update
/// silently optimize different objectives.
///
/// Runs on `cfg.n_threads` workers (0 = all cores). Every parallel stage
/// — per-strip EM init, per-group sweep assignment, row-banded error
/// propagation, and the codebook-update matmuls — partitions disjoint
/// work with a deterministic reduction order, so the output is bitwise
/// identical for every thread count, at either `cfg.precision`.
///
/// # Example: both precisions on a synthetic layer
///
/// The documented two-precision workflow, executed by `cargo test`
/// (doc-test). The f32 fast path must reproduce the f64 reference
/// reconstruction loss within the 5% guardrail that the test suite pins:
///
/// ```
/// use gptvq::quant::gptvq::{gptvq_quantize, GptvqConfig};
/// use gptvq::quant::HessianEstimator;
/// use gptvq::tensor::{Matrix, Precision};
/// use gptvq::util::Rng;
///
/// // a small synthetic layer and its calibration Hessian
/// let mut rng = Rng::new(7);
/// let w = Matrix::from_fn(8, 16, |_, _| rng.gaussian() * 0.05);
/// let x = Matrix::from_fn(64, 16, |_, _| rng.gaussian());
/// let mut est = HessianEstimator::new(16);
/// est.update(&x);
/// let u = est.inverse_factor(0.01)?;
/// let h = est.dampened(0.01);
///
/// let mut cfg = GptvqConfig::for_setting(2, 2, 0.25);
/// cfg.em_iters = 10;
/// cfg.update_iters = 3;
///
/// // f64 reference run, then the f32 fast path on the same layer
/// let r64 = gptvq_quantize(&w, &u, &h, &cfg)?;
/// cfg.precision = Precision::F32;
/// let r32 = gptvq_quantize(&w, &u, &h, &cfg)?;
///
/// // guardrail: final losses are both f64-accounted and must agree
/// let (l64, l32) = (r64.stats.loss_after_update, r32.stats.loss_after_update);
/// assert!(l32.is_finite());
/// assert!((l64 - l32).abs() <= 0.05 * (1e-12 + l64.abs()), "f32 {l32} vs f64 {l64}");
/// # Ok::<(), gptvq::Error>(())
/// ```
pub fn gptvq_quantize(w: &Matrix, u: &Matrix, h: &Matrix, cfg: &GptvqConfig) -> Result<GptvqResult> {
    let pool = WorkerPool::new(cfg.n_threads);
    gptvq_quantize_on(w, u, h, cfg, &pool)
}

/// [`gptvq_quantize`] on a borrowed [`WorkerPool`] — the form callers
/// that quantize many layers (the pipeline, the throughput bench) use so
/// one set of workers serves every layer and every stage, instead of
/// re-spawning per invocation. `cfg.n_threads` is ignored here; the
/// pool's width governs. Output is bitwise identical for every pool
/// width and identical to a fresh-pool [`gptvq_quantize`] call.
pub fn gptvq_quantize_on(
    w: &Matrix,
    u: &Matrix,
    h: &Matrix,
    cfg: &GptvqConfig,
    pool: &WorkerPool,
) -> Result<GptvqResult> {
    match cfg.precision {
        Precision::F64 => gptvq_quantize_impl::<f64>(w, u, h, cfg, pool),
        Precision::F32 => gptvq_quantize_impl::<f32>(w, u, h, cfg, pool),
    }
}

/// The precision-generic engine body behind [`gptvq_quantize`].
///
/// The element width `E` governs the sweep state (`work`, the error
/// block, propagation/flush axpys), the EM inner loop, and the
/// assignment distances. Everything that must stay trustworthy is f64
/// regardless of `E`: the Cholesky-derived inputs `u`/`h`, EM seeding,
/// scale fitting, the stored group codebooks (widened back at the span
/// boundary — lossless from f32), the decoded `qweight`, and the
/// reported losses. For `E = f64` the conversions are identities and
/// this is exactly the historical engine.
fn gptvq_quantize_impl<E: Element>(
    w: &Matrix,
    u: &Matrix,
    h: &Matrix,
    cfg: &GptvqConfig,
    pool: &WorkerPool,
) -> Result<GptvqResult> {
    let (r, c) = (w.rows(), w.cols());
    assert_eq!(u.rows(), c, "inverse factor dim");
    assert_eq!(h.rows(), c, "hessian dim");
    let d = cfg.d;
    assert!(c % d == 0, "columns {c} must be divisible by VQ dim {d}");
    let k = cfg.k();
    let nt = pool.n_threads();

    // sweep state in the compute width; u is narrowed once so the
    // propagation loops read contiguous E-width rows
    // detlint: allow(precision-cast, the single documented f64->E narrowing at sweep entry (PR 3 boundary))
    let mut work: MatrixG<E> = w.convert();
    // detlint: allow(precision-cast, the single documented f64->E narrowing at sweep entry (PR 3 boundary))
    let u_e: MatrixG<E> = u.convert();
    let mut q = Matrix::zeros(r, c);
    let mut groups: Vec<VqGroup> = Vec::new();
    let mut stats = GptvqStats { n_weights: r * c, ..Default::default() };

    // ---- span loop -------------------------------------------------------
    // Schedule: with pipelining on, span s+1's EM init runs on pool
    // lanes while span s applies its deferred tail flush — both bitwise
    // equal to the serial order (see `far_flush`). `prefetched` carries
    // the EM results from the overlap region into the next iteration.
    let pipeline = cfg.span_pipeline && span_pipeline_env();
    let mut prefetched: Option<Vec<Result<(VqGroup, CodebookG<E>)>>> = None;
    let mut col0 = 0;
    while col0 < c {
        let col1 = span_end(c, d, cfg.max_group_cols, col0);
        let span = col1 - col0;
        let g_r = rows_per_group(cfg.group_size, span, r);
        let strip_rows = strip_rows_for(r, g_r);

        // 1. codebook init per row strip, on current weights — consumed
        // from the previous span's overlap when pipelined, computed here
        // otherwise. Strips are independent, so they fan across workers;
        // each strip seeds its own RNG stream from (rng_seed, span,
        // strip), which makes the result independent of thread count,
        // execution order, and the pipelining schedule.
        let em_timer = Timer::start();
        let col_w = column_weights(u, col0..col1);
        // detlint: allow(precision-cast, Hessian column weights computed in pinned f64 then narrowed once per span)
        let col_w_e: Vec<E> = col_w.iter().map(|&v| E::from_f64(v)).collect();
        let span_groups_start = groups.len();
        let init: Vec<Result<(VqGroup, CodebookG<E>)>> = match prefetched.take() {
            Some(v) => v,
            None => em_init_span::<E>(cfg, pool, col0, col1, &strip_rows, &work, &col_w),
        };
        // E-width codebooks of this span's groups, indexed like
        // `groups[span_groups_start + gi]`
        let mut span_cbs: Vec<CodebookG<E>> = Vec::with_capacity(init.len());
        for g in init {
            let (group, cb_e) = g?;
            groups.push(group);
            span_cbs.push(cb_e);
        }
        stats.em_seconds += em_timer.elapsed_secs();

        // 2. GPTQ-style sweep over the span, d columns at a time
        let sweep_timer = Timer::start();
        let block = cfg.block_size.min(span).max(d);
        let block = block - (block % d);
        let n_span_groups = groups.len() - span_groups_start;
        // deferred-flush horizon: with pipelining, each block's lazy
        // flush stops at the end of the *next* span and retains its
        // error columns; the tail beyond the horizon is applied — in
        // identical per-element order — by `far_flush` once this span's
        // errors are final, overlapped with span s+1's EM init
        let next1 =
            if pipeline && col1 < c { span_end(c, d, cfg.max_group_cols, col1) } else { c };
        let mut span_errs: Vec<(usize, MatrixG<E>)> = Vec::new();
        let mut bi = 0;
        // detlint: hot(engine-sweep) — the per-block assign/propagate loop is
        // the quantizer's inner loop; allocations here scale with column count
        while bi < span {
            let bend = (bi + block).min(span);
            let bw = bend - bi;
            let mut err = MatrixG::<E>::zeros(r, bw);

            let mut j = 0;
            while bi + j < bend {
                let p0 = col0 + bi + j; // absolute first column of the strip
                // quantize every group's rows for columns [p0, p0+d):
                // gather the normalized points, assign, decode. One task
                // per row strip; the strips are row-disjoint, so results
                // apply in group order regardless of who computed them.
                // Gathering and assignment run in the compute width E
                // (against the span's E-width codebooks); the decoded
                // qvals come from the stored f64 codebook + scales.
                let span_groups = &groups[span_groups_start..];
                let span_cbs_ref = &span_cbs;
                let work_ref = &work;
                let col_w_e_ref = &col_w_e;
                let step_nt = pool.threads_for(r * k * d);
                let step: Vec<(Vec<u32>, Vec<f64>)> =
                    parallel_map(pool, step_nt, n_span_groups, |gi| {
                        let g = &span_groups[gi];
                        let gr = g.group_rows();
                        // gather points (normalized current weights)
                        let mut pts = MatrixG::<E>::zeros(gr, d);
                        let mut hw = MatrixG::<E>::zeros(gr, d);
                        for rr in 0..gr {
                            for t in 0..d {
                                let cabs = p0 + t;
                                let s = g.scales.scale_at(rr, cabs - g.col0);
                                // detlint: allow(precision-cast, scales live in pinned f64 and narrow at point build)
                                pts.set(rr, t, work_ref.get(g.row0 + rr, cabs) / E::from_f64(s));
                                hw.set(rr, t, col_w_e_ref[cabs - col0]);
                            }
                        }
                        let assign = assign_diag(&pts, &span_cbs_ref[gi], &hw);
                        // detlint: allow(hot-alloc, per-strip decode scratch local to one pool task; size gr*d is tiny and strip-bound)
                        let mut qvals = vec![0.0; gr * d];
                        for rr in 0..gr {
                            let a = assign[rr] as usize;
                            for t in 0..d {
                                let cabs = p0 + t;
                                let s = g.scales.scale_at(rr, cabs - g.col0);
                                qvals[rr * d + t] = g.codebook.centroid(a)[t] * s;
                            }
                        }
                        (assign, qvals)
                    });
                for (gi, (assign, qvals)) in step.into_iter().enumerate() {
                    let g = &mut groups[span_groups_start + gi];
                    let strips = g.strips();
                    let strip_idx = (p0 - g.col0) / d;
                    for rr in 0..g.group_rows() {
                        g.assignments[rr * strips + strip_idx] = assign[rr];
                        for t in 0..d {
                            q.set(g.row0 + rr, p0 + t, qvals[rr * d + t]);
                        }
                    }
                }
                // scaled errors for the d columns + propagate to the rest
                // of the block (from column p0+d on)
                for t in 0..d {
                    let cabs = p0 + t;
                    let diag = u_e.get(cabs, cabs);
                    for rr in 0..r {
                        // detlint: allow(precision-cast, q is pinned f64; narrowed once to E for error propagation)
                        let e = (work.get(rr, cabs) - E::from_f64(q.get(rr, cabs))) / diag;
                        err.set(rr, cabs - col0 - bi, e);
                    }
                }
                let tail0 = p0 + d; // absolute column where updates start
                let tail1 = col0 + bend;
                if tail0 < tail1 {
                    // rows are independent: band them across workers; each
                    // row applies its d error columns in order through one
                    // contiguous axpy over the block tail
                    let err_ref = &err;
                    let u_e_ref = &u_e;
                    let prop_nt = pool.threads_for(r * d * (tail1 - tail0));
                    parallel_row_bands(pool, work.as_mut_slice(), r, c, prop_nt, |band_r0, band| {
                        let band_rows = band.len() / c;
                        for t in 0..d {
                            let cabs = p0 + t;
                            let urow = &u_e_ref.row(cabs)[tail0..tail1];
                            for i in 0..band_rows {
                                let e = err_ref.get(band_r0 + i, cabs - col0 - bi);
                                if e == E::ZERO {
                                    continue;
                                }
                                axpy(&mut band[i * c + tail0..i * c + tail1], -e, urow);
                            }
                        }
                    });
                }
                j += d;
            }

            // lazy flush: all columns after the block up to the deferral
            // horizon, through the shared kernel. Columns ≥ next1 (only
            // a shorter range when pipelining) get exactly these updates
            // later, in the same order, from `far_flush` — same kernel,
            // different column range.
            flush_block(pool, &mut work, &u_e, &err, col0 + bi, col0 + bend, next1);
            if next1 < c {
                // retain this block's scaled errors for the deferred
                // tail flush beyond the horizon
                span_errs.push((bi, err));
            }
            bi = bend;
        }
        // detlint: endhot

        if pipeline && col1 < c {
            // 3. span pipelining: every flush of span s has reached
            // [col1, next1) by now, so span s+1's working weights are
            // final — snapshot them and run its EM init on pool lanes
            // while the caller applies the deferred tail flush to
            // [next1, c). EM reads only the snapshot and the flush
            // writes only columns ≥ next1, so the overlap is race-free
            // and the result is bit-for-bit the serial schedule's.
            let g_r_next = rows_per_group(cfg.group_size, next1 - col1, r);
            let strip_rows_next = strip_rows_for(r, g_r_next);
            let col_w_next = column_weights(u, col1..next1);
            let sub_next = gather_strip_f64(&work, 0, r, col1, next1);
            let inner_nt = (nt / strip_rows_next.len().max(1)).max(1);
            let slots: Vec<Mutex<Option<Result<(VqGroup, CodebookG<E>)>>>> =
                (0..strip_rows_next.len()).map(|_| Mutex::new(None)).collect();
            pool.scope(|s| {
                for si in 0..strip_rows_next.len() {
                    let slots = &slots;
                    let strip_rows_next = &strip_rows_next;
                    let sub_next = &sub_next;
                    let col_w_next = &col_w_next;
                    s.spawn(move || {
                        let (row0, row1) = strip_rows_next[si];
                        let res = em_init_strip::<E>(
                            cfg,
                            pool,
                            inner_nt,
                            col1,
                            next1,
                            si,
                            row0,
                            row1,
                            sub_next.slice_rows(row0, row1),
                            col_w_next,
                        );
                        *slots[si].lock().unwrap() = Some(res);
                    });
                }
                far_flush(pool, &mut work, &u_e, &span_errs, col0, next1);
            });
            prefetched = Some(
                slots
                    .into_iter()
                    .map(|m| m.into_inner().unwrap().expect("prefetched strip completed"))
                    .collect(),
            );
        }
        stats.sweep_seconds += sweep_timer.elapsed_secs();
        col0 = col1;
    }

    stats.n_groups = groups.len();
    stats.loss_after_sweep = recon_loss_on(w, &q, h, pool);

    // ---- post-processing (§3.3) -----------------------------------------
    let update_timer = Timer::start();
    if cfg.update_iters > 0 {
        codebook_update_on(w, h, &mut groups, cfg.update_iters, pool, E::PRECISION);
    }
    let svd_rank = if let Some(frac) = cfg.svd_rank_frac {
        let svd = svd_compress_1d_on(w, h, &mut groups, frac, cfg.update_iters.max(10), pool)?;
        Some(svd.rank)
    } else {
        if cfg.codebook_bits == 8 {
            quantize_all_codebooks_int8(&mut groups);
        }
        None
    };
    stats.update_seconds = update_timer.elapsed_secs();

    let qweight = decode_groups_on(r, c, &groups, pool);
    stats.loss_after_update = recon_loss_on(w, &qweight, h, pool);

    // bpv accounting: nominal + effective (actual group sizes). Codebook
    // storage is identical for every group, so it is costed once:
    // without SVD each group stores its k*d centroid coordinates; with
    // SVD each group stores only its rank-sized row of the U'' factor
    // (the *actual* rank the factorization kept, which the thin SVD
    // clamps to min(n_groups, k)), plus the shared V' [k, rank] once.
    let per_group_bits = match svd_rank {
        Some(rank) => (rank * cfg.codebook_bits as usize) as f64,
        None => (k * d * cfg.codebook_bits as usize) as f64,
    };
    let mut cb_bits_total = groups.len() as f64 * per_group_bits;
    if let Some(rank) = svd_rank {
        cb_bits_total += (k * rank * cfg.codebook_bits as usize) as f64;
    }
    let bpv = breakdown(d, k, cfg.codebook_bits, cfg.group_size, cfg.scale_block);
    let effective_bpv = bpv.index_bits + cb_bits_total / (r * c) as f64 + bpv.scale_bits;

    Ok(GptvqResult { qweight, groups, bpv, effective_bpv, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::gptq_quantize;
    use crate::quant::hessian::HessianEstimator;
    use crate::quant::kmeans::kmeans_vq_quantize;
    use crate::quant::vq::decode_groups;
    use crate::quant::vq::update::recon_loss;
    use crate::tensor::matmul;
    use crate::util::Rng;

    fn setup(rng: &mut Rng, r: usize, c: usize) -> (Matrix, HessianEstimator) {
        let w = Matrix::from_fn(r, c, |_, _| rng.gaussian() * 0.05);
        let base = Matrix::from_fn(4 * c, c, |_, _| rng.gaussian());
        let mix = Matrix::from_fn(c, c, |i, j| if i == j { 1.0 } else { 0.2 * rng.gaussian() });
        let x = matmul(&base, &mix);
        let mut est = HessianEstimator::new(c);
        est.update(&x);
        (w, est)
    }

    fn quick_cfg(d: usize, b: u32) -> GptvqConfig {
        let mut cfg = GptvqConfig::for_setting(d, b, 0.25);
        cfg.em_iters = 20;
        cfg.update_iters = 5;
        cfg.group_size = 512;
        // CI runs the suite once with GPTVQ_TEST_THREADS=4 so every
        // engine test also exercises the parallel paths
        cfg.n_threads = crate::util::test_threads();
        cfg
    }

    #[test]
    fn runs_and_covers_matrix() {
        let mut rng = Rng::new(1);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let res = gptvq_quantize(&w, &u, &h, &quick_cfg(2, 2)).unwrap();
        assert_eq!(res.qweight.rows(), 16);
        assert_eq!(res.qweight.cols(), 32);
        assert!(res.stats.n_groups >= 1);
        // every group cell decodes to the reported qweight
        let dec = decode_groups(16, 32, &res.groups);
        assert_eq!(dec, res.qweight);
    }

    fn assert_same_result(a: &GptvqResult, b: &GptvqResult, label: &str) {
        assert_eq!(a.qweight, b.qweight, "{label}: qweights must be bitwise identical");
        assert_eq!(a.groups.len(), b.groups.len(), "{label}");
        for (ga, gb) in a.groups.iter().zip(&b.groups) {
            assert_eq!(ga.assignments, gb.assignments, "{label}");
            assert_eq!(ga.codebook.centroids, gb.codebook.centroids, "{label}");
        }
        assert_eq!(a.effective_bpv, b.effective_bpv, "{label}");
    }

    #[test]
    fn threaded_engine_matches_single_thread_bitwise() {
        // the tentpole guarantee: thread count never changes a weight.
        // 96x256 puts the lazy flush (96*128*128) and the update matmuls
        // (96*256*256) over the default PAR_GRAIN, so the row-banded and
        // threaded-matmul paths genuinely run multi-threaded here even
        // without the CI GPTVQ_PAR_GRAIN=1 override.
        let mut rng = Rng::new(10);
        let (w, est) = setup(&mut rng, 96, 256);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.em_iters = 5;
        cfg.update_iters = 3;
        cfg.scale_block = Some(16); // exercise the normalization path too
        cfg.n_threads = 1;
        let single = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        for nt in [2, 4, 8] {
            cfg.n_threads = nt;
            let multi = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
            assert_same_result(&single, &multi, &format!("{nt} threads"));
        }
    }

    #[test]
    fn threaded_engine_deterministic_with_kmeanspp_seeding() {
        // the rng-dependent seeding path: per-strip streams must make the
        // outcome independent of strip scheduling
        let mut rng = Rng::new(11);
        let (w, est) = setup(&mut rng, 24, 64);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.seed_method = SeedMethod::KmeansPlusPlus;
        cfg.group_size = 128; // several strips per span
        cfg.n_threads = 1;
        let single = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        cfg.n_threads = 4;
        let multi = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        assert_same_result(&single, &multi, "kmeans++ 4 threads");
    }

    #[test]
    fn span_pipelining_matches_serial_schedule_bitwise() {
        // the PR 4 schedule change: EM(s+1) overlapped with span s's
        // deferred tail flush must be bit-for-bit the serial schedule,
        // at every thread count and both precisions. Geometry forces
        // several spans (c=96, max span 32) and several blocks per span
        // (block 16), so the deferred flush really engages.
        let mut rng = Rng::new(30);
        let (w, est) = setup(&mut rng, 24, 96);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        for precision in [Precision::F64, Precision::F32] {
            let mut cfg = quick_cfg(2, 2);
            cfg.max_group_cols = 32;
            cfg.block_size = 16;
            cfg.group_size = 128; // several strips per span
            cfg.scale_block = Some(8); // normalization path included
            cfg.precision = precision;
            cfg.span_pipeline = false;
            cfg.n_threads = 1;
            let serial = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
            for nt in [1, 2, 4, 8] {
                cfg.n_threads = nt;
                cfg.span_pipeline = true;
                let piped = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
                assert_same_result(&serial, &piped, &format!("{precision:?} piped {nt}t"));
                cfg.span_pipeline = false;
                let unpiped = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
                assert_same_result(&serial, &unpiped, &format!("{precision:?} unpiped {nt}t"));
            }
        }
    }

    #[test]
    fn engine_on_shared_pool_matches_per_invocation_pools() {
        // the pool-reuse contract quantize_model relies on: many layers
        // through one WorkerPool give exactly the per-invocation results
        let pool = crate::util::WorkerPool::new(4);
        for seed in [40u64, 41] {
            let mut lrng = Rng::new(seed);
            let (w, est) = setup(&mut lrng, 24, 64);
            let u = est.inverse_factor(0.01).unwrap();
            let h = est.dampened(0.01);
            let mut cfg = quick_cfg(2, 2);
            cfg.max_group_cols = 32; // multi-span: pipelining active
            cfg.n_threads = 4;
            let fresh = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
            let shared = gptvq_quantize_on(&w, &u, &h, &cfg, &pool).unwrap();
            assert_same_result(&fresh, &shared, &format!("layer seed {seed}"));
        }
    }

    #[test]
    fn f32_engine_loss_within_guardrail_of_f64() {
        // the pinned accuracy contract of `--precision f32`: same layer,
        // both widths, final f64-accounted losses within F32_LOSS_REL_TOL
        let mut rng = Rng::new(20);
        let (w, est) = setup(&mut rng, 48, 96);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.scale_block = Some(16); // cover the normalization path too
        let r64 = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        cfg.precision = Precision::F32;
        let r32 = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        for (l64, l32, stage) in [
            (r64.stats.loss_after_sweep, r32.stats.loss_after_sweep, "sweep"),
            (r64.stats.loss_after_update, r32.stats.loss_after_update, "update"),
        ] {
            assert!(l32.is_finite() && l32 > 0.0, "{stage}: degenerate f32 loss {l32}");
            let rel = (l64 - l32).abs() / (1e-12 + l64.abs());
            assert!(
                rel <= F32_LOSS_REL_TOL,
                "{stage}: f32 loss {l32} drifted {rel:.4} rel from f64 {l64} (tol {F32_LOSS_REL_TOL})"
            );
        }
        // the decoded weights stay close in aggregate (single assignment
        // flips on borderline points are fine; wholesale drift is not)
        let rel_frob = r64.qweight.sub(&r32.qweight).frob_norm_sq().sqrt()
            / (r64.qweight.frob_norm_sq().sqrt() + 1e-12);
        assert!(rel_frob < 0.2, "qweight relative frobenius drift {rel_frob}");
        assert_eq!(r64.stats.n_groups, r32.stats.n_groups);
    }

    #[test]
    fn f32_engine_is_thread_count_deterministic() {
        // the bitwise determinism contract must hold on the f32 path too
        let mut rng = Rng::new(21);
        let (w, est) = setup(&mut rng, 32, 64);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.precision = Precision::F32;
        cfg.group_size = 128; // several strips per span
        cfg.n_threads = 1;
        let single = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        for nt in [2, 4, 8] {
            cfg.n_threads = nt;
            let multi = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
            assert_same_result(&single, &multi, &format!("f32 {nt} threads"));
        }
    }

    #[test]
    fn beats_data_aware_kmeans() {
        // the paper's core claim (Table 1): GPTVQ's error feedback beats
        // k-means with data on the Hessian-weighted loss
        let mut rng = Rng::new(2);
        let (w, est) = setup(&mut rng, 24, 48);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let res = gptvq_quantize(&w, &u, &h, &quick_cfg(2, 2)).unwrap();
        let km = kmeans_vq_quantize(&w, 2, 16, 512, 256, Some(&h), 20, 0);
        let l_vq = recon_loss(&w, &res.qweight, &h);
        let l_km = recon_loss(&w, &km, &h);
        assert!(l_vq < l_km, "gptvq {l_vq} vs kmeans+data {l_km}");
    }

    #[test]
    fn more_bits_help() {
        let mut rng = Rng::new(3);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let l2 = recon_loss(&w, &gptvq_quantize(&w, &u, &h, &quick_cfg(2, 2)).unwrap().qweight, &h);
        let l3 = recon_loss(&w, &gptvq_quantize(&w, &u, &h, &quick_cfg(2, 3)).unwrap().qweight, &h);
        assert!(l3 < l2, "3 bits {l3} should beat 2 bits {l2}");
    }

    #[test]
    fn vq_2d_beats_uniform_gptq_at_equal_index_bits() {
        // Figure 1 / Table 2 shape: at the same index budget, 2D VQ fits
        // the (gaussian) weight distribution better than the uniform grid
        let mut rng = Rng::new(4);
        let (w, est) = setup(&mut rng, 32, 64);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.em_iters = 50;
        cfg.update_iters = 15;
        let vq = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        let uniform = gptq_quantize(&w, &u, 2, 64, 32);
        let l_vq = recon_loss(&w, &vq.qweight, &h);
        let l_u = recon_loss(&w, &uniform.qweight, &h);
        assert!(l_vq < l_u, "2D VQ {l_vq} should beat uniform GPTQ {l_u}");
    }

    #[test]
    fn d1_with_svd_compression_runs() {
        let mut rng = Rng::new(5);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(1, 3);
        cfg.svd_rank_frac = Some(0.5);
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        assert!(res.stats.loss_after_update.is_finite());
        // effective bpv accounts for the halved codebook storage
        assert!(res.effective_bpv < 3.0 + 1.0);
    }

    #[test]
    fn svd_effective_bpv_follows_stored_rank() {
        let mut rng = Rng::new(9);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(1, 3);
        cfg.group_size = 32; // one row strip per group -> many codebooks
        cfg.svd_rank_frac = Some(0.5);
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        let k = cfg.k();
        let ng = res.stats.n_groups;
        // the rank the compression actually stores (thin-SVD clamped)
        let rank = ((k as f64 * 0.5).round() as usize).clamp(1, ng.min(k));
        let expected_cb = ((ng * rank + k * rank) * 8) as f64 / (16.0 * 32.0);
        let got_cb = res.effective_bpv - res.bpv.index_bits - res.bpv.scale_bits;
        assert!((got_cb - expected_cb).abs() < 1e-9, "cb bits {got_cb} vs {expected_cb}");
        // with ng > k the rank-r factors undercut full codebook storage
        let full_cb = (ng * k * 8) as f64 / (16.0 * 32.0);
        assert!(got_cb < full_cb, "{got_cb} !< {full_cb}");
    }

    #[test]
    fn scaling_path_runs_and_reports_overhead() {
        let mut rng = Rng::new(6);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 3);
        cfg.scale_block = Some(16);
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        assert!(res.bpv.scale_bits > 0.0);
        assert!(res.stats.loss_after_update.is_finite());
    }

    #[test]
    fn update_improves_or_maintains_loss() {
        let mut rng = Rng::new(7);
        let (w, est) = setup(&mut rng, 16, 32);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.codebook_bits = 16; // isolate the update from int8 rounding
        cfg.update_iters = 10;
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        assert!(
            res.stats.loss_after_update <= res.stats.loss_after_sweep * 1.001,
            "update {} vs sweep {}",
            res.stats.loss_after_update,
            res.stats.loss_after_sweep
        );
    }

    #[test]
    fn odd_shapes_ragged_spans() {
        let mut rng = Rng::new(8);
        // c = 40 with max span 16 -> spans 16,16,8; d=2
        let (w, est) = setup(&mut rng, 10, 40);
        let u = est.inverse_factor(0.01).unwrap();
        let h = est.dampened(0.01);
        let mut cfg = quick_cfg(2, 2);
        cfg.max_group_cols = 16;
        let res = gptvq_quantize(&w, &u, &h, &cfg).unwrap();
        assert_eq!(res.qweight.cols(), 40);
        // all columns quantized (non-zero where w nonzero on average)
        let dec = decode_groups(10, 40, &res.groups);
        assert_eq!(dec, res.qweight);
    }
}
