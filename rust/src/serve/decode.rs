//! Decode policies: how many tokens one slot advances per engine step.
//!
//! A [`DecodePolicy`] owns the per-step token emission of every decode
//! slot. Two ship with the engine:
//!
//! * [`OneToken`] — the classic incremental loop: one KV-cached forward,
//!   one greedy token per step.
//! * [`SelfSpeculative`] — drafts `k` tokens per step on the cheap
//!   dense/decoded path, then verifies all of them in **one** batched
//!   [`forward_logits_cached_with`] call on the serving backend,
//!   accepting the longest matching prefix plus the target's correction
//!   token. Rejected draft positions are rolled back out of the KV cache
//!   ([`KvCache::truncate`]).
//!
//! **Determinism rule**: every policy must emit *exactly* the tokens
//! [`OneToken`] would — policies change wall time and tokens-per-step,
//! never the token stream. For [`SelfSpeculative`] this holds by
//! construction: each emitted token is the greedy argmax of target-path
//! logits over exactly the context [`OneToken`] would have used (the
//! batched verification rows are computed row-independently, so they
//! match the sequential single-row forwards bitwise), and near the
//! sliding-window edge the policy degrades to single-token steps rather
//! than batch across a moving window. Parity is pinned by tests for
//! k ∈ {1, 2, 4} on both backends.
//!
//! **Cross-slot batching**: the engine's batched step splits a policy
//! step into [`DecodePolicy::plan`] (stage this slot's exact forward
//! input) and [`DecodePolicy::finish`] (commit tokens from this slot's
//! rows of the shared batched logits), running every planned slot's
//! input through ONE ragged `forward_logits_batched_with` call. The
//! per-slot `decode` of the shipped policies is implemented as
//! plan → single-item forward → finish, so both step modes execute the
//! same code and token identity across them holds by construction.
//!
//! [`forward_logits_cached_with`]: crate::model::forward::forward_logits_cached_with
//! [`forward_logits_batched_with`]: crate::model::forward::forward_logits_batched_with
//! [`KvCache::truncate`]: crate::model::kv::KvCache::truncate

use crate::error::Result;
use crate::model::forward::{forward_logits, forward_logits_cached_with, DenseLinears};
use crate::model::kv::{KvCache, KvSeq};
use crate::model::Model;
use crate::serve::engine::SeqState;
use crate::serve::{model_from_container, ServeBackend};
use crate::tensor::Matrix;

/// NaN-filtered greedy argmax over one logits row: the index of the
/// largest non-NaN logit as a byte token (the model is a byte LM with a
/// 256-entry vocabulary). A corrupted row of all-NaN logits falls back to
/// `b' '` instead of letting NaN win the comparison or panicking — the
/// single shared argmax used by every decode policy and the deprecated
/// `generate_greedy*` shims.
pub fn argmax_logits(logits: &[f64]) -> u8 {
    logits
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan()) // a NaN logit must not win argmax
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u8)
        .unwrap_or(b' ')
}

/// Per-slot draft-path state for [`SelfSpeculative`]: a second KV cache
/// tracking the accepted token stream through the draft model. Lives on
/// the slot's [`SeqState`] so the policy itself stays slot-agnostic —
/// and so cancellation, deadline expiry, and sink-close all free the
/// draft cache for free: dropping the slot drops its `SeqState`, which
/// owns both the serving KV cache and this one.
#[derive(Debug)]
pub(crate) struct DraftState {
    /// draft-model KV cache over a prefix of the accepted stream
    pub(crate) cache: KvCache,
}

/// One slot's staged contribution to a cross-slot batched engine step,
/// produced by [`DecodePolicy::plan`] and consumed by
/// [`DecodePolicy::finish`] after the engine ran every staged slot
/// through ONE ragged batched forward. `input` is the exact token slice
/// the policy's own `decode` would have forwarded behind the slot's KV
/// cache: the cache's pending suffix of the accepted stream, plus
/// `n_draft` trailing unverified draft tokens for speculative policies.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// tokens to forward behind the slot's KV cache (never empty)
    pub input: Vec<u8>,
    /// how many trailing tokens of `input` are unverified drafts
    pub n_draft: usize,
}

/// Per-step token emission strategy for one decode slot. See the module
/// docs for the determinism rule every implementation must obey.
pub trait DecodePolicy {
    /// Policy name, as shown by `gptvq serve` and the bench tables.
    fn name(&self) -> &'static str;

    /// Called once when an engine takes ownership of its backend, so a
    /// policy can derive auxiliary state (e.g. [`SelfSpeculative`]
    /// decodes a fused container into its dense draft model here).
    fn attach(&mut self, _backend: &ServeBackend) -> Result<()> {
        Ok(())
    }

    /// Advance `seq` by at least one and at most `remaining` tokens
    /// (`remaining ≥ 1`); returns the emitted tokens in order. Every
    /// returned token must also be committed to the stream
    /// ([`SeqState::commit_token`] / [`SeqState::one_token`]) — the
    /// engine derives slot progress from the stream length.
    fn decode(&mut self, backend: &ServeBackend, seq: &mut SeqState, remaining: usize) -> Vec<u8>;

    /// Stage this slot for the engine's cross-slot batched forward
    /// instead of forwarding immediately: slide the window, run any
    /// draft-path work, and return the exact input `decode` would have
    /// forwarded — without committing tokens yet. The engine stacks
    /// every staged slot's input into one ragged batched forward and
    /// hands each policy its logit rows back via
    /// [`DecodePolicy::finish`]. Returning `None` (the default) opts the
    /// slot out of the batch; the engine falls back to `decode` for it,
    /// so external policies keep working unchanged under batched
    /// stepping.
    fn plan(
        &mut self,
        _backend: &ServeBackend,
        _seq: &mut SeqState,
        _remaining: usize,
    ) -> Option<BatchPlan> {
        None
    }

    /// Commit tokens for a slot staged by [`DecodePolicy::plan`]:
    /// rows `row0 .. row0 + plan.input.len()` of `logits` are this
    /// slot's slice of the batched forward, bitwise identical to what a
    /// dedicated forward of `plan.input` would have produced. Same
    /// contract as `decode`: emit 1..=remaining tokens and commit every
    /// one to `seq`. Only invoked after `plan` returned `Some` on the
    /// same policy, so the default is unreachable for policies that
    /// never plan.
    fn finish(
        &mut self,
        _seq: &mut SeqState,
        _plan: &BatchPlan,
        _logits: &Matrix,
        _row0: usize,
    ) -> Vec<u8> {
        unreachable!("DecodePolicy::finish called on a policy that never returned a plan")
    }

    /// Cumulative `(drafted, accepted)` draft-token counters for
    /// speculative policies; `None` for policies that never draft.
    fn spec_counters(&self) -> Option<(usize, usize)> {
        None
    }
}

// ---------------------------------------------------------------------------

/// One KV-cached forward, one greedy token per step — the serving
/// default, and the reference stream every other policy must reproduce.
#[derive(Debug, Clone, Copy, Default)]
pub struct OneToken;

impl OneToken {
    /// New one-token policy.
    pub fn new() -> OneToken {
        OneToken
    }
}

impl DecodePolicy for OneToken {
    fn name(&self) -> &'static str {
        "one-token"
    }

    fn decode(&mut self, backend: &ServeBackend, seq: &mut SeqState, _remaining: usize) -> Vec<u8> {
        vec![seq.one_token(backend.model(), backend)]
    }

    fn plan(
        &mut self,
        _backend: &ServeBackend,
        seq: &mut SeqState,
        _remaining: usize,
    ) -> Option<BatchPlan> {
        // the exact pending suffix SeqState::one_token would forward
        seq.sync_window();
        let new0 = seq.window_start + seq.cache.len();
        Some(BatchPlan { input: seq.tokens[new0..].to_vec(), n_draft: 0 })
    }

    fn finish(
        &mut self,
        seq: &mut SeqState,
        plan: &BatchPlan,
        logits: &Matrix,
        row0: usize,
    ) -> Vec<u8> {
        let next = argmax_logits(logits.row(row0 + plan.input.len() - 1));
        seq.commit_token(next);
        vec![next]
    }
}

// ---------------------------------------------------------------------------

/// The seed's full-recompute decode: every step re-runs the whole context
/// window through the model with a fresh cache. Kept only as the baseline
/// the KV-cached policies are measured against in
/// `benches/runtime_throughput.rs` — never use it to serve. It never
/// returns a [`BatchPlan`] (its forward does not extend the slot's real
/// KV cache), so under batched stepping the engine exercises the
/// per-slot fallback path for it.
#[derive(Debug, Clone, Copy, Default)]
pub struct FullRecompute;

impl FullRecompute {
    /// New full-recompute baseline policy.
    pub fn new() -> FullRecompute {
        FullRecompute
    }
}

impl DecodePolicy for FullRecompute {
    fn name(&self) -> &'static str {
        "full-recompute"
    }

    fn decode(&mut self, backend: &ServeBackend, seq: &mut SeqState, _remaining: usize) -> Vec<u8> {
        let ctx_start = seq.tokens.len().saturating_sub(seq.max_ctx);
        let window = &seq.tokens[ctx_start..];
        let logits = match backend {
            // the seed baseline proper: the plain full forward, with no
            // KV-append traffic that would inflate the measured baseline
            ServeBackend::Dense(m) => forward_logits(m, window),
            // the fused path has no cache-free forward; prefill into a
            // throwaway cache (bitwise-identical logits)
            ServeBackend::FusedVq { .. } => {
                let model = backend.model();
                let mut cache = KvCache::oracle(&model.cfg);
                forward_logits_cached_with(model, backend, &mut cache, window)
            }
        };
        let next = argmax_logits(logits.row(logits.rows() - 1));
        seq.tokens.push(next);
        vec![next]
    }
}

// ---------------------------------------------------------------------------

/// Self-speculative multi-token decode: draft `k` tokens per step with
/// the cheap dense/decoded path, verify them in one batched target-path
/// forward, accept the longest matching prefix plus the target's own
/// next token — between 1 and `k + 1` tokens per step, token-identical
/// to [`OneToken`] (see the module docs for why).
///
/// * On a [`ServeBackend::Dense`] engine the draft path *is* the target
///   path, so every draft is accepted and each step emits `k + 1` tokens
///   (subject to the request budget). Note this configuration is the
///   *parity harness*, not a speed win: dense matmul cost is linear in
///   rows, so the k un-batched draft forwards plus the (k+1)-row verify
///   cost roughly twice OneToken's FLOPs, and the draft cache doubles
///   per-slot KV memory. Use it to validate the machinery (acceptance is
///   exactly 1.0); serve dense traffic with [`OneToken`].
/// * On a [`ServeBackend::FusedVq`] engine the drafts come from a dense
///   model decoded once from the container at [`DecodePolicy::attach`]
///   time (trading the container's memory win for draft speed — the
///   packed payload still serves verification), and the batched
///   verification runs the fused LUT decode-matmul over all `k + 1`
///   rows at once, amortizing packed-index reads across the batch (see
///   `VqLinear::matmul_decoded`) — this is where the wall-clock win
///   lives. Draft and target logits differ only in float rounding, so
///   acceptance stays near 1.
///
/// Rejected draft positions are rolled back from both KV caches via
/// [`KvCache::truncate`], so a mispredicted step costs one wasted row of
/// the batch, never a corrupted cache.
pub struct SelfSpeculative {
    k: usize,
    /// dense draft model decoded from a fused container (None on dense
    /// backends, where the backend's own model drafts)
    draft: Option<Model>,
    drafted: usize,
    accepted: usize,
}

impl SelfSpeculative {
    /// Speculative policy drafting `k ≥ 1` tokens per step.
    pub fn new(k: usize) -> SelfSpeculative {
        assert!(k >= 1, "SelfSpeculative needs a draft length of at least 1");
        SelfSpeculative { k, draft: None, drafted: 0, accepted: 0 }
    }

    /// Configured draft length `k`.
    pub fn draft_len(&self) -> usize {
        self.k
    }

    /// Draft `k ≥ 1` tokens on the cheap dense/decoded path, extending
    /// the slot's draft cache; the accepted stream stays untouched.
    fn draft_tokens(&self, backend: &ServeBackend, seq: &mut SeqState, k: usize) -> Vec<u8> {
        let draft_model: &Model = match backend {
            ServeBackend::Dense(m) => m,
            ServeBackend::FusedVq { .. } => self
                .draft
                .as_ref()
                .expect("SelfSpeculative::attach not called before decode on a fused backend"),
        };
        if seq.draft.is_none() {
            // the draft cache is deliberately contiguous (not pooled):
            // it shadows the accepted stream on the cheap draft path and
            // never competes for the serving arena's pages
            seq.draft = Some(DraftState { cache: KvCache::oracle(&draft_model.cfg) });
        }
        let dcache = &mut seq.draft.as_mut().unwrap().cache;
        // the draft cache always trails the accepted stream (≥ 1
        // pending token), so the first forward is never empty
        let mut pending: Vec<u8> = seq.tokens[dcache.len()..].to_vec();
        let lin = DenseLinears(draft_model);
        let mut drafts: Vec<u8> = Vec::with_capacity(k);
        for _ in 0..k {
            let logits = forward_logits_cached_with(draft_model, &lin, dcache, &pending);
            let next = argmax_logits(logits.row(logits.rows() - 1));
            drafts.push(next);
            pending = vec![next];
        }
        // dcache now covers the accepted stream plus drafts[..k-1]
        drafts
    }
}

impl DecodePolicy for SelfSpeculative {
    fn name(&self) -> &'static str {
        "self-speculative"
    }

    fn attach(&mut self, backend: &ServeBackend) -> Result<()> {
        if let ServeBackend::FusedVq { template, vq } = backend {
            if self.draft.is_none() {
                self.draft = Some(model_from_container(template, vq)?);
            }
        }
        Ok(())
    }

    fn decode(&mut self, backend: &ServeBackend, seq: &mut SeqState, remaining: usize) -> Vec<u8> {
        // the per-slot step is plan → single-item forward → finish, the
        // exact code the engine's batched step runs with more items —
        // cross-mode token identity holds because it IS the same code
        let plan = self
            .plan(backend, seq, remaining)
            .expect("SelfSpeculative::plan always stages a forward");
        let model = backend.model();
        let logits = forward_logits_cached_with(model, backend, &mut seq.cache, &plan.input);
        self.finish(seq, &plan, &logits, 0)
    }

    fn plan(
        &mut self,
        backend: &ServeBackend,
        seq: &mut SeqState,
        remaining: usize,
    ) -> Option<BatchPlan> {
        seq.sync_window();
        let len0 = seq.tokens.len();
        // Speculate only while the whole step fits the context window: in
        // the sliding regime every token shifts ctx_start, so a batched
        // verification would see a different window than OneToken — fall
        // back to single-token steps there to keep token identity.
        let slide_room =
            if seq.window_start == 0 { seq.max_ctx.saturating_sub(len0) } else { 0 };
        let k = self.k.min(remaining.saturating_sub(1)).min(slide_room);
        // input: the target cache's pending suffix of the accepted stream
        // (≥ 1 token), then k freshly drafted tokens to verify
        let t_pending0 = seq.window_start + seq.cache.len();
        let mut input = seq.tokens[t_pending0..].to_vec();
        if k == 0 {
            // this fallback is terminal for drafting: either the window
            // is sliding (it never un-slides) or this is the request's
            // final token — free the slot's draft cache instead of
            // carrying a second full KV cache for the rest of the run
            seq.draft = None;
            return Some(BatchPlan { input, n_draft: 0 });
        }
        let drafts = self.draft_tokens(backend, seq, k);
        input.extend_from_slice(&drafts);
        self.drafted += k;
        Some(BatchPlan { input, n_draft: k })
    }

    fn finish(
        &mut self,
        seq: &mut SeqState,
        plan: &BatchPlan,
        logits: &Matrix,
        row0: usize,
    ) -> Vec<u8> {
        let k = plan.n_draft;
        let len0 = seq.tokens.len();
        let drafts = &plan.input[plan.input.len() - k..];
        // row (base + i) holds the target logits after the accepted
        // stream extended by i accepted drafts
        let base = row0 + (plan.input.len() - k) - 1;
        let mut accepted = 0usize;
        let mut emitted: Vec<u8> = Vec::with_capacity(k + 1);
        while accepted < k {
            let target = argmax_logits(logits.row(base + accepted));
            if drafts[accepted] == target {
                emitted.push(target);
                accepted += 1;
            } else {
                break;
            }
        }
        // the target's own token after the accepted prefix: the
        // correction on mismatch, the free bonus token on full acceptance
        emitted.push(argmax_logits(logits.row(base + accepted)));

        // roll the caches back over rejected draft positions (a no-op
        // for draftless plans: the cache ends exactly at the stream)
        seq.cache.truncate(len0 + accepted - seq.window_start);
        seq.tokens.extend_from_slice(&emitted);
        if let Some(d) = seq.draft.as_mut() {
            let keep = (len0 + accepted).min(d.cache.len());
            d.cache.truncate(keep);
        }
        self.accepted += accepted;
        emitted
    }

    fn spec_counters(&self) -> Option<(usize, usize)> {
        Some((self.drafted, self.accepted))
    }
}
