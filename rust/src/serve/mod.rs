//! The serving runtime: an [`Engine`]/[`Session`] API over pluggable
//! schedulers and decode policies.
//!
//! Four pieces make the paper's closing claim (§5, Table 3 — VQ decode
//! is a *production* execution mode, not just a storage trick) visible on
//! the request path:
//!
//! * **Execution backends** — [`ServeBackend`] selects how linears run:
//!   `Dense` (decoded f64 weights) or `FusedVq` (packed container through
//!   `VqLinear::matmul_decoded`, the LUT decode-matmul that never
//!   materializes a dense weight matrix on the request path).
//! * **KV-cached generation** — each decode slot owns a KV sequence
//!   ([`crate::model::kv::KvSeq`]); a step runs only new positions
//!   through the model ([`crate::model::kv`]). By default that sequence
//!   is a contiguous per-slot [`crate::model::kv::KvCache`]; with
//!   [`Engine::with_kv_page`] every slot draws fixed-size pages from one
//!   shared [`crate::model::kvpool::KvPool`] arena instead (optionally
//!   int8-quantized per page via [`Engine::with_kv_store`]), and
//!   retirement returns the pages to the arena's free list.
//! * **Scheduling** — the [`Engine`] admits requests into decode slots
//!   through a [`Scheduler`] ([`Fifo`], [`RoundRobin`],
//!   [`ShortestRemaining`]) and reports tail fairness (TTFT, queue wait)
//!   per policy, not just throughput.
//! * **Decode policies** — a [`DecodePolicy`] decides tokens per slot per
//!   step: [`OneToken`] (the classic loop) or [`SelfSpeculative`]
//!   (draft-k-verify-batched multi-token decode, token-identical output).
//! * **Cross-slot batching** — by default ([`StepMode::Batched`]) every
//!   scheduled slot's staged input joins ONE ragged batched forward per
//!   step, so a fused-VQ backend decodes each linear once per step
//!   instead of once per slot; long prompts can prefill in budget-sized
//!   chunks ([`Engine::with_prefill_chunk`]). Both are token-identical
//!   to the per-slot reference loop ([`StepMode::PerSlot`]).
//!
//! * **Overload control** — a bounded admission queue
//!   ([`Engine::with_queue_cap`]) sheds excess submissions with a typed
//!   [`Rejected`] outcome, per-request step-count deadlines
//!   ([`GenRequest::deadline_steps`]) expire overdue work and return its
//!   KV immediately, and [`TokenSink`]s push back token-by-token
//!   ([`SinkStatus`]). The open-loop generator in [`loadgen`] produces
//!   the deterministic Poisson/heavy-tail/burst traffic these controls
//!   are evaluated under, and [`ServeStats`] reports goodput and SLO
//!   attainment next to raw throughput. A bounded paged-KV arena
//!   ([`Engine::with_kv_pages`]) extends shedding into the *page*
//!   domain: submissions whose worst-case KV footprint cannot fit are
//!   refused with [`Rejected::KvExhausted`], and schedulers observe
//!   `free_pages` in their views.
//!
//! **Determinism rule**: schedulers and decode policies change wall time,
//! never tokens — every request's output is the greedy decode of its own
//! isolated context under any configuration. Overload decisions
//! (shedding, expiry, backpressure pauses) are made in engine-step time,
//! never wall-clock time, so they inherit the same reproducibility.
//!
//! The seed-era surface — `ContinuousBatcher` and the three
//! `generate_greedy*` free functions — survives as thin deprecated shims
//! over the engine core, kept for bench baselines.

pub mod decode;
pub mod engine;
pub mod loadgen;
pub mod scheduler;
pub mod stats;

pub use decode::{argmax_logits, BatchPlan, DecodePolicy, FullRecompute, OneToken, SelfSpeculative};
pub use engine::{
    Engine, GenRequest, GenResponse, Outcome, Rejected, SeqState, Session, SinkStatus, StepError,
    StepMode, SubmitOutcome, TokenSink,
};
pub use loadgen::{generate, offered_tokens_per_step, run_open_loop, Arrival, LengthDist, LoadGenConfig};
pub use scheduler::{
    Fifo, QueuedView, RoundRobin, Scheduler, ShortestRemaining, SlotView, STARVATION_AGE,
};
pub use stats::{percentile, ServeStats};

use crate::error::Result;
use crate::model::forward::LinearApply;
use crate::model::{LinearKind, Model};
use crate::tensor::{matmul, Matrix};
use crate::vqformat::VqModel;

pub use crate::model::forward::DenseLinears;

/// Rebuild a dense `Model` from a packed VQ container + the FP config.
/// The quantized linears are decoded through the container's int8
/// codebooks; dense residual tensors come straight from the container.
pub fn model_from_container(template: &Model, vq: &VqModel) -> Result<Model> {
    let mut model = template.clone();
    for layer in 0..model.cfg.n_layers {
        for kind in LinearKind::ALL {
            let name = Model::linear_name(layer, kind);
            if let Some(lin) = vq.linears.get(&name) {
                // container stores paper layout [out, in]; model wants [in, out]
                model.set_linear(layer, kind, lin.decode().transpose());
            }
        }
    }
    Ok(model)
}

// ---------------------------------------------------------------------------
// execution backends

/// How the request path executes linear layers.
pub enum ServeBackend {
    /// Dense f64 weights: the FP model, or a container decoded at load.
    Dense(Model),
    /// Packed VQ container executed through the fused LUT decode-matmul.
    /// `template` supplies embeddings, norms, the head, and any linear
    /// absent from the container; quantized linears run straight from
    /// packed indices + int8 codebooks — no dense weight matrix exists.
    FusedVq {
        /// embeddings, norms, head + any linear the container lacks
        template: Model,
        /// the packed container the quantized linears execute from
        vq: VqModel,
    },
}

impl ServeBackend {
    /// Decode the container into a dense model (eval-style execution).
    pub fn dense_from_container(template: &Model, vq: &VqModel) -> Result<ServeBackend> {
        Ok(ServeBackend::Dense(model_from_container(template, vq)?))
    }

    /// Serve the container through the fused LUT decode-matmul path.
    /// Dense copies of container-covered linears are dropped from the
    /// retained template — the fused path never reads them, and keeping
    /// them would defeat the packed container's memory win.
    pub fn fused(template: &Model, vq: VqModel) -> ServeBackend {
        let mut template = template.clone();
        for layer in 0..template.cfg.n_layers {
            for kind in LinearKind::ALL {
                if vq.linears.contains_key(&Model::linear_name(layer, kind)) {
                    template.clear_linear(layer, kind);
                }
            }
        }
        ServeBackend::FusedVq { template, vq }
    }

    /// The model carrying embeddings/norms/head (and, for `Dense`, the
    /// linear weights themselves).
    pub fn model(&self) -> &Model {
        match self {
            ServeBackend::Dense(m) => m,
            ServeBackend::FusedVq { template, .. } => template,
        }
    }

    /// Backend name as exposed by `--backend` ("dense" / "fused-vq").
    pub fn name(&self) -> &'static str {
        match self {
            ServeBackend::Dense(_) => "dense",
            ServeBackend::FusedVq { .. } => "fused-vq",
        }
    }

    /// Weight bytes resident on the request path: f32-equivalent dense
    /// storage vs the packed VQ payload.
    pub fn payload_bytes(&self) -> usize {
        match self {
            ServeBackend::Dense(m) => m.quantizable_weights() * 4,
            ServeBackend::FusedVq { vq, .. } => {
                vq.linears.values().map(|l| l.packed_bytes()).sum()
            }
        }
    }
}

impl LinearApply for ServeBackend {
    fn apply(&self, layer: usize, kind: LinearKind, x: &Matrix) -> Matrix {
        match self {
            ServeBackend::Dense(m) => matmul(x, m.linear(layer, kind)),
            ServeBackend::FusedVq { template, vq } => {
                match vq.linears.get(&Model::linear_name(layer, kind)) {
                    Some(lin) => lin.matmul_decoded(x),
                    None => matmul(x, template.linear(layer, kind)),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// deprecated seed-era shims (kept for bench baselines)

/// Run one request through a single-slot engine core over a borrowed
/// backend — the machinery behind the deprecated `generate_greedy*`
/// shims.
fn run_single(
    backend: &ServeBackend,
    prompt: &[u8],
    max_new: usize,
    mut policy: Box<dyn DecodePolicy>,
) -> Vec<u8> {
    policy.attach(backend).expect("decode policy attach");
    let mut core = engine::Core::new(1, Box::new(Fifo::new()), policy);
    // the shims promise the legacy behavior verbatim: per-slot stepping
    core.step_mode = StepMode::PerSlot;
    // no queue cap, no deadline: with overload control disabled, submit
    // can only fail on a malformed request
    core.submit(GenRequest::new(0, prompt.to_vec(), max_new), None, usize::MAX)
        .expect("generate_greedy shims need a non-empty prompt");
    let mut out = Vec::new();
    while core.pending() > 0 {
        for r in core.step(backend).expect("Fifo + OneToken cannot stall") {
            out = r.output;
        }
    }
    out
}

/// Greedy autoregressive generation with a per-sequence KV cache — the
/// pre-[`Engine`] serving entry point, now a shim over the shared
/// [`OneToken`] step.
#[deprecated(note = "use serve::Engine with ServeBackend::Dense (Fifo + OneToken)")]
pub fn generate_greedy(model: &Model, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut seq = SeqState::new(&model.cfg, prompt);
    (0..max_new).map(|_| seq.one_token(model, &DenseLinears(model))).collect()
}

/// Greedy generation over an execution backend (dense or fused-VQ), now
/// a shim over a single-slot [`Engine`] core.
#[deprecated(note = "use serve::Engine::submit + run_to_completion")]
pub fn generate_greedy_backend(backend: &ServeBackend, prompt: &[u8], max_new: usize) -> Vec<u8> {
    run_single(backend, prompt, max_new, Box::new(OneToken::new()))
}

/// The seed's full-recompute decode, kept as the baseline the KV cache is
/// measured against (`benches/runtime_throughput.rs`): every step re-runs
/// the whole context window through the model. Deliberately *not* routed
/// through the engine so the timed baseline pays exactly the seed's
/// per-step cost (no model clone, no slot bookkeeping, no cache
/// traffic); the engine-resident equivalent is the [`FullRecompute`]
/// decode policy, whose dense path runs this same plain forward.
#[deprecated(note = "use serve::Engine with the FullRecompute policy (bench baseline only)")]
pub fn generate_greedy_full(model: &Model, prompt: &[u8], max_new: usize) -> Vec<u8> {
    use crate::model::forward::forward_logits;
    let mut tokens = prompt.to_vec();
    let max_ctx = model.cfg.max_seq;
    for _ in 0..max_new {
        let ctx_start = tokens.len().saturating_sub(max_ctx);
        let logits = forward_logits(model, &tokens[ctx_start..]);
        let next = argmax_logits(logits.row(logits.rows() - 1));
        tokens.push(next);
    }
    tokens[prompt.len()..].to_vec()
}

/// Deprecated continuous batcher: FIFO admission, one token per sequence
/// per step. Now a thin shim over the [`Engine`] core configured with
/// [`Fifo`] + [`OneToken`], which reproduces its schedule bit-for-bit
/// (pinned by the engine parity test). Kept for bench baselines.
#[deprecated(note = "use serve::Engine (Fifo + OneToken reproduce this schedule bit-for-bit)")]
pub struct ContinuousBatcher {
    core: engine::Core,
    /// maximum concurrently decoding sequences
    pub max_batch: usize,
}

#[allow(deprecated)]
impl ContinuousBatcher {
    /// Batcher with up to `max_batch` concurrent decode slots.
    pub fn new(max_batch: usize) -> ContinuousBatcher {
        let max_batch = max_batch.max(1);
        let mut core =
            engine::Core::new(max_batch, Box::new(Fifo::new()), Box::new(OneToken::new()));
        // the legacy batcher decoded one forward per slot per step; pin
        // per-slot mode so its schedule stays reproduced bit-for-bit
        core.step_mode = StepMode::PerSlot;
        ContinuousBatcher { core, max_batch }
    }

    /// Enqueue a request; it is admitted at the next scheduler step
    /// with a free slot. Panics on an empty prompt (the legacy surface
    /// has no error channel; the old code panicked inside the forward
    /// pass instead).
    pub fn submit(&mut self, req: GenRequest) {
        // unbounded queue, no deadline: the legacy surface predates
        // admission control, so nothing is ever shed here
        let _outcome = self.core.submit(req, None, usize::MAX).expect("invalid request");
    }

    /// Requests not yet completed (queued + active).
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.core.queued()
    }

    /// Requests currently decoding.
    pub fn active_count(&self) -> usize {
        self.core.active_count()
    }

    /// One scheduler step: admit queued requests into free slots, decode
    /// one token for every active sequence, retire finished ones.
    /// Returns the responses completed this step (admission order).
    pub fn step(&mut self, backend: &ServeBackend) -> Vec<GenResponse> {
        self.core.max_batch = self.max_batch.max(1);
        // the pinned Fifo scheduler upholds every progress contract
        self.core.step(backend).expect("Fifo + OneToken cannot stall")
    }

    /// Drain queue and slots, accumulating stats.
    pub fn run_to_completion(&mut self, backend: &ServeBackend) -> ServeStats {
        self.core.max_batch = self.max_batch.max(1);
        self.core.run_to_completion(backend).expect("Fifo + OneToken cannot stall")
    }
}

#[cfg(test)]
#[allow(deprecated)] // the shims are exercised on purpose (parity baselines)
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;
    use crate::model::forward::forward_logits_cached_with;
    use crate::model::kv::KvCache;

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = tiny_model(51);
        let a = generate_greedy(&m, b"hello wor", 8);
        let b = generate_greedy(&m, b"hello wor", 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn context_window_is_respected() {
        let m = tiny_model(52);
        // prompt longer than max_seq must not panic
        let long: Vec<u8> = (0..100).map(|i| (i % 250) as u8).collect();
        let out = generate_greedy(&m, &long, 4);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn kv_cached_generation_matches_full_recompute() {
        // parity including the sliding-window regime: tiny max_seq is 32,
        // so 28 prompt tokens + 12 new tokens crosses the window edge
        let m = tiny_model(56);
        let prompt: Vec<u8> = (0..28).map(|i| (i * 13 + 7) as u8).collect();
        let cached = generate_greedy(&m, &prompt, 12);
        let full = generate_greedy_full(&m, &prompt, 12);
        assert_eq!(cached, full);
    }

    #[test]
    fn engine_completes_all_and_preserves_ids() {
        let m = tiny_model(53);
        let mut e = Engine::new(ServeBackend::Dense(m), 2);
        for id in 0..5 {
            e.submit(GenRequest::new(id, vec![65 + id as u8; 4], 2)).unwrap();
        }
        let mut done = Vec::new();
        while e.pending() > 0 {
            done.extend(e.step().unwrap().into_iter().map(|r| r.id));
        }
        // equal-length requests on a FIFO admission: completion keeps order
        assert_eq!(done, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fifo_engine_matches_legacy_batcher_transcript() {
        // the Fifo + OneToken engine and the deprecated ContinuousBatcher
        // shim produce bitwise-equal transcripts (ids, outputs, completion
        // order), mid-stream admission included. Since the engine defaults
        // to StepMode::Batched while the shim pins StepMode::PerSlot, this
        // is also a cross-mode identity check: one ragged batched forward
        // per step reproduces the legacy one-forward-per-slot schedule
        // token for token. The legacy schedule itself — FIFO admission
        // order, one token per slot per step, retire-on-finish in
        // admission order — is pinned by engine_completes_all_* and
        // mid_stream_admission_and_isolation below, whose expectations
        // were written against the pre-engine batcher's behavior
        let m = tiny_model(57);
        let reqs = |n: u64| -> Vec<GenRequest> {
            (0..n)
                .map(|id| {
                    GenRequest::new(
                        id,
                        vec![b'a' + (id % 7) as u8; 3 + (id % 3) as usize],
                        2 + (id as usize % 5) * 3,
                    )
                })
                .collect()
        };
        let run_engine = |m: &Model| {
            let mut e = Engine::new(ServeBackend::Dense(m.clone()), 3);
            for r in reqs(4) {
                e.submit(r).unwrap();
            }
            let mut transcript = Vec::new();
            let mut injected = false;
            while e.pending() > 0 {
                for r in e.step().unwrap() {
                    transcript.push((r.id, r.output, r.tokens_generated));
                }
                if !injected {
                    // mid-stream admission exercises the slot-reuse path
                    for mut r in reqs(3) {
                        r.id += 10;
                        e.submit(r).unwrap();
                    }
                    injected = true;
                }
            }
            transcript
        };
        let run_legacy = |m: &Model| {
            let backend = ServeBackend::Dense(m.clone());
            let mut b = ContinuousBatcher::new(3);
            for r in reqs(4) {
                b.submit(r);
            }
            let mut transcript = Vec::new();
            let mut injected = false;
            while b.pending() > 0 {
                for r in b.step(&backend) {
                    transcript.push((r.id, r.output, r.tokens_generated));
                }
                if !injected {
                    for mut r in reqs(3) {
                        r.id += 10;
                        b.submit(r);
                    }
                    injected = true;
                }
            }
            transcript
        };
        assert_eq!(run_engine(&m), run_legacy(&m));
    }

    #[test]
    fn mid_stream_admission_and_isolation() {
        // a short request admitted mid-generation must complete before a
        // long one that started earlier, and every output must equal the
        // request's isolated generation (no cross-sequence contamination)
        let m = tiny_model(57);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2);
        e.submit(GenRequest::new(0, b"abcd".to_vec(), 3)).unwrap();
        e.submit(GenRequest::new(1, b"efgh".to_vec(), 10)).unwrap();
        // one step: both slots busy, then a short request arrives
        assert!(e.step().unwrap().is_empty());
        e.submit(GenRequest::new(2, b"ijkl".to_vec(), 2)).unwrap();
        assert_eq!(e.queued(), 1);
        assert_eq!(e.active_count(), 2);
        let mut completions = Vec::new();
        let mut responses = Vec::new();
        while e.pending() > 0 {
            for r in e.step().unwrap() {
                completions.push(r.id);
                responses.push(r);
            }
        }
        // id 2 enters the slot id 0 frees and, being short, overtakes the
        // still-running id 1 — the seed's FIFO batcher could not do this
        assert_eq!(completions, vec![0, 2, 1]);
        for r in &responses {
            let prompt: &[u8] = match r.id {
                0 => b"abcd",
                1 => b"efgh",
                _ => b"ijkl",
            };
            let isolated = generate_greedy(&m, prompt, r.output.len());
            assert_eq!(r.output, isolated, "request {} contaminated", r.id);
        }
    }

    #[test]
    fn session_streams_tokens_and_reports_timing() {
        let m = tiny_model(54);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2);
        let streamed = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let sink_buf = std::rc::Rc::clone(&streamed);
        let session = e
            .submit_with_sink(
                GenRequest::new(9, b"abc".to_vec(), 5),
                Box::new(move |t: u8| {
                    sink_buf.borrow_mut().push(t);
                    SinkStatus::Ready
                }),
            )
            .unwrap();
        assert!(!session.is_finished());
        assert_eq!(session.time_to_first_token(), None);
        let stats = e.run_to_completion().unwrap();
        assert!(session.is_finished());
        let resp = session.response().expect("finished session has a response");
        assert_eq!(resp.id, 9);
        assert_eq!(resp.output.len(), 5);
        // the sink and the session snapshot both saw exactly the output
        assert_eq!(*streamed.borrow(), resp.output);
        assert_eq!(session.streamed(), resp.output);
        // timing surfaces: ttft within total latency, queue wait recorded
        assert!(session.time_to_first_token().unwrap() <= resp.latency_s);
        assert!(session.queue_wait().unwrap() >= 0.0);
        assert!((resp.ttft_s - session.time_to_first_token().unwrap()).abs() < 1e-12);
        // per-run stats carry the tail-fairness vectors
        assert_eq!(stats.ttfts.len(), 1);
        assert_eq!(stats.queue_waits.len(), 1);
        assert!(stats.ttft_percentile(95.0) >= stats.queue_wait_percentile(95.0));
        // output equals the isolated generation
        assert_eq!(resp.output, generate_greedy(&m, b"abc", 5));
    }

    #[test]
    fn stats_accumulate() {
        // 4 requests × 3 tokens on 3 slots: 2 waves of 3 steps each.
        // Under the default batched mode one step is one decode call no
        // matter how many slots advanced; per-slot mode keeps the legacy
        // one-call-per-slot-token accounting.
        let run = |mode: StepMode| {
            let m = tiny_model(54);
            let mut e = Engine::new(ServeBackend::Dense(m), 3).with_step_mode(mode);
            for id in 0..4 {
                e.submit(GenRequest::new(id, b"abc".to_vec(), 3)).unwrap();
            }
            e.run_to_completion().unwrap()
        };
        let stats = run(StepMode::Batched);
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.total_tokens, 12);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.p50_latency() >= 0.0);
        assert!(stats.p95_latency() >= stats.p50_latency());
        assert!(stats.p99_latency() >= stats.p95_latency());
        assert_eq!(stats.engine_steps, 6);
        // batched: one forward per step — wave 1 batches 3 slots, wave 2
        // has 1, so 12 tokens over 6 calls
        assert_eq!(stats.decode_calls, 6);
        assert_eq!(stats.decoded_tokens, 12);
        assert!((stats.tokens_per_step() - 2.0).abs() < 1e-12);
        assert_eq!(stats.acceptance_rate(), None);
        assert_eq!(stats.prefill_chunks, 0);
        // per-slot reference: one decode call per generated token
        let legacy = run(StepMode::PerSlot);
        assert_eq!(legacy.engine_steps, 6);
        assert_eq!(legacy.decode_calls, 12);
        assert_eq!(legacy.decoded_tokens, 12);
        assert!((legacy.tokens_per_step() - 1.0).abs() < 1e-12);
    }

    fn run_policy_engine(
        m: &Model,
        scheduler: Box<dyn Scheduler>,
        budget: usize,
        reqs: Vec<GenRequest>,
    ) -> Vec<GenResponse> {
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2)
            .with_scheduler(scheduler)
            .with_step_budget(budget);
        for r in reqs {
            e.submit(r).unwrap();
        }
        let mut responses = Vec::new();
        let mut guard = 0;
        while e.pending() > 0 {
            responses.extend(e.step().unwrap());
            guard += 1;
            assert!(guard < 10_000, "engine failed to make progress");
        }
        responses
    }

    #[test]
    fn schedulers_never_change_tokens() {
        // the determinism rule: any scheduler/budget combination emits
        // exactly the isolated greedy tokens for every request
        let m = tiny_model(61);
        let mk_reqs = || -> Vec<GenRequest> {
            (0..5)
                .map(|id| {
                    GenRequest::new(id, vec![b'p' + id as u8; 4], [7usize, 2, 9, 3, 5][id as usize])
                })
                .collect()
        };
        for (sched, budget) in [
            (Box::new(Fifo::new()) as Box<dyn Scheduler>, 0usize),
            (Box::new(RoundRobin::new()), 1),
            (Box::new(ShortestRemaining::new()), 1),
        ] {
            let responses = run_policy_engine(&m, sched, budget, mk_reqs());
            assert_eq!(responses.len(), 5);
            for r in &responses {
                let prompt = vec![b'p' + r.id as u8; 4];
                let isolated = generate_greedy(&m, &prompt, r.output.len());
                assert_eq!(r.output, isolated, "request {} tokens changed", r.id);
            }
        }
    }

    #[test]
    fn no_starvation_under_adversarial_short_request_flood() {
        // a long request competes against a stream of short ones under a
        // 1-slot step budget; aging must keep it progressing under both
        // fair-share policies (pure SRPT would park it forever)
        let m = tiny_model(62);
        for sched in [
            Box::new(RoundRobin::new()) as Box<dyn Scheduler>,
            Box::new(ShortestRemaining::new()),
        ] {
            let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2)
                .with_scheduler(sched)
                .with_step_budget(1);
            e.submit(GenRequest::new(0, b"long".to_vec(), 12)).unwrap();
            let mut finished = std::collections::BTreeMap::new();
            let mut next_id = 1u64;
            for step in 0..400 {
                // keep injecting short work for the first 60 steps
                if step < 60 && step % 3 == 0 {
                    e.submit(GenRequest::new(next_id, b"shrt".to_vec(), 2)).unwrap();
                    next_id += 1;
                }
                for r in e.step().unwrap() {
                    finished.insert(r.id, (step, r.output));
                }
                if e.pending() == 0 && step >= 60 {
                    break;
                }
            }
            assert!(e.pending() == 0, "engine did not drain");
            let (long_step, long_out) = finished.get(&0).expect("long request starved");
            // the long request must finish while shorts were still being
            // injected or shortly after — not only once the flood ended
            assert!(
                *long_step < 180,
                "long request finished too late (step {long_step}) — starvation"
            );
            assert_eq!(long_out, &generate_greedy(&m, b"long", 12), "long output corrupted");
            for (id, (_, out)) in finished.iter().filter(|(id, _)| **id != 0) {
                assert_eq!(out, &generate_greedy(&m, b"shrt", 2), "short {id} corrupted");
            }
        }
    }

    #[test]
    fn shortest_remaining_cuts_short_request_tail() {
        // with a long request hogging a slot, SRPT admits+retires the
        // short requests first, so their completion precedes the long one
        let m = tiny_model(63);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2)
            .with_scheduler(Box::new(ShortestRemaining::new()));
        e.submit(GenRequest::new(0, b"AAAA".to_vec(), 20)).unwrap();
        e.submit(GenRequest::new(1, b"BBBB".to_vec(), 20)).unwrap();
        for id in 2..6 {
            e.submit(GenRequest::new(id, b"CCCC".to_vec(), 2)).unwrap();
        }
        let mut order = Vec::new();
        while e.pending() > 0 {
            order.extend(e.step().unwrap().into_iter().map(|r| r.id));
        }
        // all four shorts retire before both longs
        let long_pos = order.iter().position(|&id| id == 0 || id == 1).unwrap();
        let last_short_pos = order.iter().rposition(|&id| id >= 2).unwrap();
        assert!(
            last_short_pos < long_pos || order[..long_pos].iter().filter(|&&id| id >= 2).count() == 4,
            "shorts did not overtake longs: {order:?}"
        );
    }

    #[test]
    fn speculative_decode_is_token_identical_to_one_token() {
        // the tentpole acceptance: SelfSpeculative(k) emits exactly the
        // OneToken stream for k ∈ {1, 2, 4} while decoding fewer steps
        let m = tiny_model(64);
        let prompt: Vec<u8> = (0..6).map(|i| (i * 31 + 3) as u8).collect();
        let run = |k: usize| -> (Vec<u8>, ServeStats) {
            let policy: Box<dyn DecodePolicy> = if k == 0 {
                Box::new(OneToken::new())
            } else {
                Box::new(SelfSpeculative::new(k))
            };
            let mut e = Engine::new(ServeBackend::Dense(m.clone()), 1)
                .with_decode(policy)
                .unwrap();
            let s = e.submit(GenRequest::new(0, prompt.clone(), 14)).unwrap();
            let stats = e.run_to_completion().unwrap();
            (s.response().unwrap().output, stats)
        };
        let (base, base_stats) = run(0);
        assert_eq!(base.len(), 14);
        assert_eq!(base_stats.decode_calls, 14);
        for k in [1usize, 2, 4] {
            let (out, stats) = run(k);
            assert_eq!(out, base, "SelfSpeculative({k}) diverged from OneToken");
            assert!(
                stats.decode_calls < base_stats.decode_calls,
                "k={k} did not reduce decode steps ({} vs {})",
                stats.decode_calls,
                base_stats.decode_calls
            );
            assert!(stats.tokens_per_step() > 1.0, "k={k} tokens/step not > 1");
            // dense draft path == target path: every draft accepted
            assert_eq!(stats.acceptance_rate(), Some(1.0), "k={k}");
        }
    }

    #[test]
    fn speculative_decode_survives_the_sliding_window() {
        // near the window edge the policy must degrade to one-token steps
        // and still match OneToken exactly (tiny max_seq is 32; 28 prompt
        // + 12 new tokens slides the window mid-request)
        let m = tiny_model(65);
        let prompt: Vec<u8> = (0..28).map(|i| (i * 13 + 7) as u8).collect();
        let base = generate_greedy(&m, &prompt, 12);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 1)
            .with_decode(Box::new(SelfSpeculative::new(4)))
            .unwrap();
        let s = e.submit(GenRequest::new(0, prompt.clone(), 12)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(s.response().unwrap().output, base);
    }

    #[test]
    fn batched_step_composes_with_speculative_decode_across_slots() {
        // the tentpole composition: SelfSpeculative verification rows
        // from different slots join ONE ragged batched forward, and the
        // result is token-identical to the per-slot reference — same
        // outputs, same step-count timing, same draft/accept counters —
        // while spending fewer target forwards
        let m = tiny_model(71);
        let reqs: Vec<GenRequest> = (0..3u64)
            .map(|id| {
                GenRequest::new(
                    id,
                    (0..5).map(|i| (i * 17 + id as usize * 7 + 2) as u8).collect(),
                    10,
                )
            })
            .collect();
        let run = |mode: StepMode| {
            let mut e = Engine::new(ServeBackend::Dense(m.clone()), 3)
                .with_step_mode(mode)
                .with_decode(Box::new(SelfSpeculative::new(2)))
                .unwrap();
            let sessions: Vec<Session> =
                reqs.iter().map(|r| e.submit(r.clone()).unwrap()).collect();
            let stats = e.run_to_completion().unwrap();
            let out: Vec<(Vec<u8>, Option<usize>)> = sessions
                .iter()
                .map(|s| (s.response().unwrap().output, s.time_to_first_token_steps()))
                .collect();
            (out, stats)
        };
        let (batched, bs) = run(StepMode::Batched);
        let (per_slot, ps) = run(StepMode::PerSlot);
        assert_eq!(batched, per_slot, "speculative batching changed tokens or timing");
        assert_eq!((bs.spec_drafted, bs.spec_accepted), (ps.spec_drafted, ps.spec_accepted));
        assert_eq!(bs.decoded_tokens, ps.decoded_tokens);
        assert!(
            bs.decode_calls < ps.decode_calls,
            "batching must cut target forwards ({} vs {})",
            bs.decode_calls,
            ps.decode_calls
        );
        // and each stream equals the isolated greedy decode
        for (i, (out, _)) in batched.iter().enumerate() {
            assert_eq!(out, &generate_greedy(&m, &reqs[i].prompt, 10), "slot {i} contaminated");
        }
    }

    // -----------------------------------------------------------------
    // quantized-container backends

    fn quantized_container(m: &Model) -> (Model, VqModel) {
        use crate::coordinator::{quantize_model, Method, PipelineConfig};
        use crate::data::tokens::synthetic_stream;
        use crate::quant::gptvq::GptvqConfig;
        let template = m.clone();
        let mut qm = m.clone();
        let s = synthetic_stream(4_000, 1);
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 5;
        g.update_iters = 2;
        g.group_size = 256;
        let mut cfg = PipelineConfig::new(Method::Gptvq(g));
        cfg.calib_sequences = 2;
        cfg.calib_seq_len = 16;
        let rep = quantize_model(&mut qm, &s, &cfg).unwrap();
        (template, rep.vq_model.unwrap())
    }

    #[test]
    fn container_roundtrip_model() {
        use crate::coordinator::{quantize_model, Method, PipelineConfig};
        use crate::data::tokens::synthetic_stream;
        use crate::quant::gptvq::GptvqConfig;
        let mut m = tiny_model(55);
        let template = m.clone();
        let s = synthetic_stream(4_000, 1);
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 5;
        g.update_iters = 2;
        g.group_size = 256;
        let mut cfg = PipelineConfig::new(Method::Gptvq(g));
        cfg.calib_sequences = 2;
        cfg.calib_seq_len = 16;
        let rep = quantize_model(&mut m, &s, &cfg).unwrap();
        let vq = rep.vq_model.unwrap();
        let served = model_from_container(&template, &vq).unwrap();
        // served model linears equal the quantized model's
        for kind in LinearKind::ALL {
            let a = served.linear(0, kind);
            let b = m.linear(0, kind);
            let diff = a.sub(b).max_abs();
            assert!(diff < 1e-5, "{kind:?}: {diff}");
        }
    }

    #[test]
    fn fused_backend_logits_match_dense_backend() {
        // acceptance: the fused-VQ backend produces logits matching the
        // dense backend within 1e-5 without materializing dense weights
        let m = tiny_model(58);
        let (template, vq) = quantized_container(&m);
        let dense = ServeBackend::dense_from_container(&template, &vq).unwrap();
        let fused = ServeBackend::fused(&template, vq);
        let toks: Vec<u8> = (0..12).map(|i| (i * 11 + 5) as u8).collect();
        let mut cd = KvCache::new(&dense.model().cfg);
        let ld = forward_logits_cached_with(dense.model(), &dense, &mut cd, &toks);
        let mut cf = KvCache::new(&fused.model().cfg);
        let lf = forward_logits_cached_with(fused.model(), &fused, &mut cf, &toks);
        let mut max_abs = 0.0f64;
        for (a, b) in ld.as_slice().iter().zip(lf.as_slice()) {
            max_abs = max_abs.max((a - b).abs());
        }
        assert!(max_abs < 1e-5, "backend divergence {max_abs}");
    }

    #[test]
    fn fused_backend_serves_via_engine() {
        let m = tiny_model(59);
        let (template, vq) = quantized_container(&m);
        let packed = vq.linears.values().map(|l| l.packed_bytes()).sum::<usize>();
        let fused = ServeBackend::fused(&template, vq);
        assert_eq!(fused.name(), "fused-vq");
        assert_eq!(fused.payload_bytes(), packed);
        // the dense copy of a container-covered linear was dropped
        assert!(fused.model().layers[0].wq.is_empty(), "dense copy retained");
        let mut e = Engine::new(fused, 2);
        for id in 0..3 {
            e.submit(GenRequest::new(id, b"serve".to_vec(), 3)).unwrap();
        }
        let stats = e.run_to_completion().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.total_tokens, 9);
    }

    #[test]
    fn speculative_decode_matches_one_token_on_fused_backend() {
        // dense-decoded drafts verified on the fused path: output must be
        // token-identical to fused OneToken for every k, and acceptance
        // stays high (draft and target differ only in float rounding)
        let m = tiny_model(66);
        let (template, vq) = quantized_container(&m);
        let prompt: Vec<u8> = (0..6).map(|i| (i * 29 + 11) as u8).collect();
        let run = |k: usize| -> (Vec<u8>, ServeStats) {
            let backend = ServeBackend::fused(&template, vq.clone());
            let policy: Box<dyn DecodePolicy> = if k == 0 {
                Box::new(OneToken::new())
            } else {
                Box::new(SelfSpeculative::new(k))
            };
            let mut e = Engine::new(backend, 1).with_decode(policy).unwrap();
            let s = e.submit(GenRequest::new(0, prompt.clone(), 12)).unwrap();
            let stats = e.run_to_completion().unwrap();
            (s.response().unwrap().output, stats)
        };
        let (base, base_stats) = run(0);
        for k in [1usize, 2, 4] {
            let (out, stats) = run(k);
            assert_eq!(out, base, "fused SelfSpeculative({k}) diverged from OneToken");
            assert!(
                stats.decode_calls <= base_stats.decode_calls,
                "k={k} used more decode steps than OneToken"
            );
            assert!(stats.spec_drafted > 0, "k={k} never drafted");
        }
        // at k=4 the batched verification should be accepting drafts
        let (_, s4) = run(4);
        assert!(
            s4.tokens_per_step() > 1.0,
            "fused speculative decode accepted nothing (tokens/step {})",
            s4.tokens_per_step()
        );
    }

    #[test]
    fn open_loop_runs_are_deterministic_and_fully_resolved() {
        // loadgen traffic through a capped engine with deadlines: every
        // offered request terminally resolves exactly once (completed,
        // shed, expired, or cancelled), and two identically-seeded runs
        // agree on every deterministic field of the report
        let m = tiny_model(73);
        let cfg = LoadGenConfig {
            seed: 3,
            rate: 1.0,
            requests: 20,
            prompt_max: 24,
            output_max: 12,
            deadline_steps: 30,
            ..LoadGenConfig::default()
        };
        let arrivals = generate(&cfg);
        let run = || {
            let mut e = Engine::new(ServeBackend::Dense(m.clone()), 2).with_queue_cap(3);
            run_open_loop(&mut e, &arrivals).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.requests + a.shed, 20, "every offered request resolved exactly once");
        assert!(a.completed() > 0, "nothing completed under mild load");
        assert_eq!(
            (a.requests, a.shed, a.expired, a.cancelled),
            (b.requests, b.shed, b.expired, b.cancelled),
            "overload decisions drifted between identically-seeded runs"
        );
        assert_eq!(a.goodput_tokens, b.goodput_tokens);
        assert_eq!(a.clock_steps, b.clock_steps);
        assert_eq!(a.ttft_steps, b.ttft_steps, "step-domain TTFTs must be bitwise equal");
    }

    #[test]
    fn empty_prompt_is_rejected_at_submit() {
        // a bad request must not reach the forward pass, where it would
        // panic the engine under other in-flight requests
        let m = tiny_model(69);
        let mut e = Engine::new(ServeBackend::Dense(m), 1);
        assert!(e.submit(GenRequest::new(0, Vec::new(), 4)).is_err());
        assert_eq!(e.pending(), 0, "rejected request must not be enqueued");
    }

    #[test]
    fn full_recompute_policy_matches_seed_loop() {
        // the engine-resident baseline policy equals the seed loop it
        // mirrors, including the sliding-window regime (28 + 6 > 32)
        let m = tiny_model(68);
        let prompt: Vec<u8> = (0..28).map(|i| (i * 9 + 1) as u8).collect();
        let seed = generate_greedy_full(&m, &prompt, 6);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 1)
            .with_decode(Box::new(FullRecompute::new()))
            .unwrap();
        let s = e.submit(GenRequest::new(0, prompt.clone(), 6)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(s.response().unwrap().output, seed);
    }

    #[test]
    fn deprecated_shims_agree_with_engine() {
        let m = tiny_model(67);
        let prompt = b"shim parity".to_vec();
        let backend = ServeBackend::Dense(m.clone());
        let via_shim = generate_greedy_backend(&backend, &prompt, 9);
        let mut e = Engine::new(ServeBackend::Dense(m.clone()), 1);
        let s = e.submit(GenRequest::new(0, prompt.clone(), 9)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(via_shim, s.response().unwrap().output);
        assert_eq!(via_shim, generate_greedy(&m, &prompt, 9));
    }
}
