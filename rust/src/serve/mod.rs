//! Serving demo: batched greedy generation over a (VQ-decoded) model with
//! latency/throughput accounting — the "tokens per second at fixed
//! accuracy" side of the paper's conclusion.
//!
//! The request path is pure rust: the GVQMODL1 container is decoded with
//! the LUT kernels at load, then a simple FIFO batcher drives the native
//! forward pass (or the PJRT logits artifact in the examples).

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::Result;
use crate::model::forward::forward_logits;
use crate::model::{LinearKind, Model};
use crate::vqformat::VqModel;

/// Rebuild a dense `Model` from a packed VQ container + the FP config.
/// The quantized linears are decoded through the container's int8
/// codebooks; dense residual tensors come straight from the container.
pub fn model_from_container(template: &Model, vq: &VqModel) -> Result<Model> {
    let mut model = template.clone();
    for layer in 0..model.cfg.n_layers {
        for kind in LinearKind::ALL {
            let name = Model::linear_name(layer, kind);
            if let Some(lin) = vq.linears.get(&name) {
                // container stores paper layout [out, in]; model wants [in, out]
                model.set_linear(layer, kind, lin.decode().transpose());
            }
        }
    }
    Ok(model)
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// Completed request with timing.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub output: Vec<u8>,
    pub latency_s: f64,
    pub tokens_generated: usize,
}

/// Greedy autoregressive generation (full-recompute decode — fine at the
/// demo scale; the KV-cache optimization lives in the §Perf backlog).
pub fn generate_greedy(model: &Model, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut tokens = prompt.to_vec();
    let max_ctx = model.cfg.max_seq;
    for _ in 0..max_new {
        let ctx_start = tokens.len().saturating_sub(max_ctx);
        let logits = forward_logits(model, &tokens[ctx_start..]);
        let last = logits.row(logits.rows() - 1);
        let next = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u8)
            .unwrap_or(b' ');
        tokens.push(next);
    }
    tokens[prompt.len()..].to_vec()
}

/// FIFO batcher: drains the queue in arrival order, processing up to
/// `max_batch` requests per step (requests in a batch are generated
/// sequentially on this single-core testbed; the batching structure is
/// what the router contributes).
pub struct Batcher {
    queue: VecDeque<(GenRequest, Instant)>,
    pub max_batch: usize,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub total_tokens: usize,
    pub total_seconds: f64,
    pub latencies: Vec<f64>,
}

impl ServeStats {
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens as f64 / self.total_seconds
        } else {
            0.0
        }
    }

    pub fn p50_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1) }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Process one batch; returns completed responses.
    pub fn step(&mut self, model: &Model) -> Vec<GenResponse> {
        let n = self.queue.len().min(self.max_batch);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let (req, enqueued) = self.queue.pop_front().unwrap();
            let output = generate_greedy(model, &req.prompt, req.max_new_tokens);
            out.push(GenResponse {
                id: req.id,
                tokens_generated: output.len(),
                output,
                latency_s: enqueued.elapsed().as_secs_f64(),
            });
        }
        out
    }

    /// Drain the whole queue, accumulating stats.
    pub fn run_to_completion(&mut self, model: &Model) -> ServeStats {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        while self.pending() > 0 {
            for resp in self.step(model) {
                stats.requests += 1;
                stats.total_tokens += resp.tokens_generated;
                stats.latencies.push(resp.latency_s);
            }
        }
        stats.total_seconds = t0.elapsed().as_secs_f64();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = tiny_model(51);
        let a = generate_greedy(&m, b"hello wor", 8);
        let b = generate_greedy(&m, b"hello wor", 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn context_window_is_respected() {
        let m = tiny_model(52);
        // prompt longer than max_seq must not panic
        let long: Vec<u8> = (0..100).map(|i| (i % 250) as u8).collect();
        let out = generate_greedy(&m, &long, 4);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn batcher_preserves_order_and_ids() {
        let m = tiny_model(53);
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.submit(GenRequest { id, prompt: vec![65 + id as u8; 4], max_new_tokens: 2 });
        }
        let mut done = Vec::new();
        while b.pending() > 0 {
            done.extend(b.step(&m).into_iter().map(|r| r.id));
        }
        assert_eq!(done, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn stats_accumulate() {
        let m = tiny_model(54);
        let mut b = Batcher::new(3);
        for id in 0..4 {
            b.submit(GenRequest { id, prompt: b"abc".to_vec(), max_new_tokens: 3 });
        }
        let stats = b.run_to_completion(&m);
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.total_tokens, 12);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.p50_latency() >= 0.0);
    }

    #[test]
    fn container_roundtrip_model() {
        use crate::coordinator::{quantize_model, Method, PipelineConfig};
        use crate::data::tokens::synthetic_stream;
        use crate::quant::gptvq::GptvqConfig;
        let mut m = tiny_model(55);
        let template = m.clone();
        let s = synthetic_stream(4_000, 1);
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 5;
        g.update_iters = 2;
        g.group_size = 256;
        let mut cfg = PipelineConfig::new(Method::Gptvq(g));
        cfg.calib_sequences = 2;
        cfg.calib_seq_len = 16;
        let rep = quantize_model(&mut m, &s, &cfg).unwrap();
        let vq = rep.vq_model.unwrap();
        let served = model_from_container(&template, &vq).unwrap();
        // served model linears equal the quantized model's
        for kind in LinearKind::ALL {
            let a = served.linear(0, kind);
            let b = m.linear(0, kind);
            let diff = a.sub(b).max_abs();
            assert!(diff < 1e-5, "{kind:?}: {diff}");
        }
    }
}
