//! Incremental-decode serving runtime.
//!
//! Three pieces make the paper's closing claim (§5, Table 3 — VQ decode
//! is a *production* execution mode, not just a storage trick) visible on
//! the request path:
//!
//! * **KV-cached generation** — each sequence owns a [`KvCache`]; a decode
//!   step runs one token through the model instead of recomputing the
//!   whole context ([`crate::model::kv`]).
//! * **Execution backends** — [`ServeBackend`] selects how linears run:
//!   `Dense` (decoded f64 weights) or `FusedVq` (packed container through
//!   [`VqLinear::matmul_decoded`], the LUT decode-matmul that never
//!   materializes a dense weight matrix on the request path).
//! * **Continuous batching** — [`ContinuousBatcher`] admits requests into
//!   free decode slots mid-generation and retires finished sequences per
//!   step (VPTQ/vLLM-style scheduling on this scalar testbed), reporting
//!   p50/p95/p99 latency and tokens/sec.

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::Result;
use crate::model::forward::{forward_logits, forward_logits_cached_with, LinearApply};
use crate::model::kv::KvCache;
use crate::model::{LinearKind, Model, ModelConfig};
use crate::tensor::{matmul, Matrix};
use crate::vqformat::VqModel;

pub use crate::model::forward::DenseLinears;

/// Rebuild a dense `Model` from a packed VQ container + the FP config.
/// The quantized linears are decoded through the container's int8
/// codebooks; dense residual tensors come straight from the container.
pub fn model_from_container(template: &Model, vq: &VqModel) -> Result<Model> {
    let mut model = template.clone();
    for layer in 0..model.cfg.n_layers {
        for kind in LinearKind::ALL {
            let name = Model::linear_name(layer, kind);
            if let Some(lin) = vq.linears.get(&name) {
                // container stores paper layout [out, in]; model wants [in, out]
                model.set_linear(layer, kind, lin.decode().transpose());
            }
        }
    }
    Ok(model)
}

// ---------------------------------------------------------------------------
// execution backends

/// How the request path executes linear layers.
pub enum ServeBackend {
    /// Dense f64 weights: the FP model, or a container decoded at load.
    Dense(Model),
    /// Packed VQ container executed through the fused LUT decode-matmul.
    /// `template` supplies embeddings, norms, the head, and any linear
    /// absent from the container; quantized linears run straight from
    /// packed indices + int8 codebooks — no dense weight matrix exists.
    FusedVq { template: Model, vq: VqModel },
}

impl ServeBackend {
    /// Decode the container into a dense model (eval-style execution).
    pub fn dense_from_container(template: &Model, vq: &VqModel) -> Result<ServeBackend> {
        Ok(ServeBackend::Dense(model_from_container(template, vq)?))
    }

    /// Serve the container through the fused LUT decode-matmul path.
    /// Dense copies of container-covered linears are dropped from the
    /// retained template — the fused path never reads them, and keeping
    /// them would defeat the packed container's memory win.
    pub fn fused(template: &Model, vq: VqModel) -> ServeBackend {
        let mut template = template.clone();
        for layer in 0..template.cfg.n_layers {
            for kind in LinearKind::ALL {
                if vq.linears.contains_key(&Model::linear_name(layer, kind)) {
                    template.clear_linear(layer, kind);
                }
            }
        }
        ServeBackend::FusedVq { template, vq }
    }

    /// The model carrying embeddings/norms/head (and, for `Dense`, the
    /// linear weights themselves).
    pub fn model(&self) -> &Model {
        match self {
            ServeBackend::Dense(m) => m,
            ServeBackend::FusedVq { template, .. } => template,
        }
    }

    /// Backend name as exposed by `--backend` ("dense" / "fused-vq").
    pub fn name(&self) -> &'static str {
        match self {
            ServeBackend::Dense(_) => "dense",
            ServeBackend::FusedVq { .. } => "fused-vq",
        }
    }

    /// Weight bytes resident on the request path: f32-equivalent dense
    /// storage vs the packed VQ payload.
    pub fn payload_bytes(&self) -> usize {
        match self {
            ServeBackend::Dense(m) => m.quantizable_weights() * 4,
            ServeBackend::FusedVq { vq, .. } => {
                vq.linears.values().map(|l| l.packed_bytes()).sum()
            }
        }
    }
}

impl LinearApply for ServeBackend {
    fn apply(&self, layer: usize, kind: LinearKind, x: &Matrix) -> Matrix {
        match self {
            ServeBackend::Dense(m) => matmul(x, m.linear(layer, kind)),
            ServeBackend::FusedVq { template, vq } => {
                match vq.linears.get(&Model::linear_name(layer, kind)) {
                    Some(lin) => lin.matmul_decoded(x),
                    None => matmul(x, template.linear(layer, kind)),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// generation

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// caller-chosen request id, echoed in the response
    pub id: u64,
    /// prompt bytes (the model is a byte LM)
    pub prompt: Vec<u8>,
    /// decode budget after the prompt
    pub max_new_tokens: usize,
}

/// Completed request with timing.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// id of the originating request
    pub id: u64,
    /// full token sequence (prompt + generation)
    pub output: Vec<u8>,
    /// submit-to-retire wall-clock seconds
    pub latency_s: f64,
    /// tokens generated beyond the prompt
    pub tokens_generated: usize,
}

/// Decode state of one sequence: tokens so far plus the KV cache over the
/// current context window. The cache is reused as long as the window does
/// not slide; once the context exceeds `max_seq` the window start moves
/// every step and the state degrades to the full-recompute behavior (the
/// same logits the seed path produced).
struct SeqState {
    tokens: Vec<u8>,
    cache: KvCache,
    window_start: usize,
    max_ctx: usize,
}

impl SeqState {
    fn new(cfg: &ModelConfig, prompt: &[u8]) -> SeqState {
        SeqState {
            tokens: prompt.to_vec(),
            cache: KvCache::new(cfg),
            window_start: 0,
            max_ctx: cfg.max_seq,
        }
    }

    /// Generate one greedy token; prefers appending to the cache, falls
    /// back to re-prefill when the context window slid.
    fn next_token(&mut self, model: &Model, lin: &impl LinearApply) -> u8 {
        let ctx_start = self.tokens.len().saturating_sub(self.max_ctx);
        if ctx_start != self.window_start {
            self.cache.clear();
            self.window_start = ctx_start;
        }
        let new0 = self.window_start + self.cache.len();
        let logits = forward_logits_cached_with(model, lin, &mut self.cache, &self.tokens[new0..]);
        let last = logits.row(logits.rows() - 1);
        let next = last
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan()) // a NaN logit must not win argmax
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u8)
            .unwrap_or(b' ');
        self.tokens.push(next);
        next
    }
}

/// Greedy autoregressive generation with a per-sequence KV cache (the
/// serving default: one incremental step per new token).
pub fn generate_greedy(model: &Model, prompt: &[u8], max_new: usize) -> Vec<u8> {
    generate_greedy_with(model, &DenseLinears(model), prompt, max_new)
}

/// Greedy generation over an execution backend (dense or fused-VQ).
pub fn generate_greedy_backend(backend: &ServeBackend, prompt: &[u8], max_new: usize) -> Vec<u8> {
    generate_greedy_with(backend.model(), backend, prompt, max_new)
}

fn generate_greedy_with(
    model: &Model,
    lin: &impl LinearApply,
    prompt: &[u8],
    max_new: usize,
) -> Vec<u8> {
    let mut seq = SeqState::new(&model.cfg, prompt);
    (0..max_new).map(|_| seq.next_token(model, lin)).collect()
}

/// The seed's full-recompute decode, kept as the baseline the KV cache is
/// measured against (`benches/runtime_throughput.rs`): every step re-runs
/// the whole context window through the model.
pub fn generate_greedy_full(model: &Model, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut tokens = prompt.to_vec();
    let max_ctx = model.cfg.max_seq;
    for _ in 0..max_new {
        let ctx_start = tokens.len().saturating_sub(max_ctx);
        let logits = forward_logits(model, &tokens[ctx_start..]);
        let last = logits.row(logits.rows() - 1);
        let next = last
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan()) // a NaN logit must not win argmax
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u8)
            .unwrap_or(b' ');
        tokens.push(next);
    }
    tokens[prompt.len()..].to_vec()
}

// ---------------------------------------------------------------------------
// statistics

/// Linear-interpolated percentile over unsorted samples (`p` in [0, 100];
/// the inclusive/R-7 definition, so p50 of [1,2,3,4] is 2.5). Shared by
/// every latency report in the serving path. Sorts under IEEE total order
/// so a stray NaN sample (e.g. a 0/0 from an empty timing window) lands
/// at the top tail instead of panicking the whole stats report.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// requests completed
    pub requests: usize,
    /// tokens generated across all requests
    pub total_tokens: usize,
    /// wall-clock seconds of the serving run
    pub total_seconds: f64,
    /// per-request submit-to-retire latencies (seconds)
    pub latencies: Vec<f64>,
}

impl ServeStats {
    /// Aggregate decode throughput.
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens as f64 / self.total_seconds
        } else {
            0.0
        }
    }

    /// Interpolated latency percentile (p in [0, 100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.latencies, p)
    }

    /// Median request latency.
    pub fn p50_latency(&self) -> f64 {
        self.latency_percentile(50.0)
    }

    /// 95th-percentile request latency.
    pub fn p95_latency(&self) -> f64 {
        self.latency_percentile(95.0)
    }

    /// 99th-percentile request latency.
    pub fn p99_latency(&self) -> f64 {
        self.latency_percentile(99.0)
    }
}

// ---------------------------------------------------------------------------
// continuous batching

/// An admitted request mid-generation: one decode slot.
struct ActiveSeq {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    enqueued: Instant,
    seq: SeqState,
}

impl ActiveSeq {
    fn generated(&self) -> usize {
        self.seq.tokens.len() - self.prompt_len
    }
}

/// Continuous batcher: up to `max_batch` sequences decode concurrently;
/// new requests are admitted into free slots *mid-generation* and
/// finished sequences retire the step they complete, so a short request
/// never queues behind a long one (the FIFO head-of-line blocking of the
/// seed batcher). Each slot owns its KV cache; one [`Self::step`]
/// advances every active sequence by one token.
pub struct ContinuousBatcher {
    queue: VecDeque<(GenRequest, Instant)>,
    active: Vec<ActiveSeq>,
    /// maximum concurrently decoding sequences
    pub max_batch: usize,
}

impl ContinuousBatcher {
    /// Batcher with up to `max_batch` concurrent decode slots.
    pub fn new(max_batch: usize) -> ContinuousBatcher {
        ContinuousBatcher {
            queue: VecDeque::new(),
            active: Vec::new(),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue a request; it is admitted at the next scheduler step
    /// with a free slot.
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    /// Requests not yet completed (queued + active).
    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently decoding.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// One scheduler step: admit queued requests into free slots, decode
    /// one token for every active sequence, retire finished ones.
    /// Returns the responses completed this step (admission order).
    pub fn step(&mut self, backend: &ServeBackend) -> Vec<GenResponse> {
        while self.active.len() < self.max_batch {
            let Some((req, enqueued)) = self.queue.pop_front() else { break };
            self.active.push(ActiveSeq {
                id: req.id,
                prompt_len: req.prompt.len(),
                max_new: req.max_new_tokens,
                enqueued,
                seq: SeqState::new(&backend.model().cfg, &req.prompt),
            });
        }
        let model = backend.model();
        for a in &mut self.active {
            if a.generated() < a.max_new {
                a.seq.next_token(model, backend);
            }
        }
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated() >= self.active[i].max_new {
                let a = self.active.remove(i);
                done.push(GenResponse {
                    id: a.id,
                    tokens_generated: a.generated(),
                    output: a.seq.tokens[a.prompt_len..].to_vec(),
                    latency_s: a.enqueued.elapsed().as_secs_f64(),
                });
            } else {
                i += 1;
            }
        }
        done
    }

    /// Drain queue and slots, accumulating stats.
    pub fn run_to_completion(&mut self, backend: &ServeBackend) -> ServeStats {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        while self.pending() > 0 {
            for resp in self.step(backend) {
                stats.requests += 1;
                stats.total_tokens += resp.tokens_generated;
                stats.latencies.push(resp.latency_s);
            }
        }
        stats.total_seconds = t0.elapsed().as_secs_f64();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = tiny_model(51);
        let a = generate_greedy(&m, b"hello wor", 8);
        let b = generate_greedy(&m, b"hello wor", 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn context_window_is_respected() {
        let m = tiny_model(52);
        // prompt longer than max_seq must not panic
        let long: Vec<u8> = (0..100).map(|i| (i % 250) as u8).collect();
        let out = generate_greedy(&m, &long, 4);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn kv_cached_generation_matches_full_recompute() {
        // parity including the sliding-window regime: tiny max_seq is 32,
        // so 28 prompt tokens + 12 new tokens crosses the window edge
        let m = tiny_model(56);
        let prompt: Vec<u8> = (0..28).map(|i| (i * 13 + 7) as u8).collect();
        let cached = generate_greedy(&m, &prompt, 12);
        let full = generate_greedy_full(&m, &prompt, 12);
        assert_eq!(cached, full);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 2.5); // the seed returned 3.0 here
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 95.0) - 3.85).abs() < 1e-12);
        let odd = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&odd, 50.0), 3.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // regression: the partial_cmp().unwrap() sort panicked on any NaN
        // latency sample; total order puts NaN in the top tail instead
        let v = [0.3, f64::NAN, 0.1, 0.2];
        let p50 = percentile(&v, 50.0);
        assert!(p50.is_finite(), "p50 must not panic or go NaN mid-distribution");
        assert!((p50 - 0.25).abs() < 1e-12, "sorted finite prefix drives p50, got {p50}");
        assert_eq!(percentile(&v, 0.0), 0.1);
        // the NaN is confined to the extreme tail under total order
        assert!(percentile(&v, 100.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan()); // still no panic
    }

    #[test]
    fn batcher_completes_all_and_preserves_ids() {
        let m = tiny_model(53);
        let backend = ServeBackend::Dense(m);
        let mut b = ContinuousBatcher::new(2);
        for id in 0..5 {
            b.submit(GenRequest { id, prompt: vec![65 + id as u8; 4], max_new_tokens: 2 });
        }
        let mut done = Vec::new();
        while b.pending() > 0 {
            done.extend(b.step(&backend).into_iter().map(|r| r.id));
        }
        // equal-length requests on a FIFO admission: completion keeps order
        assert_eq!(done, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn mid_stream_admission_and_isolation() {
        // a short request admitted mid-generation must complete before a
        // long one that started earlier, and every output must equal the
        // request's isolated generation (no cross-sequence contamination)
        let m = tiny_model(57);
        let backend = ServeBackend::Dense(m.clone());
        let mut b = ContinuousBatcher::new(2);
        b.submit(GenRequest { id: 0, prompt: b"abcd".to_vec(), max_new_tokens: 3 });
        b.submit(GenRequest { id: 1, prompt: b"efgh".to_vec(), max_new_tokens: 10 });
        // one step: both slots busy, then a short request arrives
        assert!(b.step(&backend).is_empty());
        b.submit(GenRequest { id: 2, prompt: b"ijkl".to_vec(), max_new_tokens: 2 });
        assert_eq!(b.queued(), 1);
        assert_eq!(b.active_count(), 2);
        let mut completions = Vec::new();
        let mut responses = Vec::new();
        while b.pending() > 0 {
            for r in b.step(&backend) {
                completions.push(r.id);
                responses.push(r);
            }
        }
        // id 2 enters the slot id 0 frees and, being short, overtakes the
        // still-running id 1 — the seed's FIFO batcher could not do this
        assert_eq!(completions, vec![0, 2, 1]);
        for r in &responses {
            let prompt: &[u8] = match r.id {
                0 => b"abcd",
                1 => b"efgh",
                _ => b"ijkl",
            };
            let isolated = generate_greedy(&m, prompt, r.output.len());
            assert_eq!(r.output, isolated, "request {} contaminated", r.id);
        }
    }

    #[test]
    fn stats_accumulate() {
        let m = tiny_model(54);
        let backend = ServeBackend::Dense(m);
        let mut b = ContinuousBatcher::new(3);
        for id in 0..4 {
            b.submit(GenRequest { id, prompt: b"abc".to_vec(), max_new_tokens: 3 });
        }
        let stats = b.run_to_completion(&backend);
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.total_tokens, 12);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.p50_latency() >= 0.0);
        assert!(stats.p95_latency() >= stats.p50_latency());
        assert!(stats.p99_latency() >= stats.p95_latency());
    }

    fn quantized_container(m: &Model) -> (Model, VqModel) {
        use crate::coordinator::{quantize_model, Method, PipelineConfig};
        use crate::data::tokens::synthetic_stream;
        use crate::quant::gptvq::GptvqConfig;
        let template = m.clone();
        let mut qm = m.clone();
        let s = synthetic_stream(4_000, 1);
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 5;
        g.update_iters = 2;
        g.group_size = 256;
        let mut cfg = PipelineConfig::new(Method::Gptvq(g));
        cfg.calib_sequences = 2;
        cfg.calib_seq_len = 16;
        let rep = quantize_model(&mut qm, &s, &cfg).unwrap();
        (template, rep.vq_model.unwrap())
    }

    #[test]
    fn container_roundtrip_model() {
        use crate::coordinator::{quantize_model, Method, PipelineConfig};
        use crate::data::tokens::synthetic_stream;
        use crate::quant::gptvq::GptvqConfig;
        let mut m = tiny_model(55);
        let template = m.clone();
        let s = synthetic_stream(4_000, 1);
        let mut g = GptvqConfig::for_setting(2, 2, 0.25);
        g.em_iters = 5;
        g.update_iters = 2;
        g.group_size = 256;
        let mut cfg = PipelineConfig::new(Method::Gptvq(g));
        cfg.calib_sequences = 2;
        cfg.calib_seq_len = 16;
        let rep = quantize_model(&mut m, &s, &cfg).unwrap();
        let vq = rep.vq_model.unwrap();
        let served = model_from_container(&template, &vq).unwrap();
        // served model linears equal the quantized model's
        for kind in LinearKind::ALL {
            let a = served.linear(0, kind);
            let b = m.linear(0, kind);
            let diff = a.sub(b).max_abs();
            assert!(diff < 1e-5, "{kind:?}: {diff}");
        }
    }

    #[test]
    fn fused_backend_logits_match_dense_backend() {
        // acceptance: the fused-VQ backend produces logits matching the
        // dense backend within 1e-5 without materializing dense weights
        let m = tiny_model(58);
        let (template, vq) = quantized_container(&m);
        let dense = ServeBackend::dense_from_container(&template, &vq).unwrap();
        let fused = ServeBackend::fused(&template, vq);
        let toks: Vec<u8> = (0..12).map(|i| (i * 11 + 5) as u8).collect();
        let mut cd = KvCache::new(&dense.model().cfg);
        let ld = forward_logits_cached_with(dense.model(), &dense, &mut cd, &toks);
        let mut cf = KvCache::new(&fused.model().cfg);
        let lf = forward_logits_cached_with(fused.model(), &fused, &mut cf, &toks);
        let mut max_abs = 0.0f64;
        for (a, b) in ld.as_slice().iter().zip(lf.as_slice()) {
            max_abs = max_abs.max((a - b).abs());
        }
        assert!(max_abs < 1e-5, "backend divergence {max_abs}");
    }

    #[test]
    fn fused_backend_serves_via_batcher() {
        let m = tiny_model(59);
        let (template, vq) = quantized_container(&m);
        let packed = vq.linears.values().map(|l| l.packed_bytes()).sum::<usize>();
        let fused = ServeBackend::fused(&template, vq);
        assert_eq!(fused.name(), "fused-vq");
        assert_eq!(fused.payload_bytes(), packed);
        // the dense copy of a container-covered linear was dropped
        assert!(fused.model().layers[0].wq.is_empty(), "dense copy retained");
        let mut b = ContinuousBatcher::new(2);
        for id in 0..3 {
            b.submit(GenRequest { id, prompt: b"serve".to_vec(), max_new_tokens: 3 });
        }
        let stats = b.run_to_completion(&fused);
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.total_tokens, 9);
    }
}
