//! Pluggable scheduling policies: admission into decode slots and
//! per-step slot allocation.
//!
//! A [`Scheduler`] makes two decisions for the [`Engine`]:
//!
//! 1. **Admission** ([`Scheduler::admit`]): when a decode slot frees up,
//!    which queued request takes it.
//! 2. **Allocation** ([`Scheduler::allocate`]): when the per-step decode
//!    budget is smaller than the number of active slots, which slots
//!    advance this step.
//!
//! **Determinism rule**: schedulers reorder *work*, never *tokens*. Every
//! request decodes greedily over its own isolated context, so any
//! admission/allocation order produces bitwise-identical output tokens
//! per request — policies change wall time, queue waits, and completion
//! order only. This is asserted by the engine's scheduler tests.
//!
//! [`Engine`]: crate::serve::Engine

/// A queued request, as visible to admission decisions.
#[derive(Debug, Clone, Copy)]
pub struct QueuedView {
    /// caller-chosen request id
    pub id: u64,
    /// engine-assigned monotone arrival number (FIFO tie-break key)
    pub arrival: u64,
    /// prompt length in tokens
    pub prompt_len: usize,
    /// requested decode budget
    pub max_new: usize,
    /// engine steps this request has waited in the queue
    pub waited_steps: usize,
    /// pages neither allocated nor reserved in the engine's shared
    /// paged-KV arena at the start of this admission round, or
    /// `usize::MAX` when the engine runs contiguous (non-pooled) caches
    /// or an unbounded arena. Informational: the engine itself reserves
    /// pages per admission, so a scheduler may use this to defer large
    /// requests under page pressure but never needs to account pages.
    pub free_pages: usize,
}

/// An active decode slot, as visible to per-step allocation.
#[derive(Debug, Clone, Copy)]
pub struct SlotView {
    /// caller-chosen request id
    pub id: u64,
    /// engine-assigned monotone arrival number
    pub arrival: u64,
    /// tokens generated so far
    pub generated: usize,
    /// tokens still to generate
    pub remaining: usize,
    /// consecutive steps this slot was not allocated
    pub idle_steps: usize,
    /// prompt tokens not yet prefilled into the KV cache (non-zero only
    /// while chunked prefill is admitting a long prompt in slices).
    /// Informational: a prefilling slot still charges one allocation and
    /// its chunk charges the step budget like a decode.
    pub prefill_pending: usize,
    /// free pages in the engine's shared paged-KV arena at allocation
    /// time (`usize::MAX` = non-pooled or unbounded; see
    /// [`QueuedView::free_pages`])
    pub free_pages: usize,
}

/// Any slot or queued request left unserved for this many consecutive
/// engine steps is scheduled ahead of policy order — the aging bound that
/// keeps [`ShortestRemaining`] starvation-free under adversarial
/// short-request floods.
pub const STARVATION_AGE: usize = 8;

/// Admission + per-step slot allocation policy (see the module docs for
/// the two decision points and the determinism rule).
pub trait Scheduler {
    /// Policy name, as shown by `--policy` and the bench ladder.
    fn name(&self) -> &'static str;

    /// Pick the index (into `queue`) of the next request to admit into a
    /// free decode slot. Called repeatedly while free slots remain, each
    /// call seeing the queue view with already-admitted entries removed;
    /// returning `None` leaves the remaining slots empty this step.
    /// Deferring is only allowed while other slots are decoding: with
    /// **zero** active slots and a non-empty queue a scheduler must
    /// admit, because an idle engine cannot make progress any other way.
    /// Violations (deferring from idle, or an out-of-range index)
    /// surface as recoverable typed errors from `Engine::step`
    /// ([`StepError::AdmissionStalled`] / [`StepError::BadQueueIndex`])
    /// — a buggy external policy cannot panic the serving process, and
    /// serving resumes after `Engine::set_scheduler` or `Engine::cancel`.
    ///
    /// [`StepError::AdmissionStalled`]: crate::serve::StepError::AdmissionStalled
    /// [`StepError::BadQueueIndex`]: crate::serve::StepError::BadQueueIndex
    fn admit(&mut self, queue: &[QueuedView]) -> Option<usize>;

    /// Choose which active slots decode this step: at most `budget`
    /// indices into `slots`. The engine advances the chosen slots in
    /// ascending slot order regardless of the returned order, so order
    /// only expresses priority when truncating. Slots paused by sink
    /// backpressure may be chosen but are silently skipped — their
    /// allocation is forfeited for the step, never reassigned. The
    /// matching progress contract (with active slots, something must
    /// advance, retire, or be legitimately blocked) is likewise a typed
    /// error ([`StepError::AllocationStalled`] /
    /// [`StepError::BadSlotIndex`] / [`StepError::OverBudget`]).
    ///
    /// [`StepError::AllocationStalled`]: crate::serve::StepError::AllocationStalled
    /// [`StepError::BadSlotIndex`]: crate::serve::StepError::BadSlotIndex
    /// [`StepError::OverBudget`]: crate::serve::StepError::OverBudget
    fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize>;
}

// ---------------------------------------------------------------------------

/// First-in-first-out: admit in arrival order, advance every slot (up to
/// the budget) in admission order. Reproduces the legacy
/// `ContinuousBatcher` schedule bit-for-bit when the step budget covers
/// all slots (the default).
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl Fifo {
    /// New FIFO scheduler.
    pub fn new() -> Fifo {
        Fifo
    }
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit(&mut self, queue: &[QueuedView]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
        (0..slots.len().min(budget)).collect()
    }
}

// ---------------------------------------------------------------------------

/// Fair-share round-robin: admission stays FIFO, but when the per-step
/// budget is smaller than the active set, the *least recently served*
/// slots decode first (ties by arrival). This is round-robin that stays
/// fair across slot churn — a slot's `idle_steps` grows until it tops the
/// order, so every slot decodes at least once every
/// `ceil(active / budget)` steps and none starves.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin;

impl RoundRobin {
    /// New round-robin scheduler.
    pub fn new() -> RoundRobin {
        RoundRobin
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn admit(&mut self, queue: &[QueuedView]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..slots.len()).collect();
        idx.sort_by_key(|&i| (std::cmp::Reverse(slots[i].idle_steps), slots[i].arrival));
        idx.truncate(budget.min(slots.len()));
        idx
    }
}

// ---------------------------------------------------------------------------

/// Shortest-remaining-first: admit the queued request with the smallest
/// decode budget and allocate slots with the fewest remaining tokens
/// first, so short requests retire early and stop inflating the p99 tail
/// behind long ones. Pure SRPT starves long work under a flood of short
/// requests, so both decisions age: anything unserved for
/// [`STARVATION_AGE`] consecutive steps jumps to the head of the order
/// (oldest arrival first).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestRemaining;

impl ShortestRemaining {
    /// New shortest-remaining scheduler.
    pub fn new() -> ShortestRemaining {
        ShortestRemaining
    }
}

impl Scheduler for ShortestRemaining {
    fn name(&self) -> &'static str {
        "shortest-remaining"
    }

    fn admit(&mut self, queue: &[QueuedView]) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        // aged requests pre-empt the shortest-first order
        if let Some((i, _)) = queue
            .iter()
            .enumerate()
            .filter(|(_, q)| q.waited_steps >= STARVATION_AGE)
            .min_by_key(|(_, q)| q.arrival)
        {
            return Some(i);
        }
        queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| (q.max_new, q.arrival))
            .map(|(i, _)| i)
    }

    fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..slots.len()).collect();
        idx.sort_by_key(|&i| {
            let s = &slots[i];
            if s.idle_steps >= STARVATION_AGE {
                // aged slots first, oldest arrival first — ordering aged
                // slots by remaining instead would let aged shorts keep
                // starving an aged long request whenever more than the
                // budget's worth of slots age at once
                (0u8, s.arrival, 0u64)
            } else {
                (1u8, s.remaining as u64, s.arrival)
            }
        });
        idx.truncate(budget.min(slots.len()));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, arrival: u64, max_new: usize, waited: usize) -> QueuedView {
        QueuedView {
            id,
            arrival,
            prompt_len: 4,
            max_new,
            waited_steps: waited,
            free_pages: usize::MAX,
        }
    }

    fn s(id: u64, arrival: u64, remaining: usize, idle: usize) -> SlotView {
        SlotView {
            id,
            arrival,
            generated: 0,
            remaining,
            idle_steps: idle,
            prefill_pending: 0,
            free_pages: usize::MAX,
        }
    }

    #[test]
    fn fifo_admits_head_and_allocates_in_order() {
        let mut f = Fifo::new();
        assert_eq!(f.admit(&[]), None);
        assert_eq!(f.admit(&[q(7, 0, 10, 0), q(8, 1, 2, 0)]), Some(0));
        let slots = [s(1, 0, 5, 0), s(2, 1, 5, 0), s(3, 2, 5, 0)];
        assert_eq!(f.allocate(&slots, 3), vec![0, 1, 2]);
        assert_eq!(f.allocate(&slots, 2), vec![0, 1]);
    }

    #[test]
    fn round_robin_serves_least_recently_served() {
        let mut rr = RoundRobin::new();
        // slot 1 has waited longest; with budget 1 it must win
        let slots = [s(1, 0, 5, 1), s(2, 1, 5, 3), s(3, 2, 5, 0)];
        assert_eq!(rr.allocate(&slots, 1), vec![1]);
        // full budget covers everyone
        let mut all = rr.allocate(&slots, 8);
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn round_robin_budget_rotation_covers_all_slots() {
        // simulate the engine's idle bookkeeping: with budget 1 over 3
        // slots, every slot is served exactly once per 3 steps
        let mut rr = RoundRobin::new();
        let mut idle = [0usize; 3];
        let mut served = [0usize; 3];
        for _ in 0..9 {
            let views: Vec<SlotView> =
                (0..3).map(|i| s(i as u64, i as u64, 5, idle[i])).collect();
            let chosen = rr.allocate(&views, 1);
            assert_eq!(chosen.len(), 1);
            for (i, it) in idle.iter_mut().enumerate() {
                if i == chosen[0] {
                    *it = 0;
                    served[i] += 1;
                } else {
                    *it += 1;
                }
            }
        }
        assert_eq!(served, [3, 3, 3], "round-robin must share the budget evenly");
    }

    #[test]
    fn shortest_remaining_prefers_short_but_ages() {
        let mut sr = ShortestRemaining::new();
        // admission: shortest max_new first
        assert_eq!(sr.admit(&[q(1, 0, 100, 0), q(2, 1, 4, 0)]), Some(1));
        // arrival breaks ties
        assert_eq!(sr.admit(&[q(1, 5, 4, 0), q(2, 1, 4, 0)]), Some(1));
        // an aged long request overtakes fresh short ones
        assert_eq!(sr.admit(&[q(1, 0, 100, STARVATION_AGE), q(2, 9, 1, 0)]), Some(0));
        // allocation: fewest remaining first, aged slots pre-empt
        let slots = [s(1, 0, 50, 0), s(2, 1, 2, 0), s(3, 2, 9, STARVATION_AGE)];
        assert_eq!(sr.allocate(&slots, 2), vec![2, 1]);
    }

    #[test]
    fn aged_allocation_is_oldest_first_not_shortest() {
        // regression: when several slots age at once, the oldest arrival
        // must win regardless of remaining — ordering the aged bucket by
        // remaining would let aged shorts starve an aged long request
        // whenever more slots age per step than the budget covers
        let mut sr = ShortestRemaining::new();
        let slots = [
            s(1, 5, 2, STARVATION_AGE),     // aged short, newer
            s(2, 0, 100, STARVATION_AGE),   // aged long, oldest arrival
            s(3, 3, 4, STARVATION_AGE + 2), // aged short
        ];
        assert_eq!(sr.allocate(&slots, 1), vec![1], "aged long (oldest) must decode first");
        assert_eq!(sr.allocate(&slots, 2), vec![1, 2]);
    }
}
