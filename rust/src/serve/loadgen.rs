//! Open-loop traffic generation: the load the Engine actually faces.
//!
//! The runtime bench historically drove *closed-loop* traffic — submit a
//! fixed batch, drain it, repeat — which can never overload the engine:
//! a slow server slows its own clients. Real traffic is **open-loop**:
//! arrivals keep coming at their own rate whether or not the server
//! keeps up, so queues grow, deadlines blow, and overload control gets
//! exercised. This module generates that traffic deterministically:
//!
//! - **Poisson arrivals** at a configurable per-step rate, with
//!   periodic **burst phases** multiplying the rate (the flash-crowd
//!   pattern that breaks moving-average provisioning),
//! - **heavy-tailed lengths** — log-normal or bounded-Pareto prompt and
//!   output sizes, because production length distributions have tails
//!   that uniform sampling never probes,
//! - everything derived from one [`Rng`] seed and scheduled in
//!   **engine-step time**, so a (seed, config) pair maps to exactly one
//!   arrival sequence and identically-seeded runs are bitwise
//!   reproducible end to end.
//!
//! [`generate`] materializes the arrival schedule; [`run_open_loop`]
//! replays it against an [`Engine`], submitting each request at its
//! arrival step (shed requests are counted, not retried) and folding
//! every terminal response into a [`ServeStats`] report.

use std::time::Instant;

use crate::error::Result;
use crate::serve::engine::{Engine, GenRequest, Rejected, SubmitOutcome};
use crate::serve::stats::ServeStats;
use crate::util::Rng;

/// A request-length distribution. Both variants are sampled, rounded,
/// and clamped into the caller's `[min, max]` bounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    /// Log-normal: `exp(mu + sigma·N(0,1))`. `mu` is the log of the
    /// median length; `sigma` controls tail weight.
    LogNormal {
        /// natural log of the median length
        mu: f64,
        /// log-domain standard deviation (tail weight)
        sigma: f64,
    },
    /// Bounded Pareto on `[min, max]` via inverse-CDF: the classic
    /// heavy tail (smaller `alpha` = heavier tail, more huge requests).
    Pareto {
        /// tail exponent (`1.0..=3.0` is the interesting range)
        alpha: f64,
    },
}

impl LengthDist {
    /// Draw one length in `[min, max]` (inclusive), `min >= 1`.
    fn sample(&self, rng: &mut Rng, min: usize, max: usize) -> usize {
        let lo = min.max(1) as f64;
        let hi = max.max(min.max(1)) as f64;
        let x = match *self {
            LengthDist::LogNormal { mu, sigma } => (mu + sigma * rng.gaussian()).exp(),
            LengthDist::Pareto { alpha } => {
                // inverse CDF of the Pareto truncated to [lo, hi]:
                // x = lo·(1 − u·A)^(−1/α), A = 1 − (lo/hi)^α
                let a = 1.0 - (lo / hi).powf(alpha);
                let u = rng.uniform();
                lo * (1.0 - u * a).powf(-1.0 / alpha)
            }
        };
        (x.round() as usize).clamp(min.max(1), max.max(min.max(1)))
    }
}

/// Configuration for the open-loop generator. The [`Default`] profile is
/// a modest heavy-tailed workload sized for the tiny test models; bench
/// ladders scale `rate` to sweep offered load across capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadGenConfig {
    /// RNG seed — the (seed, config) pair fully determines the traffic
    pub seed: u64,
    /// mean arrivals per engine step outside bursts (Poisson λ)
    pub rate: f64,
    /// total requests to generate
    pub requests: usize,
    /// prompt-length distribution
    pub prompt_dist: LengthDist,
    /// prompt-length lower bound (≥ 1: the byte LM rejects empty prompts)
    pub prompt_min: usize,
    /// prompt-length upper bound
    pub prompt_max: usize,
    /// output-budget distribution
    pub output_dist: LengthDist,
    /// output-budget lower bound
    pub output_min: usize,
    /// output-budget upper bound
    pub output_max: usize,
    /// burst cycle length in steps (`0` disables bursts)
    pub burst_every: u64,
    /// steps of elevated rate at the start of each cycle
    pub burst_len: u64,
    /// rate multiplier during a burst phase
    pub burst_mult: f64,
    /// step-count deadline stamped on every request (`0` = none)
    pub deadline_steps: usize,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            seed: 7,
            rate: 0.5,
            requests: 64,
            // median 12-byte prompts with a fat log-normal tail
            prompt_dist: LengthDist::LogNormal { mu: 2.5, sigma: 0.6 },
            prompt_min: 2,
            prompt_max: 96,
            // bounded-Pareto output budgets: mostly short, a few huge
            output_dist: LengthDist::Pareto { alpha: 1.5 },
            output_min: 2,
            output_max: 48,
            burst_every: 64,
            burst_len: 16,
            burst_mult: 4.0,
            deadline_steps: 0,
        }
    }
}

/// One scheduled arrival: `req` is submitted when the engine clock
/// reaches `step`.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// engine step at which the request arrives
    pub step: u64,
    /// the request itself (id = arrival index)
    pub req: GenRequest,
}

/// Draw one Poisson(λ) count (Knuth's product-of-uniforms method —
/// exact, and cheap at the per-step rates the generator uses).
fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.uniform();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Materialize the deterministic arrival schedule for `cfg`: exactly
/// `cfg.requests` arrivals with ids `0..requests`, ordered by
/// (non-decreasing) arrival step.
pub fn generate(cfg: &LoadGenConfig) -> Vec<Arrival> {
    assert!(cfg.rate > 0.0, "loadgen rate must be positive");
    let mut rng = Rng::new(cfg.seed ^ 0x6c6f_6164_6765_6e21);
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut step = 0u64;
    while arrivals.len() < cfg.requests {
        let bursting =
            cfg.burst_every > 0 && cfg.burst_len > 0 && step % cfg.burst_every < cfg.burst_len;
        let lambda = if bursting { cfg.rate * cfg.burst_mult } else { cfg.rate };
        let n = poisson(&mut rng, lambda);
        for _ in 0..n {
            if arrivals.len() >= cfg.requests {
                break;
            }
            let plen = cfg.prompt_dist.sample(&mut rng, cfg.prompt_min, cfg.prompt_max);
            let olen = cfg.output_dist.sample(&mut rng, cfg.output_min, cfg.output_max);
            let prompt: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
            let req = GenRequest::new(arrivals.len() as u64, prompt, olen)
                .with_deadline_steps(cfg.deadline_steps);
            arrivals.push(Arrival { step, req });
        }
        step += 1;
    }
    arrivals
}

/// Offered load of a schedule in tokens per step: total requested
/// output budget over the span of arrival steps. Compare against the
/// engine's decode capacity (≈ `max_batch` tokens/step under one-token
/// decode) to place a run on the overload ladder.
pub fn offered_tokens_per_step(arrivals: &[Arrival]) -> f64 {
    if arrivals.is_empty() {
        return 0.0;
    }
    let total: usize = arrivals.iter().map(|a| a.req.max_new_tokens).sum();
    let span = arrivals.last().expect("non-empty").step + 1;
    total as f64 / span as f64
}

/// Replay an arrival schedule open-loop against `engine`: each request
/// is submitted when the engine clock reaches its arrival step — never
/// earlier, never retried — shed submissions are counted in
/// [`ServeStats::shed`], and the engine is stepped until every admitted
/// request terminally resolves. Returns the aggregate report (goodput,
/// SLO inputs, shed/expired/cancelled counters included).
///
/// Determinism: arrival steps, admission decisions, deadlines, and all
/// token output depend only on (schedule, engine config); wall-clock
/// enters the report solely through the `total_seconds` field.
pub fn run_open_loop(engine: &mut Engine, arrivals: &[Arrival]) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let c0 = engine.core_ref().counters();
    let clock0 = engine.steps_elapsed();
    // detlint: allow(wall-clock, tokens_per_second reporting only; every scheduling/shedding decision is in deterministic step-time)
    let t0 = Instant::now();
    let mut next = 0usize;
    while next < arrivals.len() || engine.pending() > 0 {
        let now = engine.steps_elapsed();
        while next < arrivals.len() && arrivals[next].step <= now {
            match engine.try_submit(arrivals[next].req.clone())? {
                SubmitOutcome::Admitted(_) => {}
                SubmitOutcome::Rejected(r) => {
                    stats.shed += 1;
                    // split out the page-domain sheds so the KV-pressure
                    // ladder can assert a monotone KvExhausted fraction
                    if matches!(r, Rejected::KvExhausted { .. }) {
                        stats.shed_kv += 1;
                    }
                }
            }
            next += 1;
        }
        for resp in engine.step()? {
            stats.record(&resp);
        }
    }
    stats.total_seconds = t0.elapsed().as_secs_f64();
    stats.clock_steps = (engine.steps_elapsed() - clock0) as usize;
    let c1 = engine.core_ref().counters();
    stats.engine_steps = c1[0] - c0[0];
    stats.decode_calls = c1[1] - c0[1];
    stats.decoded_tokens = c1[2] - c0[2];
    stats.prefill_chunks = c1[3] - c0[3];
    stats.spec_drafted = c1[4] - c0[4];
    stats.spec_accepted = c1[5] - c0[5];
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_well_formed() {
        let cfg = LoadGenConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), cfg.requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.step, y.step);
            assert_eq!(x.req.id, y.req.id);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
        // ids are the arrival index; steps never decrease; bounds hold
        let mut last = 0u64;
        for (i, arr) in a.iter().enumerate() {
            assert_eq!(arr.req.id, i as u64);
            assert!(arr.step >= last, "arrival steps must be sorted");
            last = arr.step;
            assert!((cfg.prompt_min..=cfg.prompt_max).contains(&arr.req.prompt.len()));
            assert!(
                (cfg.output_min..=cfg.output_max).contains(&arr.req.max_new_tokens)
            );
            assert_eq!(arr.req.deadline_steps, 0);
        }
        // a different seed genuinely changes the traffic
        let c = generate(&LoadGenConfig { seed: 8, ..cfg.clone() });
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.req.prompt != y.req.prompt || x.step != y.step),
            "seed change left the schedule identical"
        );
    }

    #[test]
    fn poisson_rate_and_burst_phases_shape_the_arrivals() {
        // flat Poisson at λ=2: mean inter-step arrivals ≈ 2 over a long run
        let cfg = LoadGenConfig {
            rate: 2.0,
            requests: 2000,
            burst_every: 0,
            ..LoadGenConfig::default()
        };
        let a = generate(&cfg);
        let span = a.last().unwrap().step + 1;
        let per_step = a.len() as f64 / span as f64;
        assert!(
            (per_step - 2.0).abs() < 0.25,
            "Poisson(2) arrivals averaged {per_step}/step"
        );

        // bursts: the first burst_len steps of each cycle must carry a
        // higher arrival rate than the tail of the cycle
        let cfg = LoadGenConfig {
            rate: 1.0,
            requests: 4000,
            burst_every: 32,
            burst_len: 8,
            burst_mult: 5.0,
            ..LoadGenConfig::default()
        };
        let a = generate(&cfg);
        let (mut burst_n, mut calm_n, mut burst_steps, mut calm_steps) = (0usize, 0usize, 0u64, 0u64);
        let span = a.last().unwrap().step + 1;
        for s in 0..span {
            if s % 32 < 8 {
                burst_steps += 1;
            } else {
                calm_steps += 1;
            }
        }
        for arr in &a {
            if arr.step % 32 < 8 {
                burst_n += 1;
            } else {
                calm_n += 1;
            }
        }
        let burst_rate = burst_n as f64 / burst_steps as f64;
        let calm_rate = calm_n as f64 / calm_steps.max(1) as f64;
        assert!(
            burst_rate > 2.5 * calm_rate,
            "burst phases not visible: {burst_rate:.2} vs {calm_rate:.2} arrivals/step"
        );
    }

    #[test]
    fn heavy_tails_are_actually_heavy() {
        // bounded Pareto α=1.2 on [2, 400]: the max sample must land far
        // above the median — a uniform or normal draw would not
        let mut rng = Rng::new(11);
        let dist = LengthDist::Pareto { alpha: 1.2 };
        let mut v: Vec<usize> = (0..4000).map(|_| dist.sample(&mut rng, 2, 400)).collect();
        v.sort_unstable();
        let median = v[v.len() / 2];
        let max = *v.last().unwrap();
        assert!(v[0] >= 2 && max <= 400, "bounds violated");
        assert!(median <= 8, "Pareto α=1.2 median should hug the minimum, got {median}");
        assert!(max >= 40 * median, "tail too light: median {median}, max {max}");

        // log-normal: median ≈ exp(mu), tail well beyond it
        let dist = LengthDist::LogNormal { mu: 3.0, sigma: 0.8 };
        let mut v: Vec<usize> = (0..4000).map(|_| dist.sample(&mut rng, 1, 10_000)).collect();
        v.sort_unstable();
        let median = v[v.len() / 2] as f64;
        assert!((median - 3.0f64.exp()).abs() < 6.0, "log-normal median drifted: {median}");
        assert!(*v.last().unwrap() as f64 > 4.0 * median, "log-normal tail too light");
    }

    #[test]
    fn offered_load_scales_with_rate() {
        let base = LoadGenConfig { requests: 400, burst_every: 0, ..LoadGenConfig::default() };
        let lo = offered_tokens_per_step(&generate(&base));
        let hi = offered_tokens_per_step(&generate(&LoadGenConfig {
            rate: base.rate * 4.0,
            ..base.clone()
        }));
        assert!(lo > 0.0);
        assert!(
            hi > 2.5 * lo,
            "4× arrival rate should near-4× offered tokens/step ({lo:.2} → {hi:.2})"
        );
    }
}
