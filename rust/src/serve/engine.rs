//! The serving engine: sessions, decode slots, admission, and stepping.
//!
//! [`Engine`] owns a [`ServeBackend`] plus two trait-based extension
//! points — a [`Scheduler`] (admission + per-step slot allocation) and a
//! [`DecodePolicy`] (tokens emitted per slot per step). One
//! [`Engine::step`] runs the continuous-batching cycle:
//!
//! 1. admit queued requests into free decode slots (scheduler order),
//! 2. advance the allocated slots — by default through ONE cross-slot
//!    ragged batched forward ([`StepMode::Batched`]); the PR 5 loop of
//!    one forward per slot survives as [`StepMode::PerSlot`], the
//!    reference the batched step is pinned token-identical against,
//! 3. retire finished sequences in admission order (single in-place
//!    retain pass).
//!
//! Long prompts can prefill in chunks ([`Engine::with_prefill_chunk`]):
//! a chunked slot forwards at most `chunk` prompt tokens per step,
//! growing its KV cache incrementally instead of monopolizing a step,
//! and each chunk charges the scheduler's step budget like a decode.
//! Chunking changes step counts (TTFT), never tokens.
//!
//! [`Engine::submit`] returns a [`Session`] handle that exposes streamed
//! tokens (optionally through a [`TokenSink`] callback), per-request
//! time-to-first-token and queue wait (wall-clock and deterministic
//! step counts), and the final [`GenResponse`]; [`Engine::cancel`]
//! retires a request early, freeing its slot and KV immediately.
//! The deprecated `ContinuousBatcher` and `generate_greedy*` free
//! functions in [`crate::serve`] are thin shims over the same core, so
//! their behavior is reproduced bit-for-bit by an engine with the
//! default [`Fifo`] + [`OneToken`] configuration in per-slot mode.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::model::forward::{
    forward_logits_batched_with, forward_logits_cached_with, BatchItem, LinearApply,
};
use crate::model::kv::KvCache;
use crate::model::{Model, ModelConfig};
use crate::serve::decode::{argmax_logits, BatchPlan, DecodePolicy, DraftState, OneToken};
use crate::serve::scheduler::{Fifo, QueuedView, Scheduler, SlotView};
use crate::serve::stats::ServeStats;
use crate::serve::ServeBackend;
use crate::tensor::Matrix;

// ---------------------------------------------------------------------------
// requests and responses

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// caller-chosen request id, echoed in the response
    pub id: u64,
    /// prompt bytes (the model is a byte LM)
    pub prompt: Vec<u8>,
    /// decode budget after the prompt
    pub max_new_tokens: usize,
}

/// Completed request with timing.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// id of the originating request
    pub id: u64,
    /// generated tokens (beyond the prompt)
    pub output: Vec<u8>,
    /// submit-to-retire wall-clock seconds
    pub latency_s: f64,
    /// tokens generated beyond the prompt
    pub tokens_generated: usize,
    /// submit-to-first-generated-token wall-clock seconds; equals
    /// `latency_s` for a request that generated no tokens (such
    /// responses are excluded from the [`ServeStats`] TTFT percentiles)
    pub ttft_s: f64,
    /// submit-to-admission wall-clock seconds (time queued for a slot)
    pub queue_wait_s: f64,
    /// engine steps from submit through the step that emitted the first
    /// token — the deterministic counterpart of `ttft_s` (step counts
    /// depend only on workload shape and configuration, never timing);
    /// for a request that generated nothing, the steps from submit to
    /// retirement. Chunked prefill raises this by the number of extra
    /// prefill steps.
    pub ttft_steps: usize,
    /// engine steps spent queued before admission — the deterministic
    /// counterpart of `queue_wait_s`
    pub queue_wait_steps: usize,
}

// ---------------------------------------------------------------------------
// per-sequence decode state

/// Decode state of one sequence: the accepted token stream plus the KV
/// cache over the current context window. The cache is reused as long as
/// the window does not slide; once the context exceeds `max_seq` the
/// window start moves every step and the state degrades to the seed's
/// full-recompute behavior (same logits). Speculative policies keep a
/// second, draft-path cache here as well.
pub struct SeqState {
    pub(crate) tokens: Vec<u8>,
    pub(crate) cache: KvCache,
    pub(crate) window_start: usize,
    pub(crate) max_ctx: usize,
    pub(crate) draft: Option<DraftState>,
}

impl SeqState {
    /// Fresh state over `prompt` (nothing forwarded yet).
    pub fn new(cfg: &ModelConfig, prompt: &[u8]) -> SeqState {
        SeqState {
            tokens: prompt.to_vec(),
            cache: KvCache::new(cfg),
            window_start: 0,
            max_ctx: cfg.max_seq,
            draft: None,
        }
    }

    /// Full accepted token stream (prompt + generated so far).
    pub fn tokens(&self) -> &[u8] {
        &self.tokens
    }

    /// Re-derive the context window start; clears the cache when the
    /// window slid (the cached positions no longer line up).
    pub(crate) fn sync_window(&mut self) {
        let ctx_start = self.tokens.len().saturating_sub(self.max_ctx);
        if ctx_start != self.window_start {
            self.cache.clear();
            self.window_start = ctx_start;
        }
    }

    /// Forward every token of the stream not yet covered by the cache
    /// (at least one) and return their logits rows.
    pub fn forward_pending(&mut self, model: &Model, lin: &impl LinearApply) -> Matrix {
        self.sync_window();
        let new0 = self.window_start + self.cache.len();
        forward_logits_cached_with(model, lin, &mut self.cache, &self.tokens[new0..])
    }

    /// Append one emitted token to the accepted stream. External
    /// [`DecodePolicy`] implementations record their emissions through
    /// this — the engine derives per-slot progress from the stream
    /// length, so every token a policy returns must also be committed.
    pub fn commit_token(&mut self, token: u8) {
        self.tokens.push(token);
    }

    /// Generate one greedy token — the [`OneToken`] step, shared with the
    /// speculative policy's window-edge fallback and the deprecated
    /// `generate_greedy` shim.
    pub fn one_token(&mut self, model: &Model, lin: &impl LinearApply) -> u8 {
        let logits = self.forward_pending(model, lin);
        let next = argmax_logits(logits.row(logits.rows() - 1));
        self.tokens.push(next);
        next
    }
}

// ---------------------------------------------------------------------------
// sessions

/// Callback receiving each generated token of one session as it is
/// emitted — the streaming surface of a [`Session`]. Invoked while the
/// engine holds the session's shared state, so a sink must not call back
/// into [`Session`] methods of its own session (single-threaded
/// re-entrancy guard; it would panic on the interior borrow).
pub type TokenSink = Box<dyn FnMut(u8)>;

/// Per-request state shared between the engine and a [`Session`] handle.
pub(crate) struct SessionShared {
    id: u64,
    streamed: Vec<u8>,
    ttft_s: Option<f64>,
    queue_wait_s: Option<f64>,
    ttft_steps: Option<usize>,
    queue_wait_steps: Option<usize>,
    response: Option<GenResponse>,
    sink: Option<TokenSink>,
}

/// Handle to one submitted request: observe streamed tokens, per-request
/// timing, and the final [`GenResponse`] as the engine steps. Handles are
/// single-threaded (`Rc`-shared with the engine) and stay valid after the
/// request completes.
pub struct Session {
    inner: Rc<RefCell<SessionShared>>,
}

impl Session {
    /// The request id this session tracks.
    pub fn id(&self) -> u64 {
        self.inner.borrow().id
    }

    /// Whether the request has retired (final response available).
    pub fn is_finished(&self) -> bool {
        self.inner.borrow().response.is_some()
    }

    /// Snapshot of the tokens streamed so far (beyond the prompt).
    pub fn streamed(&self) -> Vec<u8> {
        self.inner.borrow().streamed.clone()
    }

    /// Submit-to-first-token seconds, once the first token exists.
    pub fn time_to_first_token(&self) -> Option<f64> {
        self.inner.borrow().ttft_s
    }

    /// Submit-to-admission seconds, once the request holds a slot.
    pub fn queue_wait(&self) -> Option<f64> {
        self.inner.borrow().queue_wait_s
    }

    /// Engine steps from submit through the first token's step — the
    /// deterministic TTFT — once the first token exists.
    pub fn time_to_first_token_steps(&self) -> Option<usize> {
        self.inner.borrow().ttft_steps
    }

    /// Engine steps spent queued, once the request holds a slot.
    pub fn queue_wait_steps(&self) -> Option<usize> {
        self.inner.borrow().queue_wait_steps
    }

    /// The final response, once the request retired.
    pub fn response(&self) -> Option<GenResponse> {
        self.inner.borrow().response.clone()
    }
}

// ---------------------------------------------------------------------------
// engine core

struct QueueEntry {
    req: GenRequest,
    arrival: u64,
    enqueued: Instant,
    submit_step: u64,
    session: Rc<RefCell<SessionShared>>,
}

struct Slot {
    id: u64,
    arrival: u64,
    prompt_len: usize,
    max_new: usize,
    enqueued: Instant,
    submit_step: u64,
    queue_wait_s: f64,
    idle_steps: usize,
    seq: SeqState,
    session: Rc<RefCell<SessionShared>>,
}

impl Slot {
    fn generated(&self) -> usize {
        self.seq.tokens.len() - self.prompt_len
    }

    fn remaining(&self) -> usize {
        self.max_new - self.generated()
    }

    /// Prompt tokens of the *initial* context window not yet covered by
    /// the KV cache — the amount chunked prefill still has to forward
    /// before this slot can emit its first token. Zero once the first
    /// token has been generated: the sliding-window regime re-prefills
    /// whole windows inside the decode policy, which must stay a single
    /// per-step forward to keep token identity with unchunked engines.
    fn prefill_pending(&self) -> usize {
        if self.generated() > 0 {
            return 0;
        }
        let ws = self.seq.tokens.len().saturating_sub(self.seq.max_ctx);
        (self.seq.tokens.len() - ws).saturating_sub(self.seq.cache.len())
    }

    /// Stream `toks` to the session, stamping first-token timing (wall
    /// clock and the deterministic step count) on the first emission.
    fn emit(&mut self, toks: &[u8], step_no: u64) {
        let mut sess = self.session.borrow_mut();
        if sess.ttft_s.is_none() && !toks.is_empty() {
            sess.ttft_s = Some(self.enqueued.elapsed().as_secs_f64());
            sess.ttft_steps = Some((step_no - self.submit_step) as usize + 1);
        }
        for &t in toks {
            sess.streamed.push(t);
            if let Some(sink) = sess.sink.as_mut() {
                sink(t);
            }
        }
    }

    /// Build the final response, consuming the token buffer. `step_no`
    /// is the engine's step counter at retirement, the fallback for the
    /// step-count TTFT of requests that never emitted a token.
    fn finish(&mut self, step_no: u64) -> GenResponse {
        let generated = self.generated();
        let latency_s = self.enqueued.elapsed().as_secs_f64();
        let tokens = std::mem::take(&mut self.seq.tokens);
        let sess = self.session.borrow();
        let ttft_s = sess.ttft_s.unwrap_or(latency_s);
        let ttft_steps = sess.ttft_steps.unwrap_or((step_no - self.submit_step) as usize);
        let queue_wait_steps = sess.queue_wait_steps.unwrap_or(0);
        drop(sess);
        GenResponse {
            id: self.id,
            output: tokens[self.prompt_len..].to_vec(),
            latency_s,
            tokens_generated: generated,
            ttft_s,
            queue_wait_s: self.queue_wait_s,
            ttft_steps,
            queue_wait_steps,
        }
    }
}

/// How [`Engine::step`] executes the allocated slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// One forward per slot per step — the PR 5 loop, kept as the
    /// reference the batched mode is pinned token-identical against.
    PerSlot,
    /// ONE ragged batched forward across every staged slot per step (the
    /// default): same tokens, fewer weight passes — a fused-VQ backend
    /// decodes each linear once per step instead of once per slot.
    Batched,
}

/// Backend-agnostic engine internals, shared by [`Engine`] (which owns
/// its backend) and the deprecated `ContinuousBatcher` shim (which
/// borrows one per call).
pub(crate) struct Core {
    pub(crate) max_batch: usize,
    pub(crate) step_budget: usize,
    pub(crate) step_mode: StepMode,
    pub(crate) prefill_chunk: usize,
    pub(crate) scheduler: Box<dyn Scheduler>,
    pub(crate) policy: Box<dyn DecodePolicy>,
    queue: Vec<QueueEntry>,
    active: Vec<Slot>,
    arrivals: u64,
    step_no: u64,
    steps_decoded: usize,
    decode_calls: usize,
    tokens_decoded: usize,
    prefill_chunks: usize,
}

impl Core {
    pub(crate) fn new(
        max_batch: usize,
        scheduler: Box<dyn Scheduler>,
        policy: Box<dyn DecodePolicy>,
    ) -> Core {
        Core {
            max_batch: max_batch.max(1),
            step_budget: 0,
            step_mode: StepMode::Batched,
            prefill_chunk: 0,
            scheduler,
            policy,
            queue: Vec::new(),
            active: Vec::new(),
            arrivals: 0,
            step_no: 0,
            steps_decoded: 0,
            decode_calls: 0,
            tokens_decoded: 0,
            prefill_chunks: 0,
        }
    }

    pub(crate) fn submit(&mut self, req: GenRequest, sink: Option<TokenSink>) -> Result<Session> {
        // reject bad input at submit: an empty prompt would only panic
        // mid-step inside the forward pass, taking every other in-flight
        // request in this engine down with it
        if req.prompt.is_empty() {
            return Err(Error::msg(format!(
                "request {}: empty prompt (the byte LM needs at least one context token)",
                req.id
            )));
        }
        let session = Rc::new(RefCell::new(SessionShared {
            id: req.id,
            streamed: Vec::new(),
            ttft_s: None,
            queue_wait_s: None,
            ttft_steps: None,
            queue_wait_steps: None,
            response: None,
            sink,
        }));
        self.queue.push(QueueEntry {
            req,
            arrival: self.arrivals,
            // detlint: allow(wall-clock, admission timestamp feeds queue-wait percentiles only; scheduling is arrival-order/aging on step counts)
            enqueued: Instant::now(),
            submit_step: self.step_no,
            session: Rc::clone(&session),
        });
        self.arrivals += 1;
        Ok(Session { inner: session })
    }

    pub(crate) fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub(crate) fn queued(&self) -> usize {
        self.queue.len()
    }

    pub(crate) fn active_count(&self) -> usize {
        self.active.len()
    }

    pub(crate) fn step(&mut self, backend: &ServeBackend) -> Vec<GenResponse> {
        // ---- admission: scheduler fills free slots from the queue ----
        // views are built once per step — only when a slot is actually
        // free — and kept aligned with the queue across removals
        // (waited_steps cannot change mid-step), so a backlog costs one
        // pass, not one rebuild per admitted request or per busy step
        let mut views: Vec<QueuedView> = if self.active.len() < self.max_batch {
            self.queue
                .iter()
                .map(|q| QueuedView {
                    id: q.req.id,
                    arrival: q.arrival,
                    prompt_len: q.req.prompt.len(),
                    max_new: q.req.max_new_tokens,
                    waited_steps: (self.step_no - q.submit_step) as usize,
                })
                .collect()
        } else {
            Vec::new()
        };
        while self.active.len() < self.max_batch && !self.queue.is_empty() {
            let Some(i) = self.scheduler.admit(&views) else { break };
            assert!(i < self.queue.len(), "scheduler admitted out-of-range queue index {i}");
            views.remove(i);
            let q = self.queue.remove(i);
            let queue_wait_s = q.enqueued.elapsed().as_secs_f64();
            {
                let mut sess = q.session.borrow_mut();
                sess.queue_wait_s = Some(queue_wait_s);
                sess.queue_wait_steps = Some((self.step_no - q.submit_step) as usize);
            }
            self.active.push(Slot {
                id: q.req.id,
                arrival: q.arrival,
                prompt_len: q.req.prompt.len(),
                max_new: q.req.max_new_tokens,
                enqueued: q.enqueued,
                submit_step: q.submit_step,
                queue_wait_s,
                idle_steps: 0,
                seq: SeqState::new(&backend.model().cfg, &q.req.prompt),
                session: q.session,
            });
        }
        // progress contract: free slots + a non-empty queue must admit
        assert!(
            !self.active.is_empty() || self.queue.is_empty(),
            "scheduler {} stalled: empty slots but {} queued requests",
            self.scheduler.name(),
            self.queue.len()
        );

        // ---- allocation + decode ----
        if !self.active.is_empty() {
            let budget = if self.step_budget == 0 {
                self.active.len()
            } else {
                self.step_budget.min(self.active.len())
            };
            let views: Vec<SlotView> = self
                .active
                .iter()
                .map(|s| SlotView {
                    id: s.id,
                    arrival: s.arrival,
                    generated: s.generated(),
                    remaining: s.remaining(),
                    idle_steps: s.idle_steps,
                    prefill_pending: s.prefill_pending(),
                })
                .collect();
            let mut chosen = self.scheduler.allocate(&views, budget);
            chosen.sort_unstable();
            chosen.dedup();
            assert!(
                chosen.len() <= budget,
                "scheduler {} allocated {} slots over budget {budget}",
                self.scheduler.name(),
                chosen.len()
            );
            let progressed = match self.step_mode {
                StepMode::PerSlot => self.step_per_slot(backend, &chosen),
                StepMode::Batched => self.step_batched(backend, &chosen),
            };
            // progress contract, allocation side: with active slots, the
            // scheduler must either advance something (a token or a
            // prefill chunk) or leave only finished (zero-remaining)
            // slots, which retire below — a policy that allocates
            // nothing would spin forever otherwise
            assert!(
                progressed || self.active.iter().any(|s| s.remaining() == 0),
                "scheduler {} stalled: allocated no decodable slot out of {} active",
                self.scheduler.name(),
                self.active.len()
            );
            // idle accounting feeds round-robin fairness and SRPT aging
            for (i, slot) in self.active.iter_mut().enumerate() {
                if chosen.binary_search(&i).is_ok() {
                    slot.idle_steps = 0;
                } else {
                    slot.idle_steps += 1;
                }
            }
            if progressed {
                self.steps_decoded += 1;
            }
        }
        self.step_no += 1;

        // ---- retirement: one in-place retain pass, admission order ----
        let step_no = self.step_no;
        let mut done = Vec::new();
        self.active.retain_mut(|slot| {
            if slot.generated() < slot.max_new {
                return true;
            }
            let resp = slot.finish(step_no);
            let mut sess = slot.session.borrow_mut();
            sess.response = Some(resp.clone());
            // the sink can never fire again — drop it now so captured
            // state is freed even while the Session handle lives on
            sess.sink = None;
            drop(sess);
            done.push(resp);
            false
        });
        done
    }

    /// The per-slot reference loop: one policy `decode` (one forward)
    /// per allocated slot. A slot still inside chunked prefill forwards
    /// one prompt chunk instead and emits nothing. Returns whether any
    /// slot progressed (a token or a chunk).
    fn step_per_slot(&mut self, backend: &ServeBackend, chosen: &[usize]) -> bool {
        let step_no = self.step_no;
        let prefill_chunk = self.prefill_chunk;
        let Core { policy, active, decode_calls, tokens_decoded, prefill_chunks, .. } = self;
        let mut progressed = false;
        // detlint: hot(engine-step) — per-slot decode dispatch runs every
        // engine step at serving concurrency; keep it allocation-free
        for &i in chosen {
            assert!(i < active.len(), "scheduler allocated out-of-range slot {i}");
            let slot = &mut active[i];
            let remaining = slot.remaining();
            if remaining == 0 {
                continue; // zero-budget request, retires below untouched
            }
            if prefill_chunk > 0 {
                slot.seq.sync_window();
                if slot.prefill_pending() > prefill_chunk {
                    // pure prefill: extend the KV cache by one chunk of
                    // prompt tokens, emit nothing this step
                    let new0 = slot.seq.window_start + slot.seq.cache.len();
                    let chunk = &slot.seq.tokens[new0..new0 + prefill_chunk];
                    forward_logits_cached_with(backend.model(), backend, &mut slot.seq.cache, chunk);
                    *decode_calls += 1;
                    *prefill_chunks += 1;
                    progressed = true;
                    continue;
                }
            }
            let toks = policy.decode(backend, &mut slot.seq, remaining);
            // hard contract (like the scheduler stall asserts): a
            // policy emitting nothing would spin the engine forever
            assert!(
                !toks.is_empty() && toks.len() <= remaining,
                "decode policy {} emitted {} tokens with {remaining} remaining",
                policy.name(),
                toks.len()
            );
            debug_assert_eq!(
                slot.seq.tokens.len() - slot.prompt_len,
                slot.max_new - remaining + toks.len(),
                "decode policy desynced the token stream"
            );
            slot.emit(&toks, step_no);
            *decode_calls += 1;
            *tokens_decoded += toks.len();
            progressed = true;
        }
        // detlint: endhot
        progressed
    }

    /// The batched step: stage every allocated slot (a prefill chunk or
    /// a policy [`BatchPlan`]), run ALL staged inputs through ONE ragged
    /// batched forward — one `decode_call`, one weight pass — then
    /// commit each slot's tokens from its own logit rows. Slots whose
    /// policy opts out of planning fall back to per-slot `decode` calls
    /// after the batch, so external policies keep working. Token
    /// streams are identical to [`Core::step_per_slot`] because the
    /// batched forward computes each item's rows bitwise equal to a
    /// dedicated forward and the policies' plan/finish split is the
    /// same code their `decode` runs. Returns whether any slot
    /// progressed.
    fn step_batched(&mut self, backend: &ServeBackend, chosen: &[usize]) -> bool {
        enum Work {
            /// pure prefill: forward n prompt tokens, emit nothing
            Chunk(usize),
            /// policy-staged forward input, committed via `finish`
            Plan(BatchPlan),
            /// policy opted out of planning: per-slot decode below
            Fallback,
        }
        let step_no = self.step_no;
        let prefill_chunk = self.prefill_chunk;
        let Core { policy, active, decode_calls, tokens_decoded, prefill_chunks, .. } = self;

        // ---- stage: decide per slot what joins the batch (slot order:
        // `chosen` is sorted, so plans run in the same order the
        // per-slot loop would decode) ----
        let mut work: Vec<(usize, Work)> = Vec::with_capacity(chosen.len());
        for &i in chosen {
            assert!(i < active.len(), "scheduler allocated out-of-range slot {i}");
            let slot = &mut active[i];
            let remaining = slot.remaining();
            if remaining == 0 {
                continue; // zero-budget request, retires below untouched
            }
            if prefill_chunk > 0 {
                slot.seq.sync_window();
                if slot.prefill_pending() > prefill_chunk {
                    work.push((i, Work::Chunk(prefill_chunk)));
                    continue;
                }
            }
            match policy.plan(backend, &mut slot.seq, remaining) {
                Some(p) => work.push((i, Work::Plan(p))),
                None => work.push((i, Work::Fallback)),
            }
        }

        // ---- forward: every staged slot's input in ONE ragged batch;
        // item rows line up with `work` order (ascending slot index) ----
        let mut items: Vec<BatchItem<'_>> = Vec::with_capacity(work.len());
        let mut wi = 0;
        for (si, slot) in active.iter_mut().enumerate() {
            if wi >= work.len() {
                break;
            }
            if work[wi].0 != si {
                continue;
            }
            let (_, w) = &work[wi];
            wi += 1;
            let seq = &mut slot.seq;
            match w {
                Work::Chunk(n) => {
                    let new0 = seq.window_start + seq.cache.len();
                    items.push(BatchItem {
                        cache: &mut seq.cache,
                        tokens: &seq.tokens[new0..new0 + n],
                    });
                }
                Work::Plan(p) => {
                    items.push(BatchItem { cache: &mut seq.cache, tokens: &p.input });
                }
                Work::Fallback => {}
            }
        }
        let logits = if items.is_empty() {
            None
        } else {
            *decode_calls += 1;
            Some(forward_logits_batched_with(backend.model(), backend, &mut items))
        };
        drop(items);

        // ---- commit: hand each staged slot its logit rows, in order ----
        let mut progressed = false;
        let mut row0 = 0usize;
        // detlint: hot(engine-step-batched) — the batched commit loop runs
        // every engine step at serving concurrency; keep it allocation-free
        for (i, w) in &work {
            let slot = &mut active[*i];
            let remaining = slot.remaining();
            match w {
                Work::Chunk(n) => {
                    row0 += n;
                    *prefill_chunks += 1;
                    progressed = true;
                }
                Work::Plan(p) => {
                    let l = logits.as_ref().expect("planned slots imply a batched forward");
                    let toks = policy.finish(&mut slot.seq, p, l, row0);
                    row0 += p.input.len();
                    assert!(
                        !toks.is_empty() && toks.len() <= remaining,
                        "decode policy {} emitted {} tokens with {remaining} remaining",
                        policy.name(),
                        toks.len()
                    );
                    debug_assert_eq!(
                        slot.seq.tokens.len() - slot.prompt_len,
                        slot.max_new - remaining + toks.len(),
                        "decode policy desynced the token stream"
                    );
                    slot.emit(&toks, step_no);
                    *tokens_decoded += toks.len();
                    progressed = true;
                }
                Work::Fallback => {
                    let toks = policy.decode(backend, &mut slot.seq, remaining);
                    assert!(
                        !toks.is_empty() && toks.len() <= remaining,
                        "decode policy {} emitted {} tokens with {remaining} remaining",
                        policy.name(),
                        toks.len()
                    );
                    debug_assert_eq!(
                        slot.seq.tokens.len() - slot.prompt_len,
                        slot.max_new - remaining + toks.len(),
                        "decode policy desynced the token stream"
                    );
                    slot.emit(&toks, step_no);
                    *decode_calls += 1;
                    *tokens_decoded += toks.len();
                    progressed = true;
                }
            }
        }
        // detlint: endhot
        progressed
    }

    /// Cancel a request by id. A still-queued request retires with an
    /// empty response; an active one retires immediately with its
    /// partial output, freeing the slot (and its KV caches) this
    /// instant — the next step batches without it. Returns the
    /// response, or `None` for an id that is unknown or already
    /// finished.
    pub(crate) fn cancel(&mut self, id: u64) -> Option<GenResponse> {
        if let Some(qi) = self.queue.iter().position(|q| q.req.id == id) {
            let q = self.queue.remove(qi);
            let latency_s = q.enqueued.elapsed().as_secs_f64();
            let waited = (self.step_no - q.submit_step) as usize;
            let resp = GenResponse {
                id,
                output: Vec::new(),
                latency_s,
                tokens_generated: 0,
                ttft_s: latency_s,
                queue_wait_s: latency_s,
                ttft_steps: waited,
                queue_wait_steps: waited,
            };
            let mut sess = q.session.borrow_mut();
            sess.response = Some(resp.clone());
            sess.sink = None;
            return Some(resp);
        }
        if let Some(si) = self.active.iter().position(|s| s.id == id) {
            let mut slot = self.active.remove(si);
            let resp = slot.finish(self.step_no);
            let mut sess = slot.session.borrow_mut();
            sess.response = Some(resp.clone());
            sess.sink = None;
            drop(sess);
            return Some(resp);
        }
        None
    }

    pub(crate) fn run_to_completion(&mut self, backend: &ServeBackend) -> ServeStats {
        let mut stats = ServeStats::default();
        let steps0 = self.steps_decoded;
        let calls0 = self.decode_calls;
        let toks0 = self.tokens_decoded;
        let chunks0 = self.prefill_chunks;
        let (drafted0, accepted0) = self.policy.spec_counters().unwrap_or((0, 0));
        // detlint: allow(wall-clock, TTFT/latency measurement for ServeStats; token output is timing-independent by the determinism rule)
        let t0 = Instant::now();
        while self.pending() > 0 {
            for resp in self.step(backend) {
                stats.requests += 1;
                stats.total_tokens += resp.tokens_generated;
                stats.latencies.push(resp.latency_s);
                if resp.tokens_generated > 0 {
                    // a request that never emitted a token has no first
                    // token; keep it out of the TTFT distribution
                    stats.ttfts.push(resp.ttft_s);
                }
                stats.queue_waits.push(resp.queue_wait_s);
            }
        }
        stats.total_seconds = t0.elapsed().as_secs_f64();
        stats.engine_steps = self.steps_decoded - steps0;
        stats.decode_calls = self.decode_calls - calls0;
        stats.decoded_tokens = self.tokens_decoded - toks0;
        stats.prefill_chunks = self.prefill_chunks - chunks0;
        let (drafted, accepted) = self.policy.spec_counters().unwrap_or((0, 0));
        stats.spec_drafted = drafted - drafted0;
        stats.spec_accepted = accepted - accepted0;
        stats
    }
}

// ---------------------------------------------------------------------------
// the engine

/// The serving engine: owns a [`ServeBackend`], a [`Scheduler`], and a
/// [`DecodePolicy`]; turns submitted [`GenRequest`]s into [`Session`]s
/// and steps them to completion. The default configuration — [`Fifo`]
/// admission, [`OneToken`] decode, unlimited step budget — reproduces the
/// legacy `ContinuousBatcher` schedule bit-for-bit.
pub struct Engine {
    backend: ServeBackend,
    core: Core,
}

impl Engine {
    /// Engine over `backend` with up to `max_batch` concurrent decode
    /// slots, FIFO admission, and one-token decode.
    pub fn new(backend: ServeBackend, max_batch: usize) -> Engine {
        Engine { backend, core: Core::new(max_batch, Box::new(Fifo::new()), Box::new(OneToken::new())) }
    }

    /// Replace the scheduling policy (admission + slot allocation).
    pub fn with_scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Engine {
        self.core.scheduler = scheduler;
        self
    }

    /// Replace the decode policy. Fails if the policy cannot attach to
    /// this backend (e.g. decoding a draft model from the container).
    pub fn with_decode(mut self, mut policy: Box<dyn DecodePolicy>) -> Result<Engine> {
        policy.attach(&self.backend)?;
        self.core.policy = policy;
        Ok(self)
    }

    /// Cap the number of slots decoded per step (`0` = all active slots,
    /// the default). A budget below `max_batch` is where [`Scheduler`]
    /// allocation policies differ. A slot spending its allocation on a
    /// prefill chunk charges the budget exactly like a decoding slot.
    pub fn with_step_budget(mut self, budget: usize) -> Engine {
        self.core.step_budget = budget;
        self
    }

    /// Select how allocated slots execute per step (default
    /// [`StepMode::Batched`]). [`StepMode::PerSlot`] is the reference
    /// loop, kept for parity harnesses and A/B benches — both modes
    /// emit bitwise-identical token streams.
    pub fn with_step_mode(mut self, mode: StepMode) -> Engine {
        self.core.step_mode = mode;
        self
    }

    /// Admit long prompts in chunks of at most `n` tokens per step
    /// (`0` = whole-prompt prefill, the default). Chunking keeps a long
    /// prompt from monopolizing a step — the KV cache grows by one chunk
    /// per allocated step — and changes step counts and TTFT, never
    /// tokens: the first emitted token is computed over an identical KV
    /// state either way.
    pub fn with_prefill_chunk(mut self, n: usize) -> Engine {
        self.core.prefill_chunk = n;
        self
    }

    /// Active step mode.
    pub fn step_mode(&self) -> StepMode {
        self.core.step_mode
    }

    /// The execution backend this engine serves from.
    pub fn backend(&self) -> &ServeBackend {
        &self.backend
    }

    /// Recover the backend (e.g. to rebuild an engine with a different
    /// configuration without re-decoding a container).
    pub fn into_backend(self) -> ServeBackend {
        self.backend
    }

    /// Active scheduler name.
    pub fn scheduler_name(&self) -> &'static str {
        self.core.scheduler.name()
    }

    /// Active decode-policy name.
    pub fn policy_name(&self) -> &'static str {
        self.core.policy.name()
    }

    /// Enqueue a request; it is admitted at the next step with a free
    /// slot. The returned [`Session`] observes progress.
    ///
    /// Errors on an empty prompt (the byte LM needs at least one context
    /// token) — rejecting at submit keeps a bad request from panicking a
    /// forward pass mid-step under the engine's other in-flight work.
    pub fn submit(&mut self, req: GenRequest) -> Result<Session> {
        self.core.submit(req, None)
    }

    /// [`Engine::submit`] with a [`TokenSink`] invoked on every generated
    /// token as it streams out.
    pub fn submit_with_sink(&mut self, req: GenRequest, sink: TokenSink) -> Result<Session> {
        self.core.submit(req, Some(sink))
    }

    /// Requests not yet completed (queued + active).
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.core.queued()
    }

    /// Requests currently decoding.
    pub fn active_count(&self) -> usize {
        self.core.active_count()
    }

    /// One engine step: admit, decode allocated slots, retire. Returns
    /// the responses completed this step (admission order).
    pub fn step(&mut self) -> Vec<GenResponse> {
        self.core.step(&self.backend)
    }

    /// Cancel a request by id: a queued request retires with an empty
    /// response, an active one retires immediately with its partial
    /// output and frees its slot and KV caches. Returns the response,
    /// or `None` if the id is unknown or already finished.
    pub fn cancel(&mut self, id: u64) -> Option<GenResponse> {
        self.core.cancel(id)
    }

    /// Drain queue and slots, accumulating [`ServeStats`] for this run.
    pub fn run_to_completion(&mut self) -> ServeStats {
        self.core.run_to_completion(&self.backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    fn dense_engine(seed: u64, max_batch: usize) -> Engine {
        Engine::new(ServeBackend::Dense(tiny_model(seed)), max_batch)
    }

    fn drain(engine: &mut Engine) -> Vec<GenResponse> {
        let mut done = Vec::new();
        let mut guard = 0;
        while engine.pending() > 0 {
            done.extend(engine.step());
            guard += 1;
            assert!(guard < 10_000, "engine failed to drain");
        }
        done
    }

    #[test]
    fn chunked_prefill_is_token_identical_and_grows_kv_incrementally() {
        // chunk sizes spanning every edge: 1 (one token per step), a
        // non-divisor (3, 7), prompt-1 (19), exactly the prompt (20),
        // and larger than the prompt (64, behaves like unchunked)
        let prompt: Vec<u8> = (0..20).map(|i| (i * 7 + 3) as u8).collect();
        let req = GenRequest { id: 0, prompt: prompt.clone(), max_new_tokens: 6 };
        let mut base_engine = dense_engine(81, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let base = base_sess.response().unwrap();
        assert_eq!(base.ttft_steps, 1, "unchunked prefill emits at step 1");

        for chunk in [1usize, 3, 7, 19, 20, 64] {
            for mode in [StepMode::PerSlot, StepMode::Batched] {
                let mut e =
                    dense_engine(81, 1).with_step_mode(mode).with_prefill_chunk(chunk);
                let sess = e.submit(req.clone()).unwrap();
                // pin the KV cache growing by exactly one chunk per
                // pure-prefill step
                let mut pure_steps = 0;
                while sess.time_to_first_token_steps().is_none() {
                    e.step();
                    if sess.time_to_first_token_steps().is_none() {
                        pure_steps += 1;
                        assert_eq!(
                            e.core.active[0].seq.cache.len(),
                            pure_steps * chunk,
                            "chunk {chunk}: cache must grow chunk-wise"
                        );
                    }
                }
                let expect_ttft = prompt.len().div_ceil(chunk);
                assert_eq!(
                    sess.time_to_first_token_steps(),
                    Some(expect_ttft),
                    "chunk {chunk}: wrong prefill step count"
                );
                drain(&mut e);
                let resp = sess.response().unwrap();
                assert_eq!(resp.output, base.output, "chunk {chunk} changed tokens");
                assert_eq!(resp.tokens_generated, 6);
            }
        }
    }

    #[test]
    fn chunk_boundary_on_the_sliding_window_edge() {
        // prompt length exactly max_ctx (32): chunked prefill must stop
        // exactly at the window edge, and generation then slides the
        // window identically to the unchunked engine
        let edge: Vec<u8> = (0..32).map(|i| (i * 5 + 1) as u8).collect();
        let req = GenRequest { id: 0, prompt: edge.clone(), max_new_tokens: 4 };
        let mut base_engine = dense_engine(82, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let want = base_sess.response().unwrap();

        let mut e = dense_engine(82, 1).with_prefill_chunk(8);
        let sess = e.submit(req).unwrap();
        e.step();
        assert_eq!(e.core.active[0].seq.cache.len(), 8);
        e.step();
        e.step();
        assert_eq!(e.core.active[0].seq.cache.len(), 24);
        e.step(); // final window chunk + first token in one forward
        assert_eq!(sess.time_to_first_token_steps(), Some(4));
        assert_eq!(e.core.active[0].seq.cache.len(), 32, "cache fills the window exactly");
        assert_eq!(e.core.active[0].seq.window_start, 0, "window has not slid yet");
        drain(&mut e);
        assert_eq!(sess.response().unwrap().output, want.output);

        // prompt longer than the window (40 > 32): only the final
        // 32-token window prefills, still chunk-wise
        let long: Vec<u8> = (0..40).map(|i| (i * 3 + 2) as u8).collect();
        let req = GenRequest { id: 1, prompt: long.clone(), max_new_tokens: 3 };
        let mut base_engine = dense_engine(82, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let want = base_sess.response().unwrap();
        let mut e = dense_engine(82, 1).with_prefill_chunk(8);
        let sess = e.submit(req).unwrap();
        e.step();
        assert_eq!(e.core.active[0].seq.window_start, 8, "window starts past the prompt head");
        assert_eq!(e.core.active[0].seq.cache.len(), 8);
        drain(&mut e);
        assert_eq!(sess.time_to_first_token_steps(), Some(4), "32-token window / 8 per chunk");
        assert_eq!(sess.response().unwrap().output, want.output);
    }

    #[test]
    fn mid_prefill_cancellation_frees_the_slot_and_keeps_serving() {
        let prompt: Vec<u8> = (0..10).map(|i| (i * 11 + 4) as u8).collect();
        let mut e = dense_engine(83, 1).with_prefill_chunk(2);
        let s0 = e.submit(GenRequest { id: 0, prompt, max_new_tokens: 3 }).unwrap();
        let s1 = e.submit(GenRequest { id: 1, prompt: vec![9, 8, 7], max_new_tokens: 2 }).unwrap();
        e.step();
        e.step();
        // id 0 is mid-prefill (2 chunks in), id 1 queued behind max_batch 1
        assert_eq!(e.core.active[0].seq.cache.len(), 4);
        assert!(!s0.is_finished());
        assert_eq!(e.queued(), 1);

        let resp = e.cancel(0).expect("active request cancels");
        assert_eq!(resp.tokens_generated, 0);
        assert!(resp.output.is_empty());
        assert!(s0.is_finished(), "cancel resolves the session");
        assert_eq!(e.active_count(), 0, "slot and KV freed immediately");
        assert!(e.cancel(0).is_none(), "double-cancel is a no-op");
        assert!(e.cancel(99).is_none(), "unknown id is a no-op");

        // the engine keeps serving: id 1 admits into the freed slot and
        // completes with the same tokens as an isolated run
        drain(&mut e);
        let mut isolated = dense_engine(83, 1);
        let r = isolated
            .submit(GenRequest { id: 1, prompt: vec![9, 8, 7], max_new_tokens: 2 })
            .unwrap();
        drain(&mut isolated);
        assert_eq!(s1.response().unwrap().output, r.response().unwrap().output);

        // a request cancelled while still queued retires with an empty
        // response and never occupies a slot
        let mut e2 = dense_engine(83, 1);
        let a = e2.submit(GenRequest { id: 5, prompt: vec![1, 2], max_new_tokens: 4 }).unwrap();
        let b = e2.submit(GenRequest { id: 6, prompt: vec![3, 4], max_new_tokens: 1 }).unwrap();
        let resp = e2.cancel(6).expect("queued request cancels");
        assert_eq!(resp.tokens_generated, 0);
        assert!(b.is_finished());
        drain(&mut e2);
        assert_eq!(a.response().unwrap().tokens_generated, 4);
    }

    #[test]
    fn batched_step_counts_one_decode_call_but_n_slot_tokens() {
        // the stats-accounting fix: a batched step is ONE decode call
        // (one forward) emitting N slot-tokens; the per-slot loop stays
        // one call per slot-token. tokens_per_step makes the batching
        // win visible instead of silently reporting it as a no-op.
        let reqs: Vec<GenRequest> = (0..3u8)
            .map(|id| GenRequest {
                id: id as u64,
                prompt: (0..6).map(|i| (i * 13 + id * 3 + 1) as u8).collect(),
                max_new_tokens: 4,
            })
            .collect();
        let run_mode = |mode: StepMode, chunk: usize| {
            let mut e = dense_engine(84, 3).with_step_mode(mode).with_prefill_chunk(chunk);
            let sessions: Vec<Session> =
                reqs.iter().map(|r| e.submit(r.clone()).unwrap()).collect();
            let stats = e.run_to_completion();
            let out: Vec<(Vec<u8>, usize, usize)> = sessions
                .iter()
                .map(|s| {
                    let r = s.response().unwrap();
                    (r.output, r.ttft_steps, r.queue_wait_steps)
                })
                .collect();
            (stats, out)
        };

        let (b, bo) = run_mode(StepMode::Batched, 0);
        let (p, po) = run_mode(StepMode::PerSlot, 0);
        assert_eq!(bo, po, "step mode changed tokens or step-count timing");
        assert_eq!((b.engine_steps, p.engine_steps), (4, 4));
        assert_eq!((b.decoded_tokens, p.decoded_tokens), (12, 12));
        assert_eq!(b.decode_calls, 4, "one forward per batched step");
        assert_eq!(p.decode_calls, 12, "one forward per slot-token per-slot");
        assert!((b.tokens_per_step() - 3.0).abs() < 1e-12);
        assert!((p.tokens_per_step() - 1.0).abs() < 1e-12);
        for (_, ttft, wait) in &bo {
            assert_eq!((*ttft, *wait), (1, 0), "all three admit at step 0, emit at step 1");
        }

        // chunked prefill accounting: 6-token prompts under chunk 2 pay
        // 2 pure prefill chunks per slot before emitting
        let (c, co) = run_mode(StepMode::Batched, 2);
        assert_eq!(co.iter().map(|(o, _, _)| o.clone()).collect::<Vec<_>>(),
                   bo.iter().map(|(o, _, _)| o.clone()).collect::<Vec<_>>(),
                   "chunked prefill changed tokens");
        assert_eq!(c.prefill_chunks, 6, "2 chunks per slot");
        assert_eq!(c.engine_steps, 6, "2 prefill steps + 4 decode steps");
        assert_eq!(c.decode_calls, 6, "still one batched forward per step");
        assert_eq!(c.decoded_tokens, 12);
        for (_, ttft, _) in &co {
            assert_eq!(*ttft, 3, "2 prefill steps push the first token to step 3");
        }
        assert_eq!(b.prefill_chunks, 0);
    }
}
