//! The serving engine: sessions, decode slots, admission, and stepping.
//!
//! [`Engine`] owns a [`ServeBackend`] plus two trait-based extension
//! points — a [`Scheduler`] (admission + per-step slot allocation) and a
//! [`DecodePolicy`] (tokens emitted per slot per step). One
//! [`Engine::step`] runs the legacy continuous-batching cycle:
//!
//! 1. admit queued requests into free decode slots (scheduler order),
//! 2. advance the allocated slots through the decode policy,
//! 3. retire finished sequences in admission order (single in-place
//!    retain pass).
//!
//! [`Engine::submit`] returns a [`Session`] handle that exposes streamed
//! tokens (optionally through a [`TokenSink`] callback), per-request
//! time-to-first-token and queue wait, and the final [`GenResponse`].
//! The deprecated `ContinuousBatcher` and `generate_greedy*` free
//! functions in [`crate::serve`] are thin shims over the same core, so
//! their behavior is reproduced bit-for-bit by an engine with the
//! default [`Fifo`] + [`OneToken`] configuration.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::model::forward::{forward_logits_cached_with, LinearApply};
use crate::model::kv::KvCache;
use crate::model::{Model, ModelConfig};
use crate::serve::decode::{argmax_logits, DecodePolicy, DraftState, OneToken};
use crate::serve::scheduler::{Fifo, QueuedView, Scheduler, SlotView};
use crate::serve::stats::ServeStats;
use crate::serve::ServeBackend;
use crate::tensor::Matrix;

// ---------------------------------------------------------------------------
// requests and responses

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// caller-chosen request id, echoed in the response
    pub id: u64,
    /// prompt bytes (the model is a byte LM)
    pub prompt: Vec<u8>,
    /// decode budget after the prompt
    pub max_new_tokens: usize,
}

/// Completed request with timing.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// id of the originating request
    pub id: u64,
    /// generated tokens (beyond the prompt)
    pub output: Vec<u8>,
    /// submit-to-retire wall-clock seconds
    pub latency_s: f64,
    /// tokens generated beyond the prompt
    pub tokens_generated: usize,
    /// submit-to-first-generated-token wall-clock seconds; equals
    /// `latency_s` for a request that generated no tokens (such
    /// responses are excluded from the [`ServeStats`] TTFT percentiles)
    pub ttft_s: f64,
    /// submit-to-admission wall-clock seconds (time queued for a slot)
    pub queue_wait_s: f64,
}

// ---------------------------------------------------------------------------
// per-sequence decode state

/// Decode state of one sequence: the accepted token stream plus the KV
/// cache over the current context window. The cache is reused as long as
/// the window does not slide; once the context exceeds `max_seq` the
/// window start moves every step and the state degrades to the seed's
/// full-recompute behavior (same logits). Speculative policies keep a
/// second, draft-path cache here as well.
pub struct SeqState {
    pub(crate) tokens: Vec<u8>,
    pub(crate) cache: KvCache,
    pub(crate) window_start: usize,
    pub(crate) max_ctx: usize,
    pub(crate) draft: Option<DraftState>,
}

impl SeqState {
    /// Fresh state over `prompt` (nothing forwarded yet).
    pub fn new(cfg: &ModelConfig, prompt: &[u8]) -> SeqState {
        SeqState {
            tokens: prompt.to_vec(),
            cache: KvCache::new(cfg),
            window_start: 0,
            max_ctx: cfg.max_seq,
            draft: None,
        }
    }

    /// Full accepted token stream (prompt + generated so far).
    pub fn tokens(&self) -> &[u8] {
        &self.tokens
    }

    /// Re-derive the context window start; clears the cache when the
    /// window slid (the cached positions no longer line up).
    pub(crate) fn sync_window(&mut self) {
        let ctx_start = self.tokens.len().saturating_sub(self.max_ctx);
        if ctx_start != self.window_start {
            self.cache.clear();
            self.window_start = ctx_start;
        }
    }

    /// Forward every token of the stream not yet covered by the cache
    /// (at least one) and return their logits rows.
    pub fn forward_pending(&mut self, model: &Model, lin: &impl LinearApply) -> Matrix {
        self.sync_window();
        let new0 = self.window_start + self.cache.len();
        forward_logits_cached_with(model, lin, &mut self.cache, &self.tokens[new0..])
    }

    /// Append one emitted token to the accepted stream. External
    /// [`DecodePolicy`] implementations record their emissions through
    /// this — the engine derives per-slot progress from the stream
    /// length, so every token a policy returns must also be committed.
    pub fn commit_token(&mut self, token: u8) {
        self.tokens.push(token);
    }

    /// Generate one greedy token — the [`OneToken`] step, shared with the
    /// speculative policy's window-edge fallback and the deprecated
    /// `generate_greedy` shim.
    pub fn one_token(&mut self, model: &Model, lin: &impl LinearApply) -> u8 {
        let logits = self.forward_pending(model, lin);
        let next = argmax_logits(logits.row(logits.rows() - 1));
        self.tokens.push(next);
        next
    }
}

// ---------------------------------------------------------------------------
// sessions

/// Callback receiving each generated token of one session as it is
/// emitted — the streaming surface of a [`Session`]. Invoked while the
/// engine holds the session's shared state, so a sink must not call back
/// into [`Session`] methods of its own session (single-threaded
/// re-entrancy guard; it would panic on the interior borrow).
pub type TokenSink = Box<dyn FnMut(u8)>;

/// Per-request state shared between the engine and a [`Session`] handle.
pub(crate) struct SessionShared {
    id: u64,
    streamed: Vec<u8>,
    ttft_s: Option<f64>,
    queue_wait_s: Option<f64>,
    response: Option<GenResponse>,
    sink: Option<TokenSink>,
}

/// Handle to one submitted request: observe streamed tokens, per-request
/// timing, and the final [`GenResponse`] as the engine steps. Handles are
/// single-threaded (`Rc`-shared with the engine) and stay valid after the
/// request completes.
pub struct Session {
    inner: Rc<RefCell<SessionShared>>,
}

impl Session {
    /// The request id this session tracks.
    pub fn id(&self) -> u64 {
        self.inner.borrow().id
    }

    /// Whether the request has retired (final response available).
    pub fn is_finished(&self) -> bool {
        self.inner.borrow().response.is_some()
    }

    /// Snapshot of the tokens streamed so far (beyond the prompt).
    pub fn streamed(&self) -> Vec<u8> {
        self.inner.borrow().streamed.clone()
    }

    /// Submit-to-first-token seconds, once the first token exists.
    pub fn time_to_first_token(&self) -> Option<f64> {
        self.inner.borrow().ttft_s
    }

    /// Submit-to-admission seconds, once the request holds a slot.
    pub fn queue_wait(&self) -> Option<f64> {
        self.inner.borrow().queue_wait_s
    }

    /// The final response, once the request retired.
    pub fn response(&self) -> Option<GenResponse> {
        self.inner.borrow().response.clone()
    }
}

// ---------------------------------------------------------------------------
// engine core

struct QueueEntry {
    req: GenRequest,
    arrival: u64,
    enqueued: Instant,
    submit_step: u64,
    session: Rc<RefCell<SessionShared>>,
}

struct Slot {
    id: u64,
    arrival: u64,
    prompt_len: usize,
    max_new: usize,
    enqueued: Instant,
    queue_wait_s: f64,
    idle_steps: usize,
    seq: SeqState,
    session: Rc<RefCell<SessionShared>>,
}

impl Slot {
    fn generated(&self) -> usize {
        self.seq.tokens.len() - self.prompt_len
    }

    fn remaining(&self) -> usize {
        self.max_new - self.generated()
    }

    /// Build the final response, consuming the token buffer.
    fn finish(&mut self) -> GenResponse {
        let generated = self.generated();
        let latency_s = self.enqueued.elapsed().as_secs_f64();
        let tokens = std::mem::take(&mut self.seq.tokens);
        let ttft_s = self.session.borrow().ttft_s.unwrap_or(latency_s);
        GenResponse {
            id: self.id,
            output: tokens[self.prompt_len..].to_vec(),
            latency_s,
            tokens_generated: generated,
            ttft_s,
            queue_wait_s: self.queue_wait_s,
        }
    }
}

/// Backend-agnostic engine internals, shared by [`Engine`] (which owns
/// its backend) and the deprecated `ContinuousBatcher` shim (which
/// borrows one per call).
pub(crate) struct Core {
    pub(crate) max_batch: usize,
    pub(crate) step_budget: usize,
    pub(crate) scheduler: Box<dyn Scheduler>,
    pub(crate) policy: Box<dyn DecodePolicy>,
    queue: Vec<QueueEntry>,
    active: Vec<Slot>,
    arrivals: u64,
    step_no: u64,
    steps_decoded: usize,
    decode_calls: usize,
    tokens_decoded: usize,
}

impl Core {
    pub(crate) fn new(
        max_batch: usize,
        scheduler: Box<dyn Scheduler>,
        policy: Box<dyn DecodePolicy>,
    ) -> Core {
        Core {
            max_batch: max_batch.max(1),
            step_budget: 0,
            scheduler,
            policy,
            queue: Vec::new(),
            active: Vec::new(),
            arrivals: 0,
            step_no: 0,
            steps_decoded: 0,
            decode_calls: 0,
            tokens_decoded: 0,
        }
    }

    pub(crate) fn submit(&mut self, req: GenRequest, sink: Option<TokenSink>) -> Result<Session> {
        // reject bad input at submit: an empty prompt would only panic
        // mid-step inside the forward pass, taking every other in-flight
        // request in this engine down with it
        if req.prompt.is_empty() {
            return Err(Error::msg(format!(
                "request {}: empty prompt (the byte LM needs at least one context token)",
                req.id
            )));
        }
        let session = Rc::new(RefCell::new(SessionShared {
            id: req.id,
            streamed: Vec::new(),
            ttft_s: None,
            queue_wait_s: None,
            response: None,
            sink,
        }));
        self.queue.push(QueueEntry {
            req,
            arrival: self.arrivals,
            // detlint: allow(wall-clock, admission timestamp feeds queue-wait percentiles only; scheduling is arrival-order/aging on step counts)
            enqueued: Instant::now(),
            submit_step: self.step_no,
            session: Rc::clone(&session),
        });
        self.arrivals += 1;
        Ok(Session { inner: session })
    }

    pub(crate) fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub(crate) fn queued(&self) -> usize {
        self.queue.len()
    }

    pub(crate) fn active_count(&self) -> usize {
        self.active.len()
    }

    pub(crate) fn step(&mut self, backend: &ServeBackend) -> Vec<GenResponse> {
        // ---- admission: scheduler fills free slots from the queue ----
        // views are built once per step — only when a slot is actually
        // free — and kept aligned with the queue across removals
        // (waited_steps cannot change mid-step), so a backlog costs one
        // pass, not one rebuild per admitted request or per busy step
        let mut views: Vec<QueuedView> = if self.active.len() < self.max_batch {
            self.queue
                .iter()
                .map(|q| QueuedView {
                    id: q.req.id,
                    arrival: q.arrival,
                    prompt_len: q.req.prompt.len(),
                    max_new: q.req.max_new_tokens,
                    waited_steps: (self.step_no - q.submit_step) as usize,
                })
                .collect()
        } else {
            Vec::new()
        };
        while self.active.len() < self.max_batch && !self.queue.is_empty() {
            let Some(i) = self.scheduler.admit(&views) else { break };
            assert!(i < self.queue.len(), "scheduler admitted out-of-range queue index {i}");
            views.remove(i);
            let q = self.queue.remove(i);
            let queue_wait_s = q.enqueued.elapsed().as_secs_f64();
            q.session.borrow_mut().queue_wait_s = Some(queue_wait_s);
            self.active.push(Slot {
                id: q.req.id,
                arrival: q.arrival,
                prompt_len: q.req.prompt.len(),
                max_new: q.req.max_new_tokens,
                enqueued: q.enqueued,
                queue_wait_s,
                idle_steps: 0,
                seq: SeqState::new(&backend.model().cfg, &q.req.prompt),
                session: q.session,
            });
        }
        // progress contract: free slots + a non-empty queue must admit
        assert!(
            !self.active.is_empty() || self.queue.is_empty(),
            "scheduler {} stalled: empty slots but {} queued requests",
            self.scheduler.name(),
            self.queue.len()
        );

        // ---- allocation + decode ----
        if !self.active.is_empty() {
            let budget = if self.step_budget == 0 {
                self.active.len()
            } else {
                self.step_budget.min(self.active.len())
            };
            let views: Vec<SlotView> = self
                .active
                .iter()
                .map(|s| SlotView {
                    id: s.id,
                    arrival: s.arrival,
                    generated: s.generated(),
                    remaining: s.remaining(),
                    idle_steps: s.idle_steps,
                })
                .collect();
            let mut chosen = self.scheduler.allocate(&views, budget);
            chosen.sort_unstable();
            chosen.dedup();
            assert!(
                chosen.len() <= budget,
                "scheduler {} allocated {} slots over budget {budget}",
                self.scheduler.name(),
                chosen.len()
            );
            let Core { policy, active, decode_calls, tokens_decoded, .. } = self;
            let mut decoded_any = false;
            // detlint: hot(engine-step) — per-slot decode dispatch runs every
            // engine step at serving concurrency; keep it allocation-free
            for &i in &chosen {
                assert!(i < active.len(), "scheduler allocated out-of-range slot {i}");
                let slot = &mut active[i];
                let remaining = slot.remaining();
                if remaining == 0 {
                    continue; // zero-budget request, retires below untouched
                }
                let toks = policy.decode(backend, &mut slot.seq, remaining);
                // hard contract (like the scheduler stall asserts): a
                // policy emitting nothing would spin the engine forever
                assert!(
                    !toks.is_empty() && toks.len() <= remaining,
                    "decode policy {} emitted {} tokens with {remaining} remaining",
                    policy.name(),
                    toks.len()
                );
                debug_assert_eq!(
                    slot.seq.tokens.len() - slot.prompt_len,
                    slot.max_new - remaining + toks.len(),
                    "decode policy desynced the token stream"
                );
                let mut sess = slot.session.borrow_mut();
                if sess.ttft_s.is_none() && !toks.is_empty() {
                    sess.ttft_s = Some(slot.enqueued.elapsed().as_secs_f64());
                }
                for &t in &toks {
                    sess.streamed.push(t);
                    if let Some(sink) = sess.sink.as_mut() {
                        sink(t);
                    }
                }
                drop(sess);
                *decode_calls += 1;
                *tokens_decoded += toks.len();
                decoded_any = true;
            }
            // detlint: endhot
            // progress contract, allocation side: with active slots, the
            // scheduler must either decode something or leave only
            // finished (zero-remaining) slots, which retire below — a
            // policy that allocates nothing would spin forever otherwise
            assert!(
                decoded_any || self.active.iter().any(|s| s.remaining() == 0),
                "scheduler {} stalled: allocated no decodable slot out of {} active",
                self.scheduler.name(),
                self.active.len()
            );
            // idle accounting feeds round-robin fairness and SRPT aging
            for (i, slot) in self.active.iter_mut().enumerate() {
                if chosen.binary_search(&i).is_ok() {
                    slot.idle_steps = 0;
                } else {
                    slot.idle_steps += 1;
                }
            }
            if decoded_any {
                self.steps_decoded += 1;
            }
        }
        self.step_no += 1;

        // ---- retirement: one in-place retain pass, admission order ----
        let mut done = Vec::new();
        self.active.retain_mut(|slot| {
            if slot.generated() < slot.max_new {
                return true;
            }
            let resp = slot.finish();
            let mut sess = slot.session.borrow_mut();
            sess.response = Some(resp.clone());
            // the sink can never fire again — drop it now so captured
            // state is freed even while the Session handle lives on
            sess.sink = None;
            drop(sess);
            done.push(resp);
            false
        });
        done
    }

    pub(crate) fn run_to_completion(&mut self, backend: &ServeBackend) -> ServeStats {
        let mut stats = ServeStats::default();
        let steps0 = self.steps_decoded;
        let calls0 = self.decode_calls;
        let toks0 = self.tokens_decoded;
        let (drafted0, accepted0) = self.policy.spec_counters().unwrap_or((0, 0));
        // detlint: allow(wall-clock, TTFT/latency measurement for ServeStats; token output is timing-independent by the determinism rule)
        let t0 = Instant::now();
        while self.pending() > 0 {
            for resp in self.step(backend) {
                stats.requests += 1;
                stats.total_tokens += resp.tokens_generated;
                stats.latencies.push(resp.latency_s);
                if resp.tokens_generated > 0 {
                    // a request that never emitted a token has no first
                    // token; keep it out of the TTFT distribution
                    stats.ttfts.push(resp.ttft_s);
                }
                stats.queue_waits.push(resp.queue_wait_s);
            }
        }
        stats.total_seconds = t0.elapsed().as_secs_f64();
        stats.engine_steps = self.steps_decoded - steps0;
        stats.decode_calls = self.decode_calls - calls0;
        stats.decoded_tokens = self.tokens_decoded - toks0;
        let (drafted, accepted) = self.policy.spec_counters().unwrap_or((0, 0));
        stats.spec_drafted = drafted - drafted0;
        stats.spec_accepted = accepted - accepted0;
        stats
    }
}

// ---------------------------------------------------------------------------
// the engine

/// The serving engine: owns a [`ServeBackend`], a [`Scheduler`], and a
/// [`DecodePolicy`]; turns submitted [`GenRequest`]s into [`Session`]s
/// and steps them to completion. The default configuration — [`Fifo`]
/// admission, [`OneToken`] decode, unlimited step budget — reproduces the
/// legacy `ContinuousBatcher` schedule bit-for-bit.
pub struct Engine {
    backend: ServeBackend,
    core: Core,
}

impl Engine {
    /// Engine over `backend` with up to `max_batch` concurrent decode
    /// slots, FIFO admission, and one-token decode.
    pub fn new(backend: ServeBackend, max_batch: usize) -> Engine {
        Engine { backend, core: Core::new(max_batch, Box::new(Fifo::new()), Box::new(OneToken::new())) }
    }

    /// Replace the scheduling policy (admission + slot allocation).
    pub fn with_scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Engine {
        self.core.scheduler = scheduler;
        self
    }

    /// Replace the decode policy. Fails if the policy cannot attach to
    /// this backend (e.g. decoding a draft model from the container).
    pub fn with_decode(mut self, mut policy: Box<dyn DecodePolicy>) -> Result<Engine> {
        policy.attach(&self.backend)?;
        self.core.policy = policy;
        Ok(self)
    }

    /// Cap the number of slots decoded per step (`0` = all active slots,
    /// the default). A budget below `max_batch` is where [`Scheduler`]
    /// allocation policies differ.
    pub fn with_step_budget(mut self, budget: usize) -> Engine {
        self.core.step_budget = budget;
        self
    }

    /// The execution backend this engine serves from.
    pub fn backend(&self) -> &ServeBackend {
        &self.backend
    }

    /// Recover the backend (e.g. to rebuild an engine with a different
    /// configuration without re-decoding a container).
    pub fn into_backend(self) -> ServeBackend {
        self.backend
    }

    /// Active scheduler name.
    pub fn scheduler_name(&self) -> &'static str {
        self.core.scheduler.name()
    }

    /// Active decode-policy name.
    pub fn policy_name(&self) -> &'static str {
        self.core.policy.name()
    }

    /// Enqueue a request; it is admitted at the next step with a free
    /// slot. The returned [`Session`] observes progress.
    ///
    /// Errors on an empty prompt (the byte LM needs at least one context
    /// token) — rejecting at submit keeps a bad request from panicking a
    /// forward pass mid-step under the engine's other in-flight work.
    pub fn submit(&mut self, req: GenRequest) -> Result<Session> {
        self.core.submit(req, None)
    }

    /// [`Engine::submit`] with a [`TokenSink`] invoked on every generated
    /// token as it streams out.
    pub fn submit_with_sink(&mut self, req: GenRequest, sink: TokenSink) -> Result<Session> {
        self.core.submit(req, Some(sink))
    }

    /// Requests not yet completed (queued + active).
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.core.queued()
    }

    /// Requests currently decoding.
    pub fn active_count(&self) -> usize {
        self.core.active_count()
    }

    /// One engine step: admit, decode allocated slots, retire. Returns
    /// the responses completed this step (admission order).
    pub fn step(&mut self) -> Vec<GenResponse> {
        self.core.step(&self.backend)
    }

    /// Drain queue and slots, accumulating [`ServeStats`] for this run.
    pub fn run_to_completion(&mut self) -> ServeStats {
        self.core.run_to_completion(&self.backend)
    }
}
