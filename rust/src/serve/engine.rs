//! The serving engine: sessions, decode slots, admission, and stepping.
//!
//! [`Engine`] owns a [`ServeBackend`] plus two trait-based extension
//! points — a [`Scheduler`] (admission + per-step slot allocation) and a
//! [`DecodePolicy`] (tokens emitted per slot per step). One
//! [`Engine::step`] runs the continuous-batching cycle:
//!
//! 1. re-poll backpressured sinks and expire requests past their
//!    step-count deadline (queued and active alike — an expired slot
//!    returns its KV before admission runs),
//! 2. admit queued requests into free decode slots (scheduler order),
//! 3. advance the allocated slots — by default through ONE cross-slot
//!    ragged batched forward ([`StepMode::Batched`]); the PR 5 loop of
//!    one forward per slot survives as [`StepMode::PerSlot`], the
//!    reference the batched step is pinned token-identical against,
//! 4. retire finished sequences in admission order (single in-place
//!    retain pass).
//!
//! # Overload control
//!
//! The engine can refuse work instead of degrading unboundedly. A
//! bounded admission queue ([`Engine::with_queue_cap`]) sheds submits
//! with a typed [`Rejected`] outcome once full; per-request step-count
//! deadlines ([`GenRequest::deadline_steps`]) cancel overdue requests
//! through the same path as [`Engine::cancel`], freeing slot and KV
//! immediately; and a [`TokenSink`] can push back token-by-token
//! ([`SinkStatus::Blocked`] pauses the slot's allocation until the sink
//! drains, [`SinkStatus::Closed`] cancels it). Every decision is made in
//! deterministic step-time — wall clocks never influence which tokens
//! are produced or which requests are shed, so identically-seeded runs
//! resolve identically. Scheduler progress-contract violations surface
//! as recoverable [`StepError`]s rather than panics.
//!
//! With a paged KV arena ([`Engine::with_kv_page`] +
//! [`Engine::with_kv_pages`]) overload is additionally accounted in
//! *pages*: every admission reserves a request's worst-case page count
//! up front, a submit that cannot fit on top of the queued demand is
//! shed with [`Rejected::KvExhausted`], and schedulers see the arena's
//! `free_pages` in their queue and slot views. Retirement, expiry, and
//! cancellation return a slot's pages to the shared free list the same
//! step, so thousands of sessions share a bounded arena instead of each
//! owning a contiguous cache.
//!
//! Long prompts can prefill in chunks ([`Engine::with_prefill_chunk`]):
//! a chunked slot forwards at most `chunk` prompt tokens per step,
//! growing its KV cache incrementally instead of monopolizing a step,
//! and each chunk charges the scheduler's step budget like a decode.
//! Chunking changes step counts (TTFT), never tokens.
//!
//! [`Engine::submit`] returns a [`Session`] handle that exposes streamed
//! tokens (optionally through a [`TokenSink`] callback), per-request
//! time-to-first-token and queue wait (wall-clock and deterministic
//! step counts), and the final [`GenResponse`]; [`Engine::cancel`]
//! retires a request early, freeing its slot and KV immediately.
//! The deprecated `ContinuousBatcher` and `generate_greedy*` free
//! functions in [`crate::serve`] are thin shims over the same core, so
//! their behavior is reproduced bit-for-bit by an engine with the
//! default [`Fifo`] + [`OneToken`] configuration in per-slot mode.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::model::forward::{
    forward_logits_batched_with, forward_logits_cached_with, BatchItem, LinearApply,
};
use crate::model::kv::KvSeq;
use crate::model::kvpool::{KvBacking, KvPool, KvPoolStats, KvStoreKind, PagedKvCache};
use crate::model::{Model, ModelConfig};
use crate::serve::decode::{argmax_logits, BatchPlan, DecodePolicy, DraftState, OneToken};
use crate::serve::scheduler::{Fifo, QueuedView, Scheduler, SlotView};
use crate::serve::stats::ServeStats;
use crate::serve::ServeBackend;
use crate::tensor::Matrix;

// ---------------------------------------------------------------------------
// requests and responses

/// One generation request.
#[derive(Debug, Clone, Default)]
pub struct GenRequest {
    /// caller-chosen request id, echoed in the response
    pub id: u64,
    /// prompt bytes (the model is a byte LM)
    pub prompt: Vec<u8>,
    /// decode budget after the prompt
    pub max_new_tokens: usize,
    /// engine-step deadline counted from submit: a request still
    /// unfinished after this many steps is expired — cancelled through
    /// the [`Engine::cancel`] machinery, freeing its slot and KV
    /// immediately, and resolved with [`Outcome::Expired`]. `0` (the
    /// default) means no deadline. Deadlines are checked in
    /// deterministic step-time, never wall clock, so expiry decisions
    /// are reproducible run-to-run.
    pub deadline_steps: usize,
}

impl GenRequest {
    /// Request `id` over `prompt` with a `max_new` decode budget and no
    /// deadline.
    pub fn new(id: u64, prompt: Vec<u8>, max_new: usize) -> GenRequest {
        GenRequest { id, prompt, max_new_tokens: max_new, deadline_steps: 0 }
    }

    /// Builder: expire this request `steps` engine steps after submit
    /// (`0` = no deadline).
    pub fn with_deadline_steps(mut self, steps: usize) -> GenRequest {
        self.deadline_steps = steps;
        self
    }
}

/// How a request terminally resolved. Every submitted (non-shed)
/// request resolves exactly once with one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// the request generated its full `max_new_tokens` budget
    Completed,
    /// the request hit its [`GenRequest::deadline_steps`] deadline and
    /// was cancelled by the engine (partial output, slot + KV freed)
    Expired,
    /// the request was cancelled — by [`Engine::cancel`] or by its
    /// [`TokenSink`] returning [`SinkStatus::Closed`]
    Cancelled,
}

/// Completed request with timing.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// id of the originating request
    pub id: u64,
    /// generated tokens (beyond the prompt)
    pub output: Vec<u8>,
    /// submit-to-retire wall-clock seconds
    pub latency_s: f64,
    /// tokens generated beyond the prompt
    pub tokens_generated: usize,
    /// submit-to-first-generated-token wall-clock seconds; equals
    /// `latency_s` for a request that generated no tokens (such
    /// responses are excluded from the [`ServeStats`] TTFT percentiles)
    pub ttft_s: f64,
    /// submit-to-admission wall-clock seconds (time queued for a slot)
    pub queue_wait_s: f64,
    /// engine steps from submit through the step that emitted the first
    /// token — the deterministic counterpart of `ttft_s` (step counts
    /// depend only on workload shape and configuration, never timing);
    /// for a request that generated nothing, the steps from submit to
    /// retirement. Chunked prefill raises this by the number of extra
    /// prefill steps.
    pub ttft_steps: usize,
    /// engine steps spent queued before admission — the deterministic
    /// counterpart of `queue_wait_s`
    pub queue_wait_steps: usize,
    /// engine steps from submit to terminal resolution — the
    /// deterministic counterpart of `latency_s`, and the value a
    /// deadline is compared against
    pub total_steps: usize,
    /// how the request terminally resolved (completed in full, expired
    /// at its deadline, or cancelled)
    pub outcome: Outcome,
}

// ---------------------------------------------------------------------------
// per-sequence decode state

/// Decode state of one sequence: the accepted token stream plus the KV
/// cache over the current context window. The cache is reused as long as
/// the window does not slide; once the context exceeds `max_seq` the
/// window start moves every step and the state degrades to the seed's
/// full-recompute behavior (same logits). Speculative policies keep a
/// second, draft-path cache here as well.
pub struct SeqState {
    pub(crate) tokens: Vec<u8>,
    pub(crate) cache: KvBacking,
    pub(crate) window_start: usize,
    pub(crate) max_ctx: usize,
    pub(crate) draft: Option<DraftState>,
}

impl SeqState {
    /// Fresh state over `prompt` (nothing forwarded yet), backed by a
    /// contiguous per-sequence KV cache — the non-pooled default.
    pub fn new(cfg: &ModelConfig, prompt: &[u8]) -> SeqState {
        SeqState::with_backing(cfg, prompt, KvBacking::contiguous(cfg))
    }

    /// Fresh state over `prompt` with an explicit KV backing — the paged
    /// engine admits slots through this, handing each one a
    /// [`PagedKvCache`] drawn from the shared arena.
    pub fn with_backing(cfg: &ModelConfig, prompt: &[u8], backing: KvBacking) -> SeqState {
        SeqState {
            tokens: prompt.to_vec(),
            cache: backing,
            window_start: 0,
            max_ctx: cfg.max_seq,
            draft: None,
        }
    }

    /// Full accepted token stream (prompt + generated so far).
    pub fn tokens(&self) -> &[u8] {
        &self.tokens
    }

    /// Re-derive the context window start; clears the cache when the
    /// window slid (the cached positions no longer line up).
    pub(crate) fn sync_window(&mut self) {
        let ctx_start = self.tokens.len().saturating_sub(self.max_ctx);
        if ctx_start != self.window_start {
            self.cache.clear();
            self.window_start = ctx_start;
        }
    }

    /// Forward every token of the stream not yet covered by the cache
    /// (at least one) and return their logits rows.
    pub fn forward_pending(&mut self, model: &Model, lin: &impl LinearApply) -> Matrix {
        self.sync_window();
        let new0 = self.window_start + self.cache.len();
        forward_logits_cached_with(model, lin, &mut self.cache, &self.tokens[new0..])
    }

    /// Append one emitted token to the accepted stream. External
    /// [`DecodePolicy`] implementations record their emissions through
    /// this — the engine derives per-slot progress from the stream
    /// length, so every token a policy returns must also be committed.
    pub fn commit_token(&mut self, token: u8) {
        self.tokens.push(token);
    }

    /// Generate one greedy token — the [`OneToken`] step, shared with the
    /// speculative policy's window-edge fallback and the deprecated
    /// `generate_greedy` shim.
    pub fn one_token(&mut self, model: &Model, lin: &impl LinearApply) -> u8 {
        let logits = self.forward_pending(model, lin);
        let next = argmax_logits(logits.row(logits.rows() - 1));
        self.tokens.push(next);
        next
    }
}

// ---------------------------------------------------------------------------
// sessions

/// Flow-control status a [`TokenSink`] reports back to the engine for
/// each delivered token (and each [`TokenSink::poll`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SinkStatus {
    /// the consumer keeps up — keep streaming
    #[default]
    Ready,
    /// the token was taken but the consumer's buffer is full: the
    /// engine pauses this slot's allocation (the slot keeps its KV) and
    /// re-polls the sink each step until it reports `Ready` again
    Blocked,
    /// the consumer is gone: the engine cancels the request, freeing
    /// its slot and KV immediately ([`Outcome::Cancelled`])
    Closed,
}

/// Sink receiving each generated token of one session as it is emitted
/// — the streaming surface of a [`Session`] — and the engine's
/// token-level backpressure channel: the status returned from
/// [`TokenSink::on_token`] can pause ([`SinkStatus::Blocked`]) or
/// cancel ([`SinkStatus::Closed`]) the producing slot. Any
/// `FnMut(u8) -> SinkStatus` closure is a sink (always-`Ready` for the
/// no-backpressure case). Invoked while the engine holds the session's
/// shared state, so a sink must not call back into [`Session`] methods
/// of its own session (single-threaded re-entrancy guard; it would
/// panic on the interior borrow).
///
/// Backpressure decisions happen in deterministic step-time: a paused
/// slot is skipped by allocation until a step whose `poll` returns
/// `Ready`, so a sink that drains on a step schedule reproduces the
/// same transcript every run.
pub trait TokenSink {
    /// Deliver one generated token; the returned status steers the
    /// producing slot (see [`SinkStatus`]).
    fn on_token(&mut self, tok: u8) -> SinkStatus;

    /// Re-polled by the engine once per step while the slot is paused:
    /// return `Ready` when drained (resumes allocation this step),
    /// `Blocked` to stay paused, or `Closed` to cancel the request.
    /// The default never blocks.
    fn poll(&mut self) -> SinkStatus {
        SinkStatus::Ready
    }
}

impl<F: FnMut(u8) -> SinkStatus> TokenSink for F {
    fn on_token(&mut self, tok: u8) -> SinkStatus {
        self(tok)
    }
}

/// Per-request state shared between the engine and a [`Session`] handle.
pub(crate) struct SessionShared {
    id: u64,
    streamed: Vec<u8>,
    ttft_s: Option<f64>,
    queue_wait_s: Option<f64>,
    ttft_steps: Option<usize>,
    queue_wait_steps: Option<usize>,
    response: Option<GenResponse>,
    sink: Option<Box<dyn TokenSink>>,
}

/// Handle to one submitted request: observe streamed tokens, per-request
/// timing, and the final [`GenResponse`] as the engine steps. Handles are
/// single-threaded (`Rc`-shared with the engine) and stay valid after the
/// request completes.
pub struct Session {
    inner: Rc<RefCell<SessionShared>>,
}

impl Session {
    /// The request id this session tracks.
    pub fn id(&self) -> u64 {
        self.inner.borrow().id
    }

    /// Whether the request has retired (final response available).
    pub fn is_finished(&self) -> bool {
        self.inner.borrow().response.is_some()
    }

    /// Snapshot of the tokens streamed so far (beyond the prompt).
    pub fn streamed(&self) -> Vec<u8> {
        self.inner.borrow().streamed.clone()
    }

    /// Submit-to-first-token seconds, once the first token exists.
    pub fn time_to_first_token(&self) -> Option<f64> {
        self.inner.borrow().ttft_s
    }

    /// Submit-to-admission seconds, once the request holds a slot.
    pub fn queue_wait(&self) -> Option<f64> {
        self.inner.borrow().queue_wait_s
    }

    /// Engine steps from submit through the first token's step — the
    /// deterministic TTFT — once the first token exists.
    pub fn time_to_first_token_steps(&self) -> Option<usize> {
        self.inner.borrow().ttft_steps
    }

    /// Engine steps spent queued, once the request holds a slot.
    pub fn queue_wait_steps(&self) -> Option<usize> {
        self.inner.borrow().queue_wait_steps
    }

    /// The final response, once the request retired.
    pub fn response(&self) -> Option<GenResponse> {
        self.inner.borrow().response.clone()
    }
}

// ---------------------------------------------------------------------------
// admission control and progress-contract errors

/// Why the engine refused a request at submit time (load shedding).
/// A shed request is never enqueued: it has no [`Session`] and consumes
/// nothing — the typed outcome is the backpressure signal callers act
/// on (retry later, degrade, or drop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    /// the bounded admission queue ([`Engine::with_queue_cap`]) is at
    /// capacity
    QueueFull {
        /// the configured queue capacity that was hit
        queue_cap: usize,
    },
    /// the request's deadline cannot be met even by an idle engine:
    /// fewer steps than the configured prefill alone needs
    DeadlineInfeasible {
        /// the deadline the request asked for
        deadline_steps: usize,
        /// the minimum steps this engine needs for such a request
        min_steps: usize,
    },
    /// the bounded paged-KV arena ([`Engine::with_kv_pages`]) cannot
    /// cover this request's worst-case KV footprint on top of the
    /// demand already queued — the page-domain shed reason. Requests
    /// larger than the whole arena are shed unconditionally.
    KvExhausted {
        /// pages this request would reserve at its worst case
        /// (prompt + decode budget, clamped to the context window)
        needed_pages: usize,
        /// arena pages neither allocated nor reserved at submit time
        free_pages: usize,
    },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { queue_cap } => {
                write!(f, "admission queue full (queue-cap {queue_cap})")
            }
            Rejected::DeadlineInfeasible { deadline_steps, min_steps } => write!(
                f,
                "deadline of {deadline_steps} steps infeasible (needs at least {min_steps})"
            ),
            Rejected::KvExhausted { needed_pages, free_pages } => write!(
                f,
                "kv arena exhausted ({needed_pages} pages needed, {free_pages} free)"
            ),
        }
    }
}

/// Typed result of [`Engine::try_submit`]: admitted into the queue, or
/// shed with a [`Rejected`] reason.
pub enum SubmitOutcome {
    /// enqueued; the [`Session`] observes progress
    Admitted(Session),
    /// shed at the door — nothing was enqueued
    Rejected(Rejected),
}

impl SubmitOutcome {
    /// The session, if the request was admitted.
    pub fn session(self) -> Option<Session> {
        match self {
            SubmitOutcome::Admitted(s) => Some(s),
            SubmitOutcome::Rejected(_) => None,
        }
    }

    /// The shed reason, if the request was rejected.
    pub fn rejection(&self) -> Option<Rejected> {
        match self {
            SubmitOutcome::Admitted(_) => None,
            SubmitOutcome::Rejected(r) => Some(*r),
        }
    }
}

/// A scheduler progress-contract violation, surfaced by
/// [`Engine::step`] as a recoverable error instead of a panic: a buggy
/// external [`Scheduler`] must not take the serving process (and every
/// other in-flight request) down. The engine's own state stays
/// consistent — queued and active requests are untouched by the failed
/// step and can be cancelled, drained under a replacement scheduler
/// ([`Engine::set_scheduler`]), or retried.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepError {
    /// admission: every slot is free and requests are queued, but
    /// `admit` returned `None` — an idle engine cannot progress any
    /// other way
    AdmissionStalled {
        /// the offending scheduler's name
        scheduler: &'static str,
        /// requests waiting in the queue
        queued: usize,
    },
    /// allocation: active slots exist, none finished, none paused by
    /// backpressure, yet the chosen set advanced nothing — the engine
    /// would spin forever
    AllocationStalled {
        /// the offending scheduler's name
        scheduler: &'static str,
        /// active slots at the time of the stall
        active: usize,
    },
    /// `admit` returned an index past the end of the queue view
    BadQueueIndex {
        /// the offending scheduler's name
        scheduler: &'static str,
        /// the out-of-range index
        index: usize,
        /// the queue view length it had to pick from
        len: usize,
    },
    /// `allocate` returned a slot index past the end of the active set
    BadSlotIndex {
        /// the offending scheduler's name
        scheduler: &'static str,
        /// the out-of-range index
        index: usize,
        /// the active-slot count it had to pick from
        len: usize,
    },
    /// `allocate` returned more slots than the step budget allows
    OverBudget {
        /// the offending scheduler's name
        scheduler: &'static str,
        /// distinct slots the scheduler tried to allocate
        allocated: usize,
        /// the step budget in force
        budget: usize,
    },
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StepError::AdmissionStalled { scheduler, queued } => write!(
                f,
                "scheduler {scheduler} stalled: empty slots but {queued} queued requests"
            ),
            StepError::AllocationStalled { scheduler, active } => write!(
                f,
                "scheduler {scheduler} stalled: allocated no decodable slot out of {active} active"
            ),
            StepError::BadQueueIndex { scheduler, index, len } => write!(
                f,
                "scheduler {scheduler} admitted out-of-range queue index {index} (len {len})"
            ),
            StepError::BadSlotIndex { scheduler, index, len } => write!(
                f,
                "scheduler {scheduler} allocated out-of-range slot {index} (len {len})"
            ),
            StepError::OverBudget { scheduler, allocated, budget } => write!(
                f,
                "scheduler {scheduler} allocated {allocated} slots over budget {budget}"
            ),
        }
    }
}

impl std::error::Error for StepError {}

impl From<StepError> for Error {
    fn from(e: StepError) -> Error {
        Error::msg(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// engine core

struct QueueEntry {
    req: GenRequest,
    arrival: u64,
    enqueued: Instant,
    submit_step: u64,
    session: Rc<RefCell<SessionShared>>,
}

struct Slot {
    id: u64,
    arrival: u64,
    prompt_len: usize,
    max_new: usize,
    enqueued: Instant,
    submit_step: u64,
    deadline_steps: usize,
    queue_wait_s: f64,
    idle_steps: usize,
    /// sink reported `Blocked`: skip allocation, re-poll each step
    paused: bool,
    /// sink reported `Closed`: cancel at the next resolution point
    closed: bool,
    seq: SeqState,
    session: Rc<RefCell<SessionShared>>,
}

impl Slot {
    fn generated(&self) -> usize {
        self.seq.tokens.len() - self.prompt_len
    }

    fn remaining(&self) -> usize {
        self.max_new - self.generated()
    }

    /// Prompt tokens of the *initial* context window not yet covered by
    /// the KV cache — the amount chunked prefill still has to forward
    /// before this slot can emit its first token. Zero once the first
    /// token has been generated: the sliding-window regime re-prefills
    /// whole windows inside the decode policy, which must stay a single
    /// per-step forward to keep token identity with unchunked engines.
    fn prefill_pending(&self) -> usize {
        if self.generated() > 0 {
            return 0;
        }
        let ws = self.seq.tokens.len().saturating_sub(self.seq.max_ctx);
        (self.seq.tokens.len() - ws).saturating_sub(self.seq.cache.len())
    }

    /// Stream `toks` to the session, stamping first-token timing (wall
    /// clock and the deterministic step count) on the first emission.
    /// The sink's per-token status drives backpressure: `Blocked`
    /// pauses this slot's allocation (a later `Ready` in the same batch
    /// un-pauses), `Closed` stops delivery and marks the slot for
    /// cancellation. Tokens already decoded this step always reach the
    /// session's `streamed` buffer — the status only steers future
    /// scheduling.
    fn emit(&mut self, toks: &[u8], step_no: u64) {
        let mut sess = self.session.borrow_mut();
        if sess.ttft_s.is_none() && !toks.is_empty() {
            sess.ttft_s = Some(self.enqueued.elapsed().as_secs_f64());
            sess.ttft_steps = Some((step_no - self.submit_step) as usize + 1);
        }
        for &t in toks {
            sess.streamed.push(t);
            if self.closed {
                continue;
            }
            if let Some(sink) = sess.sink.as_mut() {
                match sink.on_token(t) {
                    SinkStatus::Ready => self.paused = false,
                    SinkStatus::Blocked => self.paused = true,
                    SinkStatus::Closed => {
                        self.paused = false;
                        self.closed = true;
                    }
                }
            }
        }
    }

    /// Whether this slot's deadline has passed at engine step `step_no`.
    fn overdue(&self, step_no: u64) -> bool {
        self.deadline_steps > 0 && (step_no - self.submit_step) as usize >= self.deadline_steps
    }

    /// Build the final response, consuming the token buffer. `step_no`
    /// is the engine's step counter at retirement, the fallback for the
    /// step-count TTFT of requests that never emitted a token.
    fn finish(&mut self, step_no: u64, outcome: Outcome) -> GenResponse {
        let generated = self.generated();
        let latency_s = self.enqueued.elapsed().as_secs_f64();
        let tokens = std::mem::take(&mut self.seq.tokens);
        let sess = self.session.borrow();
        let ttft_s = sess.ttft_s.unwrap_or(latency_s);
        let ttft_steps = sess.ttft_steps.unwrap_or((step_no - self.submit_step) as usize);
        let queue_wait_steps = sess.queue_wait_steps.unwrap_or(0);
        drop(sess);
        GenResponse {
            id: self.id,
            output: tokens[self.prompt_len..].to_vec(),
            latency_s,
            tokens_generated: generated,
            ttft_s,
            queue_wait_s: self.queue_wait_s,
            ttft_steps,
            queue_wait_steps,
            total_steps: (step_no - self.submit_step) as usize,
            outcome,
        }
    }

    /// Terminally resolve this slot: build the response, publish it on
    /// the session, and drop the sink (it can never fire again). The
    /// caller removes the slot from the active set, which frees its KV.
    /// Shared by normal retirement, deadline expiry, sink-closed
    /// cancellation, and [`Engine::cancel`].
    fn resolve(&mut self, step_no: u64, outcome: Outcome) -> GenResponse {
        let resp = self.finish(step_no, outcome);
        let mut sess = self.session.borrow_mut();
        sess.response = Some(resp.clone());
        sess.sink = None;
        resp
    }
}

/// How [`Engine::step`] executes the allocated slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// One forward per slot per step — the PR 5 loop, kept as the
    /// reference the batched mode is pinned token-identical against.
    PerSlot,
    /// ONE ragged batched forward across every staged slot per step (the
    /// default): same tokens, fewer weight passes — a fused-VQ backend
    /// decodes each linear once per step instead of once per slot.
    Batched,
}

/// Backend-agnostic engine internals, shared by [`Engine`] (which owns
/// its backend) and the deprecated `ContinuousBatcher` shim (which
/// borrows one per call).
pub(crate) struct Core {
    pub(crate) max_batch: usize,
    pub(crate) step_budget: usize,
    pub(crate) step_mode: StepMode,
    pub(crate) prefill_chunk: usize,
    pub(crate) queue_cap: usize,
    /// shared paged-KV arena; `None` = contiguous per-slot caches (the
    /// legacy path and the default)
    pub(crate) kv_pool: Option<Rc<RefCell<KvPool>>>,
    /// rows per KV page (`0` = paging off); with `kv_pages` and
    /// `kv_store` this re-derives `kv_pool` whenever a builder changes one
    pub(crate) kv_page: usize,
    /// arena capacity in pages (`0` = unbounded)
    pub(crate) kv_pages: usize,
    /// page storage format for the arena
    pub(crate) kv_store: KvStoreKind,
    pub(crate) scheduler: Box<dyn Scheduler>,
    pub(crate) policy: Box<dyn DecodePolicy>,
    queue: Vec<QueueEntry>,
    active: Vec<Slot>,
    /// responses resolved by a step that then failed its progress
    /// contract — re-delivered by the next successful step so no
    /// terminal resolution is ever dropped
    carry: Vec<GenResponse>,
    arrivals: u64,
    step_no: u64,
    steps_decoded: usize,
    decode_calls: usize,
    tokens_decoded: usize,
    prefill_chunks: usize,
}

impl Core {
    pub(crate) fn new(
        max_batch: usize,
        scheduler: Box<dyn Scheduler>,
        policy: Box<dyn DecodePolicy>,
    ) -> Core {
        Core {
            max_batch: max_batch.max(1),
            step_budget: 0,
            step_mode: StepMode::Batched,
            prefill_chunk: 0,
            queue_cap: 0,
            kv_pool: None,
            kv_page: 0,
            kv_pages: 0,
            kv_store: KvStoreKind::F64Dense,
            scheduler,
            policy,
            queue: Vec::new(),
            active: Vec::new(),
            carry: Vec::new(),
            arrivals: 0,
            step_no: 0,
            steps_decoded: 0,
            decode_calls: 0,
            tokens_decoded: 0,
            prefill_chunks: 0,
        }
    }

    /// Snapshot of the monotonic decode counters — `[steps_decoded,
    /// decode_calls, tokens_decoded, prefill_chunks, spec_drafted,
    /// spec_accepted]`. The delta of two snapshots scopes a
    /// [`ServeStats`] measurement window (see the loadgen driver).
    pub(crate) fn counters(&self) -> [usize; 6] {
        let (drafted, accepted) = self.policy.spec_counters().unwrap_or((0, 0));
        [
            self.steps_decoded,
            self.decode_calls,
            self.tokens_decoded,
            self.prefill_chunks,
            drafted,
            accepted,
        ]
    }

    /// The minimum engine steps a request of this shape can possibly
    /// take on this engine: chunked prefill alone needs
    /// `ceil(window / chunk)` steps before the first token can exist
    /// (the decode policy may then emit many tokens per step, so this
    /// is a policy-agnostic lower bound, never an over-estimate).
    fn min_steps(&self, req: &GenRequest, max_ctx: usize) -> usize {
        if req.max_new_tokens == 0 || self.prefill_chunk == 0 {
            return 1;
        }
        req.prompt.len().min(max_ctx).div_ceil(self.prefill_chunk).max(1)
    }

    /// Worst-case KV rows a request of this shape can ever hold: its
    /// full context (prompt + decode budget) clamped to the model's
    /// window — the sliding-window regime never caches more rows than
    /// `max_ctx` (chunked prefill grows toward it, decode re-prefills
    /// whole windows past it). This is the row count a paged admission
    /// reserves pages for.
    fn kv_rows(req: &GenRequest, max_ctx: usize) -> usize {
        (req.prompt.len() + req.max_new_tokens).min(max_ctx)
    }

    pub(crate) fn submit(
        &mut self,
        req: GenRequest,
        sink: Option<Box<dyn TokenSink>>,
        max_ctx: usize,
    ) -> Result<SubmitOutcome> {
        // reject bad input at submit: an empty prompt would only panic
        // mid-step inside the forward pass, taking every other in-flight
        // request in this engine down with it
        if req.prompt.is_empty() {
            return Err(Error::msg(format!(
                "request {}: empty prompt (the byte LM needs at least one context token)",
                req.id
            )));
        }
        // admission policy: shed rather than grow without bound. Both
        // checks are pure functions of queue length and request shape —
        // deterministic step-time state — so identically-seeded traffic
        // sheds identically run-to-run.
        if self.queue_cap > 0 && self.queue.len() >= self.queue_cap {
            return Ok(SubmitOutcome::Rejected(Rejected::QueueFull { queue_cap: self.queue_cap }));
        }
        let min_steps = self.min_steps(&req, max_ctx);
        if req.deadline_steps > 0 && req.deadline_steps < min_steps {
            return Ok(SubmitOutcome::Rejected(Rejected::DeadlineInfeasible {
                deadline_steps: req.deadline_steps,
                min_steps,
            }));
        }
        // page-domain feasibility: with a bounded paged arena, the
        // request's worst-case page reservation must fit on top of the
        // reservations the queue already lays claim to. Shedding at
        // submit keeps the invariant `free_pages >= queued demand`
        // (admission moves a request's demand from queue to reservation
        // one-for-one; retirement only grows the free list), so a
        // scheduler pick can always take its reservation — the arena
        // never stalls admission. Like the other admission checks this
        // is a pure function of deterministic step-time state, so
        // identically-seeded traffic sheds identically run-to-run.
        if let Some(pool) = &self.kv_pool {
            let p = pool.borrow();
            if p.capacity_pages() != usize::MAX {
                let needed = p.pages_for_rows(Core::kv_rows(&req, max_ctx));
                let queued_demand: usize = self
                    .queue
                    .iter()
                    .map(|q| p.pages_for_rows(Core::kv_rows(&q.req, max_ctx)))
                    .sum();
                let free = p.free_pages();
                if needed > p.capacity_pages() || free < queued_demand + needed {
                    return Ok(SubmitOutcome::Rejected(Rejected::KvExhausted {
                        needed_pages: needed,
                        free_pages: free,
                    }));
                }
            }
        }
        let session = Rc::new(RefCell::new(SessionShared {
            id: req.id,
            streamed: Vec::new(),
            ttft_s: None,
            queue_wait_s: None,
            ttft_steps: None,
            queue_wait_steps: None,
            response: None,
            sink,
        }));
        self.queue.push(QueueEntry {
            req,
            arrival: self.arrivals,
            // detlint: allow(wall-clock, admission timestamp feeds queue-wait percentiles only; scheduling is arrival-order/aging on step counts)
            enqueued: Instant::now(),
            submit_step: self.step_no,
            session: Rc::clone(&session),
        });
        self.arrivals += 1;
        Ok(SubmitOutcome::Admitted(Session { inner: session }))
    }

    pub(crate) fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub(crate) fn queued(&self) -> usize {
        self.queue.len()
    }

    pub(crate) fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Terminally resolve a still-queued entry (deadline expiry or
    /// cancellation): empty output, queue wait equal to full latency.
    fn resolve_queued(q: QueueEntry, step_no: u64, outcome: Outcome) -> GenResponse {
        let latency_s = q.enqueued.elapsed().as_secs_f64();
        let waited = (step_no - q.submit_step) as usize;
        let resp = GenResponse {
            id: q.req.id,
            output: Vec::new(),
            latency_s,
            tokens_generated: 0,
            ttft_s: latency_s,
            queue_wait_s: latency_s,
            ttft_steps: waited,
            queue_wait_steps: waited,
            total_steps: waited,
            outcome,
        };
        let mut sess = q.session.borrow_mut();
        sess.response = Some(resp.clone());
        sess.sink = None;
        resp
    }

    pub(crate) fn step(
        &mut self,
        backend: &ServeBackend,
    ) -> std::result::Result<Vec<GenResponse>, StepError> {
        fn queued_overdue(q: &QueueEntry, step_no: u64) -> bool {
            q.req.deadline_steps > 0
                && (step_no - q.submit_step) as usize >= q.req.deadline_steps
        }
        // responses resolved before the scheduler runs (backpressure
        // cancellations, deadline expiry) plus any carried over from a
        // previous step that failed its progress contract
        let mut done = std::mem::take(&mut self.carry);
        let step_no = self.step_no;
        // detlint: hot(engine-admission) — the backpressure poll, the
        // deadline sweep, and the admission decision loop run every
        // engine step under backlog; keep them allocation-free (the
        // batched queue compaction allocates only on steps that admit)

        // ---- backpressure: re-poll paused sinks, in step-time ----
        self.active.retain_mut(|slot| {
            if !slot.paused {
                return true;
            }
            let mut sess = slot.session.borrow_mut();
            let st = sess.sink.as_mut().map_or(SinkStatus::Ready, |s| s.poll());
            drop(sess);
            match st {
                SinkStatus::Ready => {
                    slot.paused = false;
                    true
                }
                SinkStatus::Blocked => true,
                SinkStatus::Closed => {
                    done.push(slot.resolve(step_no, Outcome::Cancelled));
                    false
                }
            }
        });

        // ---- deadlines: expire overdue requests before admission so a
        // freed slot readmits this very step. Expiry reuses the cancel
        // machinery (resolve + remove from the active set), so the slot
        // and its KV caches return immediately ----
        self.active.retain_mut(|slot| {
            if !slot.overdue(step_no) {
                return true;
            }
            done.push(slot.resolve(step_no, Outcome::Expired));
            false
        });
        if self.queue.iter().any(|q| queued_overdue(q, step_no)) {
            let mut kept: Vec<QueueEntry> = Vec::with_capacity(self.queue.len());
            for q in self.queue.drain(..) {
                if queued_overdue(&q, step_no) {
                    done.push(Core::resolve_queued(q, step_no, Outcome::Expired));
                } else {
                    kept.push(q);
                }
            }
            self.queue = kept;
        }

        // ---- admission: the scheduler fills free slots from the
        // queue. The decision loop runs over a lightweight view list
        // (the scheduler sees exactly the shrinking sequence the old
        // remove-per-admit code showed it); the fat QueueEntry vec is
        // compacted ONCE per step — O(queue) total where removing each
        // admitted entry in place went quadratic under deep backlogs ----
        if self.active.len() < self.max_batch && !self.queue.is_empty() {
            let max_ctx = backend.model().cfg.max_seq;
            let free_pages =
                self.kv_pool.as_ref().map_or(usize::MAX, |p| p.borrow().free_pages());
            let mut views: Vec<QueuedView> = Vec::with_capacity(self.queue.len());
            for q in &self.queue {
                views.push(QueuedView {
                    id: q.req.id,
                    arrival: q.arrival,
                    prompt_len: q.req.prompt.len(),
                    max_new: q.req.max_new_tokens,
                    waited_steps: (step_no - q.submit_step) as usize,
                    free_pages,
                });
            }
            // vmap tracks view position -> queue index across removals
            let mut vmap: Vec<usize> = Vec::with_capacity(self.queue.len());
            vmap.extend(0..self.queue.len());
            let mut picks: Vec<usize> =
                Vec::with_capacity(self.max_batch - self.active.len());
            // paged backings allocated per pick, aligned with `picks`;
            // `None` entries mean the contiguous (non-pooled) path
            let mut backings: Vec<Option<PagedKvCache>> =
                Vec::with_capacity(self.max_batch - self.active.len());
            while self.active.len() + picks.len() < self.max_batch && !views.is_empty() {
                let Some(i) = self.scheduler.admit(&views) else { break };
                if i >= views.len() {
                    self.carry = done;
                    return Err(StepError::BadQueueIndex {
                        scheduler: self.scheduler.name(),
                        index: i,
                        len: views.len(),
                    });
                }
                // page-domain admission: a paged engine takes the pick's
                // worst-case reservation NOW, before the entry leaves the
                // queue. The submit-time feasibility invariant
                // (`free_pages >= queued demand`) makes the `None` arm
                // unreachable for a bounded arena — it is kept as a
                // defensive stop (entry stays queued, admission ends for
                // this step) rather than an assert so an accounting bug
                // degrades to queueing instead of a panic.
                let backing = match &self.kv_pool {
                    Some(pool) => {
                        let rows = Core::kv_rows(&self.queue[vmap[i]].req, max_ctx);
                        match PagedKvCache::new(pool, rows) {
                            Some(paged) => Some(paged),
                            None => break,
                        }
                    }
                    None => None,
                };
                backings.push(backing);
                views.remove(i);
                picks.push(vmap.remove(i));
            }
            if !picks.is_empty() {
                // batched compaction: one pass extracts the picked
                // entries (slots created in pick order = admission
                // order) and rebuilds the queue in stable order
                let mut taken: Vec<Option<QueueEntry>> =
                    Vec::with_capacity(self.queue.len());
                taken.extend(self.queue.drain(..).map(Some));
                for (pi, &qi) in picks.iter().enumerate() {
                    let q = taken[qi].take().expect("admission picks are distinct");
                    let queue_wait_s = q.enqueued.elapsed().as_secs_f64();
                    {
                        let mut sess = q.session.borrow_mut();
                        sess.queue_wait_s = Some(queue_wait_s);
                        sess.queue_wait_steps = Some((step_no - q.submit_step) as usize);
                    }
                    let backing = match backings[pi].take() {
                        Some(paged) => KvBacking::Paged(paged),
                        None => KvBacking::contiguous(&backend.model().cfg),
                    };
                    self.active.push(Slot {
                        id: q.req.id,
                        arrival: q.arrival,
                        prompt_len: q.req.prompt.len(),
                        max_new: q.req.max_new_tokens,
                        enqueued: q.enqueued,
                        submit_step: q.submit_step,
                        deadline_steps: q.req.deadline_steps,
                        queue_wait_s,
                        idle_steps: 0,
                        paused: false,
                        closed: false,
                        seq: SeqState::with_backing(&backend.model().cfg, &q.req.prompt, backing),
                        session: q.session,
                    });
                }
                self.queue.extend(taken.into_iter().flatten());
            }
        }
        // detlint: endhot

        // progress contract: free slots + a non-empty queue must admit.
        // Returned as a recoverable error — a buggy external scheduler
        // must not panic the serving process; the failed step mutated
        // nothing (queue and slots are exactly as submitted), so the
        // caller can cancel, swap the scheduler, or retry. When this
        // step already resolved responses (expiry/backpressure above),
        // they ride out first and the stall resurfaces next step.
        if self.active.is_empty() && !self.queue.is_empty() && done.is_empty() {
            return Err(StepError::AdmissionStalled {
                scheduler: self.scheduler.name(),
                queued: self.queue.len(),
            });
        }

        // ---- allocation + decode ----
        if !self.active.is_empty() {
            let budget = if self.step_budget == 0 {
                self.active.len()
            } else {
                self.step_budget.min(self.active.len())
            };
            let free_pages =
                self.kv_pool.as_ref().map_or(usize::MAX, |p| p.borrow().free_pages());
            let views: Vec<SlotView> = self
                .active
                .iter()
                .map(|s| SlotView {
                    id: s.id,
                    arrival: s.arrival,
                    generated: s.generated(),
                    remaining: s.remaining(),
                    idle_steps: s.idle_steps,
                    prefill_pending: s.prefill_pending(),
                    free_pages,
                })
                .collect();
            let mut chosen = self.scheduler.allocate(&views, budget);
            chosen.sort_unstable();
            chosen.dedup();
            if let Some(&hi) = chosen.last() {
                if hi >= self.active.len() {
                    self.carry = done;
                    return Err(StepError::BadSlotIndex {
                        scheduler: self.scheduler.name(),
                        index: hi,
                        len: self.active.len(),
                    });
                }
            }
            if chosen.len() > budget {
                self.carry = done;
                return Err(StepError::OverBudget {
                    scheduler: self.scheduler.name(),
                    allocated: chosen.len(),
                    budget,
                });
            }
            // backpressure: a paused slot is never decoded, whatever
            // the scheduler chose (its allocation is simply forfeited
            // this step — the slot keeps its KV and resumes on `Ready`)
            chosen.retain(|&i| !self.active[i].paused);
            let progressed = match self.step_mode {
                StepMode::PerSlot => self.step_per_slot(backend, &chosen),
                StepMode::Batched => self.step_batched(backend, &chosen),
            };
            // progress contract, allocation side: with active slots the
            // step must advance something (a token or a prefill chunk),
            // retire something (a zero-remaining slot), or be
            // legitimately held up by sink backpressure — anything else
            // would spin forever
            let idle_ok =
                self.active.iter().any(|s| s.remaining() == 0 || s.paused || s.closed);
            if !progressed && !idle_ok {
                self.carry = done;
                return Err(StepError::AllocationStalled {
                    scheduler: self.scheduler.name(),
                    active: self.active.len(),
                });
            }
            // idle accounting feeds round-robin fairness and SRPT aging
            for (i, slot) in self.active.iter_mut().enumerate() {
                if chosen.binary_search(&i).is_ok() {
                    slot.idle_steps = 0;
                } else {
                    slot.idle_steps += 1;
                }
            }
            if progressed {
                self.steps_decoded += 1;
            }
        }
        self.step_no += 1;

        // ---- retirement: one in-place retain pass, admission order.
        // A slot whose sink closed mid-emission cancels here, the same
        // step, so its KV never survives into the next batch ----
        let step_no = self.step_no;
        self.active.retain_mut(|slot| {
            let completed = slot.generated() >= slot.max_new;
            if !completed && !slot.closed {
                return true;
            }
            let outcome = if completed { Outcome::Completed } else { Outcome::Cancelled };
            done.push(slot.resolve(step_no, outcome));
            false
        });
        Ok(done)
    }

    /// The per-slot reference loop: one policy `decode` (one forward)
    /// per allocated slot. A slot still inside chunked prefill forwards
    /// one prompt chunk instead and emits nothing. Returns whether any
    /// slot progressed (a token or a chunk).
    fn step_per_slot(&mut self, backend: &ServeBackend, chosen: &[usize]) -> bool {
        let step_no = self.step_no;
        let prefill_chunk = self.prefill_chunk;
        let Core { policy, active, decode_calls, tokens_decoded, prefill_chunks, .. } = self;
        let mut progressed = false;
        // detlint: hot(engine-step) — per-slot decode dispatch runs every
        // engine step at serving concurrency; keep it allocation-free
        for &i in chosen {
            // out-of-range indices became a typed StepError in `step`
            // before decode dispatch, so this cannot fire
            debug_assert!(i < active.len(), "scheduler allocated out-of-range slot {i}");
            let slot = &mut active[i];
            let remaining = slot.remaining();
            if remaining == 0 {
                continue; // zero-budget request, retires below untouched
            }
            if prefill_chunk > 0 {
                slot.seq.sync_window();
                if slot.prefill_pending() > prefill_chunk {
                    // pure prefill: extend the KV cache by one chunk of
                    // prompt tokens, emit nothing this step
                    let new0 = slot.seq.window_start + slot.seq.cache.len();
                    let chunk = &slot.seq.tokens[new0..new0 + prefill_chunk];
                    forward_logits_cached_with(backend.model(), backend, &mut slot.seq.cache, chunk);
                    *decode_calls += 1;
                    *prefill_chunks += 1;
                    progressed = true;
                    continue;
                }
            }
            let toks = policy.decode(backend, &mut slot.seq, remaining);
            // hard contract (like the scheduler stall asserts): a
            // policy emitting nothing would spin the engine forever
            assert!(
                !toks.is_empty() && toks.len() <= remaining,
                "decode policy {} emitted {} tokens with {remaining} remaining",
                policy.name(),
                toks.len()
            );
            debug_assert_eq!(
                slot.seq.tokens.len() - slot.prompt_len,
                slot.max_new - remaining + toks.len(),
                "decode policy desynced the token stream"
            );
            slot.emit(&toks, step_no);
            *decode_calls += 1;
            *tokens_decoded += toks.len();
            progressed = true;
        }
        // detlint: endhot
        progressed
    }

    /// The batched step: stage every allocated slot (a prefill chunk or
    /// a policy [`BatchPlan`]), run ALL staged inputs through ONE ragged
    /// batched forward — one `decode_call`, one weight pass — then
    /// commit each slot's tokens from its own logit rows. Slots whose
    /// policy opts out of planning fall back to per-slot `decode` calls
    /// after the batch, so external policies keep working. Token
    /// streams are identical to [`Core::step_per_slot`] because the
    /// batched forward computes each item's rows bitwise equal to a
    /// dedicated forward and the policies' plan/finish split is the
    /// same code their `decode` runs. Returns whether any slot
    /// progressed.
    fn step_batched(&mut self, backend: &ServeBackend, chosen: &[usize]) -> bool {
        enum Work {
            /// pure prefill: forward n prompt tokens, emit nothing
            Chunk(usize),
            /// policy-staged forward input, committed via `finish`
            Plan(BatchPlan),
            /// policy opted out of planning: per-slot decode below
            Fallback,
        }
        let step_no = self.step_no;
        let prefill_chunk = self.prefill_chunk;
        let Core { policy, active, decode_calls, tokens_decoded, prefill_chunks, .. } = self;

        // ---- stage: decide per slot what joins the batch (slot order:
        // `chosen` is sorted, so plans run in the same order the
        // per-slot loop would decode) ----
        let mut work: Vec<(usize, Work)> = Vec::with_capacity(chosen.len());
        for &i in chosen {
            // pre-validated in `step` (typed BadSlotIndex error)
            debug_assert!(i < active.len(), "scheduler allocated out-of-range slot {i}");
            let slot = &mut active[i];
            let remaining = slot.remaining();
            if remaining == 0 {
                continue; // zero-budget request, retires below untouched
            }
            if prefill_chunk > 0 {
                slot.seq.sync_window();
                if slot.prefill_pending() > prefill_chunk {
                    work.push((i, Work::Chunk(prefill_chunk)));
                    continue;
                }
            }
            match policy.plan(backend, &mut slot.seq, remaining) {
                Some(p) => work.push((i, Work::Plan(p))),
                None => work.push((i, Work::Fallback)),
            }
        }

        // ---- forward: every staged slot's input in ONE ragged batch;
        // item rows line up with `work` order (ascending slot index) ----
        let mut items: Vec<BatchItem<'_, KvBacking>> = Vec::with_capacity(work.len());
        let mut wi = 0;
        for (si, slot) in active.iter_mut().enumerate() {
            if wi >= work.len() {
                break;
            }
            if work[wi].0 != si {
                continue;
            }
            let (_, w) = &work[wi];
            wi += 1;
            let seq = &mut slot.seq;
            match w {
                Work::Chunk(n) => {
                    let new0 = seq.window_start + seq.cache.len();
                    items.push(BatchItem {
                        cache: &mut seq.cache,
                        tokens: &seq.tokens[new0..new0 + n],
                    });
                }
                Work::Plan(p) => {
                    items.push(BatchItem { cache: &mut seq.cache, tokens: &p.input });
                }
                Work::Fallback => {}
            }
        }
        let logits = if items.is_empty() {
            None
        } else {
            *decode_calls += 1;
            Some(forward_logits_batched_with(backend.model(), backend, &mut items))
        };
        drop(items);

        // ---- commit: hand each staged slot its logit rows, in order ----
        let mut progressed = false;
        let mut row0 = 0usize;
        // detlint: hot(engine-step-batched) — the batched commit loop runs
        // every engine step at serving concurrency; keep it allocation-free
        for (i, w) in &work {
            let slot = &mut active[*i];
            let remaining = slot.remaining();
            match w {
                Work::Chunk(n) => {
                    row0 += n;
                    *prefill_chunks += 1;
                    progressed = true;
                }
                Work::Plan(p) => {
                    let l = logits.as_ref().expect("planned slots imply a batched forward");
                    let toks = policy.finish(&mut slot.seq, p, l, row0);
                    row0 += p.input.len();
                    assert!(
                        !toks.is_empty() && toks.len() <= remaining,
                        "decode policy {} emitted {} tokens with {remaining} remaining",
                        policy.name(),
                        toks.len()
                    );
                    debug_assert_eq!(
                        slot.seq.tokens.len() - slot.prompt_len,
                        slot.max_new - remaining + toks.len(),
                        "decode policy desynced the token stream"
                    );
                    slot.emit(&toks, step_no);
                    *tokens_decoded += toks.len();
                    progressed = true;
                }
                Work::Fallback => {
                    let toks = policy.decode(backend, &mut slot.seq, remaining);
                    assert!(
                        !toks.is_empty() && toks.len() <= remaining,
                        "decode policy {} emitted {} tokens with {remaining} remaining",
                        policy.name(),
                        toks.len()
                    );
                    debug_assert_eq!(
                        slot.seq.tokens.len() - slot.prompt_len,
                        slot.max_new - remaining + toks.len(),
                        "decode policy desynced the token stream"
                    );
                    slot.emit(&toks, step_no);
                    *decode_calls += 1;
                    *tokens_decoded += toks.len();
                    progressed = true;
                }
            }
        }
        // detlint: endhot
        progressed
    }

    /// Cancel a request by id. A still-queued request retires with an
    /// empty response; an active one retires immediately with its
    /// partial output, freeing the slot (and its KV caches) this
    /// instant — the next step batches without it. Returns the
    /// response, or `None` for an id that is unknown or already
    /// finished.
    pub(crate) fn cancel(&mut self, id: u64) -> Option<GenResponse> {
        if let Some(qi) = self.queue.iter().position(|q| q.req.id == id) {
            // rare path — plain remove is fine here; the per-step
            // admission loop is where removal cost compounds
            let q = self.queue.remove(qi);
            return Some(Core::resolve_queued(q, self.step_no, Outcome::Cancelled));
        }
        if let Some(si) = self.active.iter().position(|s| s.id == id) {
            let mut slot = self.active.remove(si);
            return Some(slot.resolve(self.step_no, Outcome::Cancelled));
        }
        None
    }

    pub(crate) fn run_to_completion(&mut self, backend: &ServeBackend) -> Result<ServeStats> {
        let mut stats = ServeStats::default();
        let steps0 = self.steps_decoded;
        let calls0 = self.decode_calls;
        let toks0 = self.tokens_decoded;
        let chunks0 = self.prefill_chunks;
        let clock0 = self.step_no;
        let (drafted0, accepted0) = self.policy.spec_counters().unwrap_or((0, 0));
        // detlint: allow(wall-clock, TTFT/latency measurement for ServeStats; token output is timing-independent by the determinism rule)
        let t0 = Instant::now();
        while self.pending() > 0 {
            for resp in self.step(backend)? {
                stats.record(&resp);
            }
        }
        stats.total_seconds = t0.elapsed().as_secs_f64();
        stats.clock_steps = (self.step_no - clock0) as usize;
        stats.engine_steps = self.steps_decoded - steps0;
        stats.decode_calls = self.decode_calls - calls0;
        stats.decoded_tokens = self.tokens_decoded - toks0;
        stats.prefill_chunks = self.prefill_chunks - chunks0;
        let (drafted, accepted) = self.policy.spec_counters().unwrap_or((0, 0));
        stats.spec_drafted = drafted - drafted0;
        stats.spec_accepted = accepted - accepted0;
        Ok(stats)
    }
}

// ---------------------------------------------------------------------------
// the engine

/// The serving engine: owns a [`ServeBackend`], a [`Scheduler`], and a
/// [`DecodePolicy`]; turns submitted [`GenRequest`]s into [`Session`]s
/// and steps them to completion. The default configuration — [`Fifo`]
/// admission, [`OneToken`] decode, unlimited step budget — reproduces the
/// legacy `ContinuousBatcher` schedule bit-for-bit.
pub struct Engine {
    backend: ServeBackend,
    core: Core,
}

impl Engine {
    /// Engine over `backend` with up to `max_batch` concurrent decode
    /// slots, FIFO admission, and one-token decode.
    pub fn new(backend: ServeBackend, max_batch: usize) -> Engine {
        Engine { backend, core: Core::new(max_batch, Box::new(Fifo::new()), Box::new(OneToken::new())) }
    }

    /// Replace the scheduling policy (admission + slot allocation).
    pub fn with_scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Engine {
        self.core.scheduler = scheduler;
        self
    }

    /// Replace the scheduler on a live engine — the recovery half of the
    /// typed progress-contract errors: after [`Engine::step`] returns a
    /// [`StepError`] naming a misbehaving scheduler, swap in a sound one
    /// and keep serving; queued and active requests carry over untouched.
    pub fn set_scheduler(&mut self, scheduler: Box<dyn Scheduler>) {
        self.core.scheduler = scheduler;
    }

    /// Bound the admission queue: once `cap` requests are waiting,
    /// further submissions are shed with [`Rejected::QueueFull`] instead
    /// of growing the queue without bound (`0` = unbounded, the default
    /// and the legacy behavior). Active slots do not count against the
    /// cap — it bounds memory held by requests the engine has not yet
    /// started, which is exactly what grows without limit under overload.
    pub fn with_queue_cap(mut self, cap: usize) -> Engine {
        self.core.queue_cap = cap;
        self
    }

    /// Replace the decode policy. Fails if the policy cannot attach to
    /// this backend (e.g. decoding a draft model from the container).
    pub fn with_decode(mut self, mut policy: Box<dyn DecodePolicy>) -> Result<Engine> {
        policy.attach(&self.backend)?;
        self.core.policy = policy;
        Ok(self)
    }

    /// Cap the number of slots decoded per step (`0` = all active slots,
    /// the default). A budget below `max_batch` is where [`Scheduler`]
    /// allocation policies differ. A slot spending its allocation on a
    /// prefill chunk charges the budget exactly like a decoding slot.
    pub fn with_step_budget(mut self, budget: usize) -> Engine {
        self.core.step_budget = budget;
        self
    }

    /// Select how allocated slots execute per step (default
    /// [`StepMode::Batched`]). [`StepMode::PerSlot`] is the reference
    /// loop, kept for parity harnesses and A/B benches — both modes
    /// emit bitwise-identical token streams.
    pub fn with_step_mode(mut self, mode: StepMode) -> Engine {
        self.core.step_mode = mode;
        self
    }

    /// Admit long prompts in chunks of at most `n` tokens per step
    /// (`0` = whole-prompt prefill, the default). Chunking keeps a long
    /// prompt from monopolizing a step — the KV cache grows by one chunk
    /// per allocated step — and changes step counts and TTFT, never
    /// tokens: the first emitted token is computed over an identical KV
    /// state either way.
    pub fn with_prefill_chunk(mut self, n: usize) -> Engine {
        self.core.prefill_chunk = n;
        self
    }

    /// Route slot KV through a shared paged arena with pages of `rows`
    /// positions per layer (`0` = contiguous per-slot caches, the
    /// default). The dense page store is bitwise token-identical to the
    /// contiguous path at every page size; pages freed by `truncate`,
    /// retirement, expiry, and cancellation return to the arena's free
    /// list for the next admission.
    pub fn with_kv_page(mut self, rows: usize) -> Engine {
        self.core.kv_page = rows;
        self.rebuild_kv_pool();
        self
    }

    /// Bound the paged arena to `cap` pages total (`0` = unbounded, the
    /// default). With a bound, overload is accounted in pages: a submit
    /// whose worst-case footprint cannot fit on top of the queued demand
    /// is shed with [`Rejected::KvExhausted`], and schedulers see the
    /// arena's `free_pages` in their views. Takes effect only together
    /// with [`Engine::with_kv_page`].
    pub fn with_kv_pages(mut self, cap: usize) -> Engine {
        self.core.kv_pages = cap;
        self.rebuild_kv_pool();
        self
    }

    /// Select the arena's page storage format (default
    /// [`KvStoreKind::F64Dense`]). [`KvStoreKind::Int8Group`] holds K/V
    /// rows group-quantized to int8 — ≥ 4× denser — dequantized on the
    /// attention read, with drift bounded by
    /// [`crate::model::kvpool::KV_INT8_NLL_REL_TOL`]. Takes effect only
    /// together with [`Engine::with_kv_page`].
    pub fn with_kv_store(mut self, kind: KvStoreKind) -> Engine {
        self.core.kv_store = kind;
        self.rebuild_kv_pool();
        self
    }

    /// Re-derive the shared arena from the current KV knobs. Called by
    /// each KV builder so the knobs compose in any order; configuring
    /// the pool before any submit means no pages are ever live here.
    fn rebuild_kv_pool(&mut self) {
        self.core.kv_pool = if self.core.kv_page > 0 {
            Some(KvPool::shared(
                &self.backend.model().cfg,
                self.core.kv_page,
                self.core.kv_pages,
                self.core.kv_store,
            ))
        } else {
            None
        };
    }

    /// The shared paged-KV arena, when paging is enabled via
    /// [`Engine::with_kv_page`]. Harnesses audit it after a drain
    /// (free-list balance, page-owner integrity, poison state).
    pub fn kv_pool(&self) -> Option<&Rc<RefCell<KvPool>>> {
        self.core.kv_pool.as_ref()
    }

    /// Snapshot of the arena's page counters, when paging is enabled.
    pub fn kv_stats(&self) -> Option<KvPoolStats> {
        self.core.kv_pool.as_ref().map(|p| p.borrow().stats())
    }

    /// Active step mode.
    pub fn step_mode(&self) -> StepMode {
        self.core.step_mode
    }

    /// The execution backend this engine serves from.
    pub fn backend(&self) -> &ServeBackend {
        &self.backend
    }

    /// Recover the backend (e.g. to rebuild an engine with a different
    /// configuration without re-decoding a container).
    pub fn into_backend(self) -> ServeBackend {
        self.backend
    }

    /// Active scheduler name.
    pub fn scheduler_name(&self) -> &'static str {
        self.core.scheduler.name()
    }

    /// Active decode-policy name.
    pub fn policy_name(&self) -> &'static str {
        self.core.policy.name()
    }

    /// Enqueue a request; it is admitted at the next step with a free
    /// slot. The returned [`Session`] observes progress.
    ///
    /// Errors on an empty prompt (the byte LM needs at least one context
    /// token) — rejecting at submit keeps a bad request from panicking a
    /// forward pass mid-step under the engine's other in-flight work —
    /// and on a shed request (queue full / infeasible deadline), folding
    /// [`Rejected`] into the error message. Callers that distinguish
    /// shedding from malformed input use [`Engine::try_submit`]; with the
    /// defaults (no queue cap, no deadline) nothing is ever shed and this
    /// behaves exactly as before overload control existed.
    pub fn submit(&mut self, req: GenRequest) -> Result<Session> {
        match self.try_submit(req)? {
            SubmitOutcome::Admitted(sess) => Ok(sess),
            SubmitOutcome::Rejected(r) => Err(Error::msg(format!("request shed: {r}"))),
        }
    }

    /// [`Engine::submit`] with a [`TokenSink`] invoked on every generated
    /// token as it streams out. The sink's [`SinkStatus`] return drives
    /// token-level backpressure; plain closures return
    /// [`SinkStatus::Ready`] to opt out.
    pub fn submit_with_sink(
        &mut self,
        req: GenRequest,
        sink: Box<dyn TokenSink>,
    ) -> Result<Session> {
        match self.try_submit_with_sink(req, sink)? {
            SubmitOutcome::Admitted(sess) => Ok(sess),
            SubmitOutcome::Rejected(r) => Err(Error::msg(format!("request shed: {r}"))),
        }
    }

    /// Admission-control-aware submit: returns the typed
    /// [`SubmitOutcome`] so a caller under overload can tell a shed
    /// request ([`SubmitOutcome::Rejected`]) from a malformed one
    /// (`Err`) and react — back off, retry later, or drop.
    pub fn try_submit(&mut self, req: GenRequest) -> Result<SubmitOutcome> {
        let max_ctx = self.backend.model().cfg.max_seq;
        self.core.submit(req, None, max_ctx)
    }

    /// [`Engine::try_submit`] with a streaming [`TokenSink`].
    pub fn try_submit_with_sink(
        &mut self,
        req: GenRequest,
        sink: Box<dyn TokenSink>,
    ) -> Result<SubmitOutcome> {
        let max_ctx = self.backend.model().cfg.max_seq;
        self.core.submit(req, Some(sink), max_ctx)
    }

    /// Requests not yet completed (queued + active).
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.core.queued()
    }

    /// Requests currently decoding.
    pub fn active_count(&self) -> usize {
        self.core.active_count()
    }

    /// Engine steps taken so far — the deterministic clock that
    /// deadlines, TTFT-steps, and the loadgen arrival schedule share.
    pub fn steps_elapsed(&self) -> u64 {
        self.core.step_no
    }

    /// Crate-internal view of the core counters, for drivers (the
    /// open-loop load generator) that assemble their own [`ServeStats`].
    pub(crate) fn core_ref(&self) -> &Core {
        &self.core
    }

    /// One engine step: poll paused sinks, expire overdue requests,
    /// admit, decode allocated slots, retire. Returns the responses
    /// resolved this step (admission order; includes expired and
    /// cancelled requests — check [`GenResponse::outcome`]).
    ///
    /// A [`StepError`] means the scheduler violated a progress contract;
    /// the engine's own state stays consistent and serving can resume
    /// after [`Engine::set_scheduler`] or [`Engine::cancel`]. Responses
    /// already resolved by the failed step are carried over and returned
    /// by the next successful step, never dropped.
    pub fn step(&mut self) -> std::result::Result<Vec<GenResponse>, StepError> {
        self.core.step(&self.backend)
    }

    /// Cancel a request by id: a queued request retires with an empty
    /// response, an active one retires immediately with its partial
    /// output and frees its slot and KV caches. Either way the response
    /// carries [`Outcome::Cancelled`]. Returns `None` if the id is
    /// unknown or already finished.
    pub fn cancel(&mut self, id: u64) -> Option<GenResponse> {
        self.core.cancel(id)
    }

    /// Drain queue and slots, accumulating [`ServeStats`] for this run.
    /// Stops with the underlying [`StepError`] (as a crate error) if the
    /// scheduler stalls. Note a sink that stays [`SinkStatus::Blocked`]
    /// forever keeps its request pending forever — drive the engine with
    /// [`Engine::step`] and a step cap when sinks can block indefinitely.
    pub fn run_to_completion(&mut self) -> Result<ServeStats> {
        self.core.run_to_completion(&self.backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;

    fn dense_engine(seed: u64, max_batch: usize) -> Engine {
        Engine::new(ServeBackend::Dense(tiny_model(seed)), max_batch)
    }

    fn drain(engine: &mut Engine) -> Vec<GenResponse> {
        let mut done = Vec::new();
        let mut guard = 0;
        while engine.pending() > 0 {
            done.extend(engine.step().unwrap());
            guard += 1;
            assert!(guard < 10_000, "engine failed to drain");
        }
        done
    }

    #[test]
    fn chunked_prefill_is_token_identical_and_grows_kv_incrementally() {
        // chunk sizes spanning every edge: 1 (one token per step), a
        // non-divisor (3, 7), prompt-1 (19), exactly the prompt (20),
        // and larger than the prompt (64, behaves like unchunked)
        let prompt: Vec<u8> = (0..20).map(|i| (i * 7 + 3) as u8).collect();
        let req = GenRequest::new(0, prompt.clone(), 6);
        let mut base_engine = dense_engine(81, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let base = base_sess.response().unwrap();
        assert_eq!(base.ttft_steps, 1, "unchunked prefill emits at step 1");

        for chunk in [1usize, 3, 7, 19, 20, 64] {
            for mode in [StepMode::PerSlot, StepMode::Batched] {
                let mut e =
                    dense_engine(81, 1).with_step_mode(mode).with_prefill_chunk(chunk);
                let sess = e.submit(req.clone()).unwrap();
                // pin the KV cache growing by exactly one chunk per
                // pure-prefill step
                let mut pure_steps = 0;
                while sess.time_to_first_token_steps().is_none() {
                    e.step().unwrap();
                    if sess.time_to_first_token_steps().is_none() {
                        pure_steps += 1;
                        assert_eq!(
                            e.core.active[0].seq.cache.len(),
                            pure_steps * chunk,
                            "chunk {chunk}: cache must grow chunk-wise"
                        );
                    }
                }
                let expect_ttft = prompt.len().div_ceil(chunk);
                assert_eq!(
                    sess.time_to_first_token_steps(),
                    Some(expect_ttft),
                    "chunk {chunk}: wrong prefill step count"
                );
                drain(&mut e);
                let resp = sess.response().unwrap();
                assert_eq!(resp.output, base.output, "chunk {chunk} changed tokens");
                assert_eq!(resp.tokens_generated, 6);
            }
        }
    }

    #[test]
    fn chunk_boundary_on_the_sliding_window_edge() {
        // prompt length exactly max_ctx (32): chunked prefill must stop
        // exactly at the window edge, and generation then slides the
        // window identically to the unchunked engine
        let edge: Vec<u8> = (0..32).map(|i| (i * 5 + 1) as u8).collect();
        let req = GenRequest::new(0, edge.clone(), 4);
        let mut base_engine = dense_engine(82, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let want = base_sess.response().unwrap();

        let mut e = dense_engine(82, 1).with_prefill_chunk(8);
        let sess = e.submit(req).unwrap();
        e.step().unwrap();
        assert_eq!(e.core.active[0].seq.cache.len(), 8);
        e.step().unwrap();
        e.step().unwrap();
        assert_eq!(e.core.active[0].seq.cache.len(), 24);
        e.step().unwrap(); // final window chunk + first token in one forward
        assert_eq!(sess.time_to_first_token_steps(), Some(4));
        assert_eq!(e.core.active[0].seq.cache.len(), 32, "cache fills the window exactly");
        assert_eq!(e.core.active[0].seq.window_start, 0, "window has not slid yet");
        drain(&mut e);
        assert_eq!(sess.response().unwrap().output, want.output);

        // prompt longer than the window (40 > 32): only the final
        // 32-token window prefills, still chunk-wise
        let long: Vec<u8> = (0..40).map(|i| (i * 3 + 2) as u8).collect();
        let req = GenRequest::new(1, long.clone(), 3);
        let mut base_engine = dense_engine(82, 1);
        let base_sess = base_engine.submit(req.clone()).unwrap();
        drain(&mut base_engine);
        let want = base_sess.response().unwrap();
        let mut e = dense_engine(82, 1).with_prefill_chunk(8);
        let sess = e.submit(req).unwrap();
        e.step().unwrap();
        assert_eq!(e.core.active[0].seq.window_start, 8, "window starts past the prompt head");
        assert_eq!(e.core.active[0].seq.cache.len(), 8);
        drain(&mut e);
        assert_eq!(sess.time_to_first_token_steps(), Some(4), "32-token window / 8 per chunk");
        assert_eq!(sess.response().unwrap().output, want.output);
    }

    #[test]
    fn mid_prefill_cancellation_frees_the_slot_and_keeps_serving() {
        let prompt: Vec<u8> = (0..10).map(|i| (i * 11 + 4) as u8).collect();
        let mut e = dense_engine(83, 1).with_prefill_chunk(2);
        let s0 = e.submit(GenRequest::new(0, prompt, 3)).unwrap();
        let s1 = e.submit(GenRequest::new(1, vec![9, 8, 7], 2)).unwrap();
        e.step().unwrap();
        e.step().unwrap();
        // id 0 is mid-prefill (2 chunks in), id 1 queued behind max_batch 1
        assert_eq!(e.core.active[0].seq.cache.len(), 4);
        assert!(!s0.is_finished());
        assert_eq!(e.queued(), 1);

        let resp = e.cancel(0).expect("active request cancels");
        assert_eq!(resp.tokens_generated, 0);
        assert!(resp.output.is_empty());
        assert!(s0.is_finished(), "cancel resolves the session");
        assert_eq!(e.active_count(), 0, "slot and KV freed immediately");
        assert!(e.cancel(0).is_none(), "double-cancel is a no-op");
        assert!(e.cancel(99).is_none(), "unknown id is a no-op");

        // the engine keeps serving: id 1 admits into the freed slot and
        // completes with the same tokens as an isolated run
        drain(&mut e);
        let mut isolated = dense_engine(83, 1);
        let r = isolated
            .submit(GenRequest::new(1, vec![9, 8, 7], 2))
            .unwrap();
        drain(&mut isolated);
        assert_eq!(s1.response().unwrap().output, r.response().unwrap().output);

        // a request cancelled while still queued retires with an empty
        // response and never occupies a slot
        let mut e2 = dense_engine(83, 1);
        let a = e2.submit(GenRequest::new(5, vec![1, 2], 4)).unwrap();
        let b = e2.submit(GenRequest::new(6, vec![3, 4], 1)).unwrap();
        let resp = e2.cancel(6).expect("queued request cancels");
        assert_eq!(resp.tokens_generated, 0);
        assert!(b.is_finished());
        drain(&mut e2);
        assert_eq!(a.response().unwrap().tokens_generated, 4);
    }

    #[test]
    fn batched_step_counts_one_decode_call_but_n_slot_tokens() {
        // the stats-accounting fix: a batched step is ONE decode call
        // (one forward) emitting N slot-tokens; the per-slot loop stays
        // one call per slot-token. tokens_per_step makes the batching
        // win visible instead of silently reporting it as a no-op.
        let reqs: Vec<GenRequest> = (0..3u8)
            .map(|id| {
                GenRequest::new(
                    id as u64,
                    (0..6).map(|i| (i * 13 + id * 3 + 1) as u8).collect(),
                    4,
                )
            })
            .collect();
        let run_mode = |mode: StepMode, chunk: usize| {
            let mut e = dense_engine(84, 3).with_step_mode(mode).with_prefill_chunk(chunk);
            let sessions: Vec<Session> =
                reqs.iter().map(|r| e.submit(r.clone()).unwrap()).collect();
            let stats = e.run_to_completion().unwrap();
            let out: Vec<(Vec<u8>, usize, usize)> = sessions
                .iter()
                .map(|s| {
                    let r = s.response().unwrap();
                    (r.output, r.ttft_steps, r.queue_wait_steps)
                })
                .collect();
            (stats, out)
        };

        let (b, bo) = run_mode(StepMode::Batched, 0);
        let (p, po) = run_mode(StepMode::PerSlot, 0);
        assert_eq!(bo, po, "step mode changed tokens or step-count timing");
        assert_eq!((b.engine_steps, p.engine_steps), (4, 4));
        assert_eq!((b.decoded_tokens, p.decoded_tokens), (12, 12));
        assert_eq!(b.decode_calls, 4, "one forward per batched step");
        assert_eq!(p.decode_calls, 12, "one forward per slot-token per-slot");
        assert!((b.tokens_per_step() - 3.0).abs() < 1e-12);
        assert!((p.tokens_per_step() - 1.0).abs() < 1e-12);
        for (_, ttft, wait) in &bo {
            assert_eq!((*ttft, *wait), (1, 0), "all three admit at step 0, emit at step 1");
        }

        // chunked prefill accounting: 6-token prompts under chunk 2 pay
        // 2 pure prefill chunks per slot before emitting
        let (c, co) = run_mode(StepMode::Batched, 2);
        assert_eq!(co.iter().map(|(o, _, _)| o.clone()).collect::<Vec<_>>(),
                   bo.iter().map(|(o, _, _)| o.clone()).collect::<Vec<_>>(),
                   "chunked prefill changed tokens");
        assert_eq!(c.prefill_chunks, 6, "2 chunks per slot");
        assert_eq!(c.engine_steps, 6, "2 prefill steps + 4 decode steps");
        assert_eq!(c.decode_calls, 6, "still one batched forward per step");
        assert_eq!(c.decoded_tokens, 12);
        for (_, ttft, _) in &co {
            assert_eq!(*ttft, 3, "2 prefill steps push the first token to step 3");
        }
        assert_eq!(b.prefill_chunks, 0);
    }

    // ---- overload control ----

    #[test]
    fn queue_cap_sheds_typed_and_default_is_unbounded() {
        let mut e = dense_engine(90, 1).with_queue_cap(2);
        let a = e.try_submit(GenRequest::new(0, vec![1, 2], 2)).unwrap();
        let b = e.try_submit(GenRequest::new(1, vec![3, 4], 2)).unwrap();
        assert!(a.rejection().is_none());
        assert!(b.rejection().is_none());
        let shed = e.try_submit(GenRequest::new(2, vec![5, 6], 2)).unwrap();
        assert_eq!(shed.rejection(), Some(Rejected::QueueFull { queue_cap: 2 }));
        assert!(shed.session().is_none(), "a shed request gets no session");
        // the plain-submit wrapper folds shedding into an error
        assert!(e.submit(GenRequest::new(3, vec![7], 2)).is_err());
        // one step admits one request; the freed queue space readmits
        e.step().unwrap();
        assert_eq!(e.queued(), 1);
        assert!(e.try_submit(GenRequest::new(4, vec![8], 2)).unwrap().rejection().is_none());
        drain(&mut e);

        // queue_cap 0 (the default) never sheds: the legacy contract
        let mut e = dense_engine(90, 1);
        for id in 0..32 {
            assert!(e.try_submit(GenRequest::new(id, vec![1], 1)).unwrap().rejection().is_none());
        }
        assert_eq!(e.queued(), 32);
        drain(&mut e);
    }

    #[test]
    fn infeasible_deadline_is_shed_at_submit() {
        // chunk 4 over a 20-token prompt needs 5 steps before the first
        // token can exist — a tighter deadline is dead on arrival
        let prompt: Vec<u8> = (0..20).map(|i| (i * 3 + 1) as u8).collect();
        let mut e = dense_engine(91, 1).with_prefill_chunk(4);
        let req = GenRequest::new(0, prompt.clone(), 4).with_deadline_steps(3);
        let out = e.try_submit(req).unwrap();
        assert_eq!(
            out.rejection(),
            Some(Rejected::DeadlineInfeasible { deadline_steps: 3, min_steps: 5 })
        );
        // exactly-feasible admits (it may still expire mid-decode later)
        let req = GenRequest::new(1, prompt, 4).with_deadline_steps(5);
        assert!(e.try_submit(req).unwrap().rejection().is_none());
        // unchunked prefill needs one step, so deadline 1 is feasible
        let mut e = dense_engine(91, 1);
        let req = GenRequest::new(2, vec![1, 2, 3], 4).with_deadline_steps(1);
        assert!(e.try_submit(req).unwrap().rejection().is_none());
    }

    #[test]
    fn deadline_expiry_frees_the_slot_and_keeps_serving() {
        // active expiry: 3 allowed steps out of a 10-token budget —
        // the request retires with partial output and Outcome::Expired,
        // and the queued request admits in the SAME step
        let mut e = dense_engine(92, 1);
        let s0 = e.submit(GenRequest::new(0, vec![5, 6, 7], 10).with_deadline_steps(3)).unwrap();
        let s1 = e.submit(GenRequest::new(1, vec![9, 8, 7], 2)).unwrap();
        for _ in 0..3 {
            assert!(e.step().unwrap().is_empty());
        }
        assert!(!s0.is_finished());
        let done = e.step().unwrap();
        assert_eq!(done.len(), 1, "expiry resolves in this step");
        assert_eq!(done[0].outcome, Outcome::Expired);
        assert_eq!(done[0].tokens_generated, 3, "partial output survives");
        assert_eq!(done[0].total_steps, 3);
        assert!(s0.is_finished());
        assert_eq!(e.core.active[0].id, 1, "freed slot readmitted the same step");
        drain(&mut e);
        let r1 = s1.response().unwrap();
        assert_eq!(r1.outcome, Outcome::Completed);
        // token identity: the survivor matches an isolated run
        let mut iso = dense_engine(92, 1);
        let ri = iso.submit(GenRequest::new(1, vec![9, 8, 7], 2)).unwrap();
        drain(&mut iso);
        assert_eq!(r1.output, ri.response().unwrap().output);

        // queued expiry: behind a long-running slot, a 2-step deadline
        // expires in the queue with no tokens and no slot ever held
        let mut e = dense_engine(92, 1);
        let _busy = e.submit(GenRequest::new(0, vec![1, 2], 20)).unwrap();
        let sq = e.submit(GenRequest::new(1, vec![3, 4], 5).with_deadline_steps(2)).unwrap();
        e.step().unwrap();
        e.step().unwrap();
        assert!(!sq.is_finished(), "one full step waited, deadline not yet reached");
        e.step().unwrap();
        let rq = sq.response().expect("queued request expired");
        assert_eq!(rq.outcome, Outcome::Expired);
        assert_eq!(rq.tokens_generated, 0);
        assert_eq!(rq.queue_wait_steps, 2);
        assert_eq!(e.queued(), 0);
        drain(&mut e);
    }

    /// A sink that buffers into shared storage with a raisable capacity:
    /// below capacity it reports `Ready`, at capacity `Blocked` — the
    /// poll path only unblocks after the "consumer" raises the cap.
    struct GatedSink {
        buf: Rc<RefCell<Vec<u8>>>,
        cap: Rc<std::cell::Cell<usize>>,
    }
    impl TokenSink for GatedSink {
        fn on_token(&mut self, tok: u8) -> SinkStatus {
            self.buf.borrow_mut().push(tok);
            if self.buf.borrow().len() >= self.cap.get() {
                SinkStatus::Blocked
            } else {
                SinkStatus::Ready
            }
        }
        fn poll(&mut self) -> SinkStatus {
            if self.buf.borrow().len() >= self.cap.get() {
                SinkStatus::Blocked
            } else {
                SinkStatus::Ready
            }
        }
    }

    #[test]
    fn blocked_sink_pauses_the_slot_until_drained_tokens_unchanged() {
        let buf = Rc::new(RefCell::new(Vec::new()));
        let cap = Rc::new(std::cell::Cell::new(2usize));
        let mut e = dense_engine(93, 2);
        let slow = e
            .submit_with_sink(
                GenRequest::new(0, vec![1, 2, 3], 6),
                Box::new(GatedSink { buf: Rc::clone(&buf), cap: Rc::clone(&cap) }),
            )
            .unwrap();
        let fast = e.submit(GenRequest::new(1, vec![4, 5, 6], 6)).unwrap();
        // two steps fill the gated sink to capacity; the slot pauses
        for _ in 0..2 {
            e.step().unwrap();
        }
        assert_eq!(buf.borrow().len(), 2);
        assert!(e.core.active.iter().any(|s| s.id == 0 && s.paused));
        // further steps advance only the other slot — the paused one
        // holds its KV but receives no allocation
        for _ in 0..4 {
            e.step().unwrap();
        }
        assert_eq!(buf.borrow().len(), 2, "no tokens while blocked");
        assert!(fast.is_finished());
        assert!(!slow.is_finished());
        // "consumer" drains: raise capacity, the poll sweep unpauses,
        // and the stream finishes byte-identical to an ungated run
        cap.set(usize::MAX);
        drain(&mut e);
        let got = slow.response().unwrap();
        assert_eq!(got.outcome, Outcome::Completed);
        let mut iso = dense_engine(93, 2);
        let r = iso.submit(GenRequest::new(0, vec![1, 2, 3], 6)).unwrap();
        iso.submit(GenRequest::new(1, vec![4, 5, 6], 6)).unwrap();
        drain(&mut iso);
        assert_eq!(got.output, r.response().unwrap().output, "backpressure changed tokens");
        assert_eq!(*buf.borrow(), got.output, "sink saw every token exactly once");
    }

    #[test]
    fn closed_sink_cancels_the_request_and_frees_the_slot() {
        let seen = Rc::new(RefCell::new(Vec::new()));
        let sink_seen = Rc::clone(&seen);
        let mut e = dense_engine(94, 1);
        let sess = e
            .submit_with_sink(
                GenRequest::new(0, vec![2, 4, 6], 10),
                Box::new(move |t: u8| {
                    let mut s = sink_seen.borrow_mut();
                    s.push(t);
                    if s.len() >= 3 { SinkStatus::Closed } else { SinkStatus::Ready }
                }),
            )
            .unwrap();
        let mut done = Vec::new();
        let mut guard = 0;
        while done.is_empty() {
            done = e.step().unwrap();
            guard += 1;
            assert!(guard < 100, "closed sink never cancelled");
        }
        assert_eq!(done[0].outcome, Outcome::Cancelled);
        assert_eq!(done[0].tokens_generated, 3, "closed after the third token");
        assert!(sess.is_finished());
        assert_eq!(e.active_count(), 0, "slot and KV freed the same step");
        assert_eq!(sess.streamed(), *seen.borrow());
    }

    // ---- scheduler progress-contract errors (recoverable) ----

    /// Refuses to admit anything: trips the admission progress contract.
    struct NoAdmit;
    impl Scheduler for NoAdmit {
        fn name(&self) -> &'static str {
            "no-admit"
        }
        fn admit(&mut self, _queue: &[QueuedView]) -> Option<usize> {
            None
        }
        fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
            (0..slots.len().min(budget)).collect()
        }
    }

    /// Admits FIFO but never allocates a decode: trips the allocation
    /// progress contract.
    struct NoAlloc;
    impl Scheduler for NoAlloc {
        fn name(&self) -> &'static str {
            "no-alloc"
        }
        fn admit(&mut self, _queue: &[QueuedView]) -> Option<usize> {
            Some(0)
        }
        fn allocate(&mut self, _slots: &[SlotView], _budget: usize) -> Vec<usize> {
            Vec::new()
        }
    }

    #[test]
    fn stalling_scheduler_is_a_recoverable_error_not_a_panic() {
        // admission stall: typed error, engine state untouched, and a
        // scheduler swap resumes serving the SAME queued requests
        let mut e = dense_engine(95, 1).with_scheduler(Box::new(NoAdmit));
        let sess = e.submit(GenRequest::new(0, vec![1, 2], 3)).unwrap();
        let err = e.step().unwrap_err();
        assert_eq!(err, StepError::AdmissionStalled { scheduler: "no-admit", queued: 1 });
        assert_eq!(e.queued(), 1, "failed step mutated nothing");
        assert_eq!(e.step().unwrap_err(), err, "stall persists until repaired");
        e.set_scheduler(Box::new(Fifo::new()));
        drain(&mut e);
        assert_eq!(sess.response().unwrap().outcome, Outcome::Completed);

        // allocation stall: same contract on the decode side
        let mut e = dense_engine(95, 1).with_scheduler(Box::new(NoAlloc));
        let sess = e.submit(GenRequest::new(0, vec![1, 2], 3)).unwrap();
        let err = e.step().unwrap_err();
        assert_eq!(err, StepError::AllocationStalled { scheduler: "no-alloc", active: 1 });
        e.set_scheduler(Box::new(Fifo::new()));
        drain(&mut e);
        assert_eq!(sess.response().unwrap().tokens_generated, 3);

        // cancel is the other recovery path: shedding the queue clears
        // an admission stall without touching the scheduler
        let mut e = dense_engine(95, 1).with_scheduler(Box::new(NoAdmit));
        let sess = e.submit(GenRequest::new(7, vec![1], 3)).unwrap();
        assert!(e.step().is_err());
        e.cancel(7).expect("queued request cancels");
        assert_eq!(sess.response().unwrap().outcome, Outcome::Cancelled);
        assert!(e.step().unwrap().is_empty(), "engine is healthy again");
    }

    /// Misallocates (an out-of-range slot index) only on its fourth
    /// allocation call, behaving FIFO otherwise.
    struct FlakyAlloc {
        calls: usize,
    }
    impl Scheduler for FlakyAlloc {
        fn name(&self) -> &'static str {
            "flaky-alloc"
        }
        fn admit(&mut self, _queue: &[QueuedView]) -> Option<usize> {
            Some(0)
        }
        fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
            self.calls += 1;
            if self.calls == 4 {
                vec![slots.len() + 7]
            } else {
                (0..slots.len().min(budget)).collect()
            }
        }
    }

    #[test]
    fn bad_scheduler_indices_are_typed_errors_and_responses_carry_over() {
        // admit out of range
        struct BadAdmit;
        impl Scheduler for BadAdmit {
            fn name(&self) -> &'static str {
                "bad-admit"
            }
            fn admit(&mut self, queue: &[QueuedView]) -> Option<usize> {
                Some(queue.len())
            }
            fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
                (0..slots.len().min(budget)).collect()
            }
        }
        let mut e = dense_engine(96, 1).with_scheduler(Box::new(BadAdmit));
        e.submit(GenRequest::new(0, vec![1], 2)).unwrap();
        assert_eq!(
            e.step().unwrap_err(),
            StepError::BadQueueIndex { scheduler: "bad-admit", index: 1, len: 1 }
        );

        // over budget
        struct Greedy;
        impl Scheduler for Greedy {
            fn name(&self) -> &'static str {
                "greedy"
            }
            fn admit(&mut self, _queue: &[QueuedView]) -> Option<usize> {
                Some(0)
            }
            fn allocate(&mut self, slots: &[SlotView], _budget: usize) -> Vec<usize> {
                (0..slots.len()).collect()
            }
        }
        let mut e = dense_engine(96, 2).with_scheduler(Box::new(Greedy)).with_step_budget(1);
        e.submit(GenRequest::new(0, vec![1], 2)).unwrap();
        e.submit(GenRequest::new(1, vec![2], 2)).unwrap();
        assert_eq!(
            e.step().unwrap_err(),
            StepError::OverBudget { scheduler: "greedy", allocated: 2, budget: 1 }
        );

        // a response resolved by a step that then errors is NOT lost:
        // it carries over to the next successful step. Deadline 3 and
        // FlakyAlloc's fourth call both land on step call 4.
        let mut e = dense_engine(96, 1).with_scheduler(Box::new(FlakyAlloc { calls: 0 }));
        let doomed = e.submit(GenRequest::new(0, vec![1, 2], 9).with_deadline_steps(3)).unwrap();
        let after = e.submit(GenRequest::new(1, vec![3, 4], 2)).unwrap();
        for _ in 0..3 {
            e.step().unwrap();
        }
        // this step expires id 0 FIRST (resolving it), then admits id 1
        // and hits the bad allocation — typed error, response carried
        let err = e.step().unwrap_err();
        assert!(matches!(err, StepError::BadSlotIndex { scheduler: "flaky-alloc", .. }));
        assert!(doomed.is_finished(), "expiry resolved despite the failed step");
        let done = e.step().unwrap();
        assert_eq!(done.len(), 1, "carried response delivered exactly once");
        assert_eq!(done[0].id, 0);
        assert_eq!(done[0].outcome, Outcome::Expired);
        drain(&mut e);
        assert_eq!(after.response().unwrap().outcome, Outcome::Completed);
    }

    // ---- admission-order identity (batched queue compaction) ----

    /// Admits the middle of the queue view — an index-sensitive policy
    /// that distinguishes remove-per-admit from any reordering.
    struct PickMiddle;
    impl Scheduler for PickMiddle {
        fn name(&self) -> &'static str {
            "pick-middle"
        }
        fn admit(&mut self, queue: &[QueuedView]) -> Option<usize> {
            Some(queue.len() / 2)
        }
        fn allocate(&mut self, slots: &[SlotView], budget: usize) -> Vec<usize> {
            (0..slots.len().min(budget)).collect()
        }
    }

    #[test]
    fn batched_compaction_reproduces_remove_per_admit_order() {
        // reference: the pre-compaction algorithm, literally — a view
        // list shrunk with remove(i) per admitted request
        let reference = |ids: &[u64], free: usize, pick: &dyn Fn(usize) -> usize| {
            let mut queue: Vec<u64> = ids.to_vec();
            let mut admitted = Vec::new();
            while admitted.len() < free && !queue.is_empty() {
                let i = pick(queue.len());
                admitted.push(queue.remove(i));
            }
            (admitted, queue)
        };
        let ids: Vec<u64> = (0..7).collect();
        let (want_active, want_queue) = reference(&ids, 3, &|len| len / 2);

        let mut e = dense_engine(97, 3).with_scheduler(Box::new(PickMiddle));
        for &id in &ids {
            e.submit(GenRequest::new(id, vec![id as u8 + 1, 2], 2)).unwrap();
        }
        e.step().unwrap();
        let got_active: Vec<u64> = e.core.active.iter().map(|s| s.id).collect();
        let got_queue: Vec<u64> = e.core.queue.iter().map(|q| q.req.id).collect();
        assert_eq!(got_active, want_active, "slot order differs from remove-per-admit");
        assert_eq!(got_queue, want_queue, "queue residue differs from remove-per-admit");
        drain(&mut e);

        // and with the stock schedulers, end-to-end responses are
        // identical across a deep backlog (Fifo admits in submit order)
        let mut e = dense_engine(97, 2);
        for id in 0..12u64 {
            e.submit(GenRequest::new(id, vec![id as u8 + 1, 3], 1)).unwrap();
        }
        let mut order = Vec::new();
        while e.pending() > 0 {
            for r in e.step().unwrap() {
                order.push(r.id);
            }
        }
        assert_eq!(order, (0..12u64).collect::<Vec<_>>(), "FIFO retirement order broke");
    }
}
