//! Cholesky decomposition, triangular solves, and SPD inversion.
//!
//! GPTQ/GPTVQ (paper §3.1, Algorithm 1 line 7) needs the *upper Cholesky
//! factor of the inverse Hessian*: `U` with `H^{-1} = U^T U`. We compute it
//! as: `L = chol(H)` (lower), invert via triangular solves, then
//! re-factorize the inverse. This mirrors the reference GPTQ implementation
//! (`torch.linalg.cholesky(torch.cholesky_inverse(chol(H)), upper=True)`)
//! and is numerically stabler than the OBQ row/column removal updates.

use crate::error::{Error, Result};
use crate::tensor::Matrix;

/// Lower Cholesky factor L of SPD matrix A (A = L L^T).
pub fn cholesky_lower(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape(format!("cholesky: {}x{} not square", a.rows(), a.cols())));
    }
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            // sum -= dot(L[i, :j], L[j, :j])
            let (li, lj) = (l.row(i), l.row(j));
            for p in 0..j {
                sum -= li[p] * lj[p];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::Linalg(format!(
                        "cholesky: non-positive pivot {sum:.3e} at {i} — matrix not PD (add damping)"
                    )));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        let lrow = l.row(i);
        for p in 0..i {
            sum -= lrow[p] * y[p];
        }
        y[i] = sum / lrow[i];
    }
    y
}

/// Solve U x = b for upper-triangular U (back substitution).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = b[i];
        let urow = u.row(i);
        for p in i + 1..n {
            sum -= urow[p] * x[p];
        }
        x[i] = sum / urow[i];
    }
    x
}

/// Inverse of an SPD matrix via Cholesky: A^{-1} = L^{-T} L^{-1}.
pub fn invert_spd(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    let l = cholesky_lower(a)?;
    let lt = l.transpose();
    let mut inv = Matrix::zeros(n, n);
    // Solve A x = e_i column by column.
    for i in 0..n {
        let mut e = vec![0.0; n];
        e[i] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&lt, &y);
        for (r, v) in x.into_iter().enumerate() {
            inv.set(r, i, v);
        }
    }
    // symmetrize to kill round-off drift
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (inv.get(i, j) + inv.get(j, i));
            inv.set(i, j, v);
            inv.set(j, i, v);
        }
    }
    Ok(inv)
}

/// The factor GPTQ's loop consumes: upper-triangular U with
/// `H^{-1} = U^T U`, computed as chol(invert_spd(H)) transposed.
pub fn cholesky_upper_of_inverse(h: &Matrix) -> Result<Matrix> {
    let hinv = invert_spd(h)?;
    let l = cholesky_lower(&hinv)?;
    Ok(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::prop::{assert_close, check};
    use crate::util::Rng;

    /// Random SPD matrix: A = B B^T + eps I.
    fn rand_spd(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = matmul(&b, &b.transpose());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + 0.5);
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        check("L L^T == A", 20, |rng| {
            let n = 1 + rng.below(12);
            let a = rand_spd(rng, n);
            let l = cholesky_lower(&a).map_err(|e| e.to_string())?;
            let rec = matmul(&l, &l.transpose());
            assert_close(rec.as_slice(), a.as_slice(), 1e-8, 1e-8, "reconstruct")
        });
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap(); // eig -1, 3
        assert!(cholesky_lower(&a).is_err());
    }

    #[test]
    fn cholesky_rejects_non_square() {
        let a = Matrix::zeros(2, 3);
        assert!(cholesky_lower(&a).is_err());
    }

    #[test]
    fn triangular_solves() {
        check("L (L^{-1} b) == b", 20, |rng| {
            let n = 1 + rng.below(10);
            let a = rand_spd(rng, n);
            let l = cholesky_lower(&a).map_err(|e| e.to_string())?;
            let b: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let y = solve_lower(&l, &b);
            let back = l.matvec(&y);
            assert_close(&back, &b, 1e-8, 1e-8, "lower")?;
            let u = l.transpose();
            let x = solve_upper(&u, &b);
            let back = u.matvec(&x);
            assert_close(&back, &b, 1e-8, 1e-8, "upper")
        });
    }

    #[test]
    fn spd_inverse() {
        check("A A^{-1} == I", 15, |rng| {
            let n = 1 + rng.below(10);
            let a = rand_spd(rng, n);
            let inv = invert_spd(&a).map_err(|e| e.to_string())?;
            let prod = matmul(&a, &inv);
            let eye = Matrix::identity(n);
            assert_close(prod.as_slice(), eye.as_slice(), 1e-7, 1e-7, "inv")
        });
    }

    #[test]
    fn upper_factor_of_inverse() {
        check("U^T U == H^{-1}", 15, |rng| {
            let n = 1 + rng.below(10);
            let h = rand_spd(rng, n);
            let u = cholesky_upper_of_inverse(&h).map_err(|e| e.to_string())?;
            // U must be upper triangular
            for i in 0..n {
                for j in 0..i {
                    if u.get(i, j).abs() > 1e-12 {
                        return Err(format!("not upper triangular at ({i},{j})"));
                    }
                }
            }
            let rec = matmul(&u.transpose(), &u);
            let hinv = invert_spd(&h).map_err(|e| e.to_string())?;
            assert_close(rec.as_slice(), hinv.as_slice(), 1e-7, 1e-6, "UTU")
        });
    }
}
