//! Symmetric eigendecomposition (cyclic Jacobi), symmetric pseudo-inverse,
//! and thin SVD built on it.
//!
//! Usage in GPTVQ: the EM M-step solves `c = (Σ H_i)^+ (Σ H_i x_i)` with a
//! Moore-Penrose pseudo-inverse of a d×d (or diagonal) sub-Hessian sum, and
//! the codebook-compression step (§3.3) takes an SVD of the `N_G × k`
//! codebook tensor slices. Sizes are small (d ≤ 8, k ≤ 256), so Jacobi's
//! O(n³) sweeps are more than fast enough and bulletproof numerically.

use crate::error::{Error, Result};
use crate::tensor::{matmul, Matrix};

/// Eigendecomposition of a symmetric matrix: returns (eigenvalues,
/// eigenvectors) with `A = V diag(w) V^T`, eigenvectors in columns of V,
/// sorted by descending eigenvalue.
pub fn jacobi_eigen_symmetric(a: &Matrix, max_sweeps: usize) -> Result<(Vec<f64>, Matrix)> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape("jacobi: not square".into()));
    }
    let mut m = a.clone();
    let mut v = Matrix::identity(n);
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    // total order: NaN from a degenerate input sorts to the tail instead
    // of panicking the unwrap mid-factorization
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let evals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut evecs = Matrix::zeros(n, n);
    for (newcol, &(_, oldcol)) in pairs.iter().enumerate() {
        for r in 0..n {
            evecs.set(r, newcol, v.get(r, oldcol));
        }
    }
    Ok((evals, evecs))
}

/// Moore-Penrose pseudo-inverse of a symmetric PSD matrix via eigen
/// truncation (eigenvalues below `rcond * max_eig` treated as zero).
pub fn pinv_symmetric(a: &Matrix, rcond: f64) -> Result<Matrix> {
    let n = a.rows();
    let (w, v) = jacobi_eigen_symmetric(a, 50)?;
    let wmax = w.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    let cutoff = rcond * wmax.max(1e-300);
    // A^+ = V diag(1/w) V^T over the kept spectrum
    let mut scaled = Matrix::zeros(n, n); // V diag(inv)
    for c in 0..n {
        let inv = if w[c].abs() > cutoff { 1.0 / w[c] } else { 0.0 };
        for r in 0..n {
            scaled.set(r, c, v.get(r, c) * inv);
        }
    }
    Ok(matmul(&scaled, &v.transpose()))
}

/// Thin SVD: A[m,n] = U[m,r] diag(s) V^T[r,n] with r = min(m,n), singular
/// values descending. Built from the eigendecomposition of the Gram matrix
/// of the smaller side.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix, // [n, r], columns are right singular vectors
}

pub fn svd_thin(a: &Matrix) -> Result<Svd> {
    let (m, n) = (a.rows(), a.cols());
    let r = m.min(n);
    if n <= m {
        // eigen of A^T A [n,n]
        let gram = matmul(&a.transpose(), a);
        let (w, v) = jacobi_eigen_symmetric(&gram, 60)?;
        let s: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
        // U = A V S^{-1}
        let av = matmul(a, &v);
        let mut u = Matrix::zeros(m, r);
        for c in 0..r {
            let inv = if s[c] > 1e-12 { 1.0 / s[c] } else { 0.0 };
            for row in 0..m {
                u.set(row, c, av.get(row, c) * inv);
            }
        }
        let mut vr = Matrix::zeros(n, r);
        for c in 0..r {
            for row in 0..n {
                vr.set(row, c, v.get(row, c));
            }
        }
        Ok(Svd { u, s: s[..r].to_vec(), v: vr })
    } else {
        // eigen of A A^T [m,m]; then V = A^T U S^{-1}
        let gram = matmul(a, &a.transpose());
        let (w, ufull) = jacobi_eigen_symmetric(&gram, 60)?;
        let s: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
        let atu = matmul(&a.transpose(), &ufull);
        let mut v = Matrix::zeros(n, r);
        for c in 0..r {
            let inv = if s[c] > 1e-12 { 1.0 / s[c] } else { 0.0 };
            for row in 0..n {
                v.set(row, c, atu.get(row, c) * inv);
            }
        }
        let mut u = Matrix::zeros(m, r);
        for c in 0..r {
            for row in 0..m {
                u.set(row, c, ufull.get(row, c));
            }
        }
        Ok(Svd { u, s: s[..r].to_vec(), v })
    }
}

impl Svd {
    /// Reconstruct with the top `rank` components: U[:, :rank] diag(s) V^T.
    pub fn reconstruct(&self, rank: usize) -> Matrix {
        let (m, n) = (self.u.rows(), self.v.rows());
        let rank = rank.min(self.s.len());
        let mut out = Matrix::zeros(m, n);
        for c in 0..rank {
            for i in 0..m {
                let uis = self.u.get(i, c) * self.s[c];
                if uis == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] += uis * self.v.get(j, c);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};
    use crate::util::Rng;

    fn rand_sym(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = matmul(&b, &b.transpose());
        a.scale(1.0 / n as f64);
        a
    }

    #[test]
    fn eigen_reconstructs() {
        check("V diag(w) V^T == A", 15, |rng| {
            let n = 1 + rng.below(8);
            let a = rand_sym(rng, n);
            let (w, v) = jacobi_eigen_symmetric(&a, 50).map_err(|e| e.to_string())?;
            let mut wd = Matrix::zeros(n, n);
            for i in 0..n {
                wd.set(i, i, w[i]);
            }
            let rec = matmul(&matmul(&v, &wd), &v.transpose());
            assert_close(rec.as_slice(), a.as_slice(), 1e-8, 1e-8, "eig")
        });
    }

    #[test]
    fn eigen_values_sorted_desc() {
        let mut rng = Rng::new(1);
        let a = rand_sym(&mut rng, 6);
        let (w, _) = jacobi_eigen_symmetric(&a, 50).unwrap();
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
    }

    #[test]
    fn eigen_orthonormal_vectors() {
        let mut rng = Rng::new(2);
        let a = rand_sym(&mut rng, 7);
        let (_, v) = jacobi_eigen_symmetric(&a, 50).unwrap();
        let vtv = matmul(&v.transpose(), &v);
        let eye = Matrix::identity(7);
        assert_close(vtv.as_slice(), eye.as_slice(), 1e-9, 1e-9, "orth").unwrap();
    }

    #[test]
    fn pinv_of_invertible_is_inverse() {
        check("pinv == inv for PD", 10, |rng| {
            let n = 1 + rng.below(6);
            let mut a = rand_sym(rng, n);
            for i in 0..n {
                a.set(i, i, a.get(i, i) + 1.0);
            }
            let p = pinv_symmetric(&a, 1e-12).map_err(|e| e.to_string())?;
            let prod = matmul(&a, &p);
            let eye = Matrix::identity(n);
            assert_close(prod.as_slice(), eye.as_slice(), 1e-7, 1e-7, "pinv")
        });
    }

    #[test]
    fn pinv_singular_satisfies_penrose() {
        // rank-1 PSD matrix: A = x x^T
        let x = [1.0, 2.0, -1.0];
        let a = Matrix::from_fn(3, 3, |i, j| x[i] * x[j]);
        let p = pinv_symmetric(&a, 1e-10).unwrap();
        // A P A == A (first Penrose condition)
        let apa = matmul(&matmul(&a, &p), &a);
        assert_close(apa.as_slice(), a.as_slice(), 1e-8, 1e-8, "penrose1").unwrap();
        // P A P == P
        let pap = matmul(&matmul(&p, &a), &p);
        assert_close(pap.as_slice(), p.as_slice(), 1e-8, 1e-8, "penrose2").unwrap();
    }

    #[test]
    fn svd_reconstructs_full_rank() {
        check("U S V^T == A", 12, |rng| {
            let m = 1 + rng.below(8);
            let n = 1 + rng.below(8);
            let a = Matrix::from_fn(m, n, |_, _| rng.gaussian());
            let svd = svd_thin(&a).map_err(|e| e.to_string())?;
            let rec = svd.reconstruct(svd.s.len());
            assert_close(rec.as_slice(), a.as_slice(), 1e-7, 1e-7, "svd")
        });
    }

    #[test]
    fn svd_singular_values_nonneg_desc() {
        let mut rng = Rng::new(3);
        let a = Matrix::from_fn(10, 4, |_, _| rng.gaussian());
        let svd = svd_thin(&a).unwrap();
        assert_eq!(svd.s.len(), 4);
        for i in 0..svd.s.len() {
            assert!(svd.s[i] >= 0.0);
            if i > 0 {
                assert!(svd.s[i - 1] >= svd.s[i] - 1e-10);
            }
        }
    }

    #[test]
    fn eigen_ordering_tolerates_nan_input() {
        // NaN-tolerance regression for the eigenvalue sort: a NaN
        // anywhere in the input (degenerate covariance, corrupted
        // Hessian) used to panic the partial_cmp().unwrap() comparator;
        // under total_cmp the factorization completes and NaN
        // eigenvalues sort deterministically to the descending tail
        let mut a = Matrix::identity(3);
        a.set(0, 1, f64::NAN);
        a.set(1, 0, f64::NAN);
        let (w, v) = jacobi_eigen_symmetric(&a, 5).expect("shape is valid; must not panic");
        assert_eq!(w.len(), 3);
        assert_eq!(v.rows(), 3);
        // NaNs, if any survived, are at the tail of the descending order
        let first_nan = w.iter().position(|x| x.is_nan());
        if let Some(p) = first_nan {
            assert!(w[p..].iter().all(|x| x.is_nan()), "NaN confined to the tail: {w:?}");
        }
    }

    #[test]
    fn svd_rank_truncation_is_best_approx_direction() {
        // rank-1 matrix recovers exactly with rank 1
        let u = [1.0, -2.0, 0.5];
        let v = [2.0, 1.0];
        let a = Matrix::from_fn(3, 2, |i, j| u[i] * v[j]);
        let svd = svd_thin(&a).unwrap();
        let rec = svd.reconstruct(1);
        assert_close(rec.as_slice(), a.as_slice(), 1e-9, 1e-9, "rank1").unwrap();
        assert!(svd.s[1] < 1e-9);
    }
}
