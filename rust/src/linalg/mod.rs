//! Dense linear algebra needed by GPTVQ: Cholesky machinery for the GPTQ /
//! GPTVQ Hessian loop, symmetric eigendecomposition (Jacobi) for
//! pseudo-inverses and SVD, and covariance/Mahalanobis statistics for the
//! EM seeding method.

mod chol;
mod eigen;
mod stats;

pub use chol::{cholesky_lower, cholesky_upper_of_inverse, invert_spd, solve_lower, solve_upper};
pub use eigen::{jacobi_eigen_symmetric, pinv_symmetric, svd_thin, Svd};
pub use stats::{covariance, mahalanobis_distances, mean_rows};
