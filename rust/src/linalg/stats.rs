//! Covariance and Mahalanobis statistics for the EM seeding method
//! (paper §4.3): points are sorted by Mahalanobis distance to the data
//! mean and sampled at equal spacing.

use crate::error::Result;
use crate::linalg::pinv_symmetric;
use crate::tensor::Matrix;

/// Column means of a data matrix [n, d].
pub fn mean_rows(x: &Matrix) -> Vec<f64> {
    let (n, d) = (x.rows(), x.cols());
    let mut mu = vec![0.0; d];
    for r in 0..n {
        for (m, v) in mu.iter_mut().zip(x.row(r)) {
            *m += v;
        }
    }
    let inv = if n > 0 { 1.0 / n as f64 } else { 0.0 };
    for m in &mut mu {
        *m *= inv;
    }
    mu
}

/// Sample covariance (biased, 1/n) of rows of x [n, d].
pub fn covariance(x: &Matrix) -> Matrix {
    let (n, d) = (x.rows(), x.cols());
    let mu = mean_rows(x);
    let mut cov = Matrix::zeros(d, d);
    for r in 0..n {
        let row = x.row(r);
        for i in 0..d {
            let di = row[i] - mu[i];
            for j in i..d {
                let dj = row[j] - mu[j];
                cov.set(i, j, cov.get(i, j) + di * dj);
            }
        }
    }
    let inv = if n > 0 { 1.0 / n as f64 } else { 0.0 };
    for i in 0..d {
        for j in i..d {
            let v = cov.get(i, j) * inv;
            cov.set(i, j, v);
            cov.set(j, i, v);
        }
    }
    cov
}

/// Squared Mahalanobis distance of every row to the mean:
/// `a_i = (x_i - mu)^T Sigma^+ (x_i - mu)`. Uses the pseudo-inverse so
/// degenerate (e.g. d=1 constant) data does not blow up.
pub fn mahalanobis_distances(x: &Matrix) -> Result<Vec<f64>> {
    let (n, d) = (x.rows(), x.cols());
    let mu = mean_rows(x);
    let mut cov = covariance(x);
    // tiny ridge for numerical safety
    let ridge = 1e-9 * (1.0 + cov.max_abs());
    for i in 0..d {
        cov.set(i, i, cov.get(i, i) + ridge);
    }
    let sinv = pinv_symmetric(&cov, 1e-12)?;
    let mut out = Vec::with_capacity(n);
    let mut centered = vec![0.0; d];
    for r in 0..n {
        let row = x.row(r);
        for i in 0..d {
            centered[i] = row[i] - mu[i];
        }
        let tmp = sinv.matvec(&centered);
        let dist: f64 = centered.iter().zip(&tmp).map(|(a, b)| a * b).sum();
        out.push(dist.max(0.0));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Rng;

    #[test]
    fn mean_simple() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(mean_rows(&x), vec![2.0, 3.0]);
    }

    #[test]
    fn covariance_of_decorrelated_axes() {
        let mut rng = Rng::new(4);
        // x ~ N(0, diag(1, 4))
        let x = Matrix::from_fn(20_000, 2, |_, c| rng.gaussian() * if c == 0 { 1.0 } else { 2.0 });
        let cov = covariance(&x);
        assert!((cov.get(0, 0) - 1.0).abs() < 0.1);
        assert!((cov.get(1, 1) - 4.0).abs() < 0.25);
        assert!(cov.get(0, 1).abs() < 0.1);
    }

    #[test]
    fn covariance_symmetric_psd_diag() {
        check("cov symmetric, diag >= 0", 10, |rng| {
            let n = 5 + rng.below(50);
            let d = 1 + rng.below(4);
            let x = Matrix::from_fn(n, d, |_, _| rng.gaussian() * 3.0);
            let cov = covariance(&x);
            for i in 0..d {
                if cov.get(i, i) < -1e-12 {
                    return Err("negative diagonal".into());
                }
                for j in 0..d {
                    if (cov.get(i, j) - cov.get(j, i)).abs() > 1e-12 {
                        return Err("asymmetric".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mahalanobis_is_scale_invariant() {
        // scaling an axis must not change Mahalanobis distances
        let mut rng = Rng::new(5);
        let base = Matrix::from_fn(500, 2, |_, _| rng.gaussian());
        let scaled = Matrix::from_fn(500, 2, |r, c| base.get(r, c) * if c == 0 { 10.0 } else { 1.0 });
        let da = mahalanobis_distances(&base).unwrap();
        let db = mahalanobis_distances(&scaled).unwrap();
        for (a, b) in da.iter().zip(&db) {
            assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn mahalanobis_mean_point_is_zero() {
        let mut rng = Rng::new(6);
        let mut x = Matrix::from_fn(101, 2, |_, _| rng.gaussian());
        let mu = mean_rows(&x);
        // put a point exactly at the mean
        x.row_mut(0).copy_from_slice(&mu);
        // (recompute since we modified; close enough for the assertion)
        let d = mahalanobis_distances(&x).unwrap();
        let min = d.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(d[0] <= min + 0.05);
    }

    #[test]
    fn mahalanobis_handles_degenerate_axis() {
        // one constant coordinate: covariance is singular; pinv handles it
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(100, 2, |_, c| if c == 0 { 5.0 } else { rng.gaussian() });
        let d = mahalanobis_distances(&x).unwrap();
        assert!(d.iter().all(|v| v.is_finite()));
    }
}
