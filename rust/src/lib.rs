//! # gptvq — reproduction of *GPTVQ: The Blessing of Dimensionality for
//! LLM Quantization* (van Baalen, Kuzmin, Nagel et al., 2024)
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: quantization pipeline, model
//!   evaluation, packed VQ formats and decode kernels, serving demo, CLI.
//! * **L2** — a JAX Llama-architecture byte LM, AOT-lowered to HLO text at
//!   build time (`python/compile/`), executed from rust via PJRT
//!   ([`runtime`]).
//! * **L1** — Pallas kernels (`vq_assign`, `vq_decode_matmul`) lowered into
//!   the same HLO artifacts; their semantics are mirrored natively in
//!   [`quant::vq`] and [`decode`] and cross-checked by integration tests.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod error;
pub mod eval;
pub mod linalg;
pub mod model;
// The documented core API: every `pub` item in these modules carries a
// doc comment, enforced by `#[warn(missing_docs)]` here and promoted to
// an error by CI's `RUSTDOCFLAGS="-D warnings" cargo doc --no-deps`.
#[warn(missing_docs)]
pub mod quant;
pub mod report;
pub mod runtime;
#[warn(missing_docs)]
pub mod serve;
#[warn(missing_docs)]
pub mod tensor;
pub mod util;
pub mod vqformat;

pub use error::{Error, Result};
