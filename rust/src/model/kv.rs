//! Per-sequence KV cache for incremental decoding.
//!
//! The serving path generates one token per step; recomputing the whole
//! context per step costs O(T) forwards of length T. The cache stores each
//! layer's key/value rows (RoPE already applied to K) so a step only runs
//! the new positions through the model — the standard KV-cache
//! transformation, done so that the cached logits match the
//! full-recompute logits bitwise (same row-wise float ops, same order).
//!
//! Layout per layer: row-major `[len, d_model]` growable buffers, the
//! `d_model` columns organized as `n_heads` blocks of `head_dim` — exactly
//! the projection layout of `forward.rs`, so attention indexes the cache
//! with the same `head * head_dim` offsets it uses for fresh rows.

use crate::model::ModelConfig;
use crate::tensor::Matrix;

/// The per-sequence KV-cache contract the forward pass decodes against.
///
/// Two implementations exist: the contiguous [`KvCache`] (one growable
/// buffer per layer — the parity oracle) and the pooled
/// [`crate::model::kvpool::PagedKvCache`] (page tables over a shared
/// arena, storage possibly quantized). `forward.rs` is generic over this
/// trait, so both run the *same* attention code.
///
/// Row reads take `&mut self`: quantized page stores dequantize into an
/// internal scratch row and lend it out, so a read may mutate scratch
/// state. The contiguous cache just reslices its buffer.
pub trait KvSeq {
    /// Number of committed positions (see [`KvCache::len`]).
    fn len(&self) -> usize;
    /// True when no positions are committed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Number of layers cached.
    fn n_layers(&self) -> usize;
    /// Drop all cached positions.
    fn clear(&mut self);
    /// Roll back to `n` committed positions (shrink-only).
    fn truncate(&mut self, n: usize);
    /// Append K/V rows for `layer` from flat `[s, d_model]` slices.
    fn append_rows(&mut self, layer: usize, k: &[f64], v: &[f64]);
    /// Commit `n` appended positions after every layer consumed them.
    fn advance(&mut self, n: usize);
    /// Exact resident bytes of the cached activations.
    fn memory_bytes(&self) -> usize;
    /// Borrow one cached key row `[d_model]` of `layer` (RoPE applied).
    fn k_row(&mut self, layer: usize, row: usize) -> &[f64];
    /// Borrow one cached value row `[d_model]` of `layer`.
    fn v_row(&mut self, layer: usize, row: usize) -> &[f64];
}

/// Cached keys and values for one layer.
#[derive(Debug, Clone, Default)]
pub struct LayerKv {
    /// keys, row-major [len, d_model], RoPE applied
    pub k: Vec<f64>,
    /// values, row-major [len, d_model]
    pub v: Vec<f64>,
}

/// KV cache across all layers of one sequence.
#[derive(Debug, Clone)]
pub struct KvCache {
    layers: Vec<LayerKv>,
    d_model: usize,
    len: usize,
}

impl KvCache {
    /// The contiguous per-sequence cache. Serving paths should obtain
    /// caches from the pool API ([`crate::model::kvpool`]); this
    /// constructor survives for the contexts where contiguous buffers
    /// are the point — parity oracles, benches, draft caches.
    #[deprecated(note = "serving paths allocate through model::kvpool; \
                         use KvCache::oracle where a contiguous reference cache is the point")]
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::oracle(cfg)
    }

    /// The contiguous cache as the parity/bench **oracle**: one growable
    /// f64 buffer per layer, no pooling, no quantization. Also the
    /// engine's backing when no KV pool is configured.
    pub fn oracle(cfg: &ModelConfig) -> KvCache {
        KvCache {
            layers: (0..cfg.n_layers).map(|_| LayerKv::default()).collect(),
            d_model: cfg.d_model,
            len: 0,
        }
    }

    /// Number of cached positions. Layer buffers may run ahead of this
    /// mid-forward (rows are appended layer by layer before
    /// [`KvCache::advance`] commits them).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Drop all cached positions (the sequence's context window slid).
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Roll back to `n` committed positions, dropping the newer K/V rows
    /// of every layer — how speculative decode discards the cache
    /// positions of rejected draft tokens. A no-op when `n >= len`; must
    /// only be called between forwards (all layers committed).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len {
            return;
        }
        for (li, l) in self.layers.iter_mut().enumerate() {
            debug_assert_eq!(l.k.len(), self.len * self.d_model, "layer {li} mid-forward");
            l.k.truncate(n * self.d_model);
            l.v.truncate(n * self.d_model);
        }
        self.len = n;
    }

    /// Append freshly projected K/V rows ([s, d_model] each) for `layer`.
    pub fn append(&mut self, layer: usize, k: &Matrix, v: &Matrix) {
        debug_assert_eq!(k.cols(), self.d_model);
        debug_assert_eq!(v.cols(), self.d_model);
        debug_assert_eq!(k.rows(), v.rows());
        self.append_rows(layer, k.as_slice(), v.as_slice());
    }

    /// Append K/V rows for `layer` from flat `[s, d_model]` slices — the
    /// batched forward carves one slot's row range out of a stacked
    /// projection matrix and appends it here without copying through an
    /// intermediate per-slot `Matrix`.
    pub fn append_rows(&mut self, layer: usize, k: &[f64], v: &[f64]) {
        debug_assert_eq!(k.len() % self.d_model, 0);
        debug_assert_eq!(k.len(), v.len());
        let l = &mut self.layers[layer];
        debug_assert_eq!(l.k.len(), self.len * self.d_model, "layer {layer} appended twice");
        l.k.extend_from_slice(k);
        l.v.extend_from_slice(v);
    }

    /// Borrow a layer's cached (keys, values) as flat [len', d_model] rows.
    #[inline]
    pub fn layer(&self, layer: usize) -> (&[f64], &[f64]) {
        let l = &self.layers[layer];
        (&l.k, &l.v)
    }

    /// Commit `n` appended positions after every layer consumed them.
    pub fn advance(&mut self, n: usize) {
        self.len += n;
        for (li, l) in self.layers.iter().enumerate() {
            debug_assert_eq!(l.k.len(), self.len * self.d_model, "layer {li} out of sync");
            debug_assert_eq!(l.v.len(), self.len * self.d_model, "layer {li} out of sync");
        }
    }

    /// Exact resident bytes of the cached activations (the serving
    /// memory budget). Counts `len`, not `capacity`: growth slack is
    /// allocator-dependent and summing capacities over-reported the
    /// budget by up to 2× after doubling.
    pub fn memory_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.k.len() + l.v.len()) * std::mem::size_of::<f64>())
            .sum()
    }
}

impl KvSeq for KvCache {
    fn len(&self) -> usize {
        KvCache::len(self)
    }
    fn n_layers(&self) -> usize {
        KvCache::n_layers(self)
    }
    fn clear(&mut self) {
        KvCache::clear(self)
    }
    fn truncate(&mut self, n: usize) {
        KvCache::truncate(self, n)
    }
    fn append_rows(&mut self, layer: usize, k: &[f64], v: &[f64]) {
        KvCache::append_rows(self, layer, k, v)
    }
    fn advance(&mut self, n: usize) {
        KvCache::advance(self, n)
    }
    fn memory_bytes(&self) -> usize {
        KvCache::memory_bytes(self)
    }
    fn k_row(&mut self, layer: usize, row: usize) -> &[f64] {
        let l = &self.layers[layer];
        &l.k[row * self.d_model..(row + 1) * self.d_model]
    }
    fn v_row(&mut self, layer: usize, row: usize) -> &[f64] {
        let l = &self.layers[layer];
        &l.v[row * self.d_model..(row + 1) * self.d_model]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{forward_logits, forward_logits_cached};
    use crate::model::forward::tests::tiny_model;
    use crate::util::prop::assert_close;

    #[test]
    fn bookkeeping_append_advance_clear() {
        let m = tiny_model(31);
        let mut cache = KvCache::oracle(&m.cfg);
        assert!(cache.is_empty());
        assert_eq!(cache.n_layers(), m.cfg.n_layers);
        let k = Matrix::zeros(3, m.cfg.d_model);
        let v = Matrix::zeros(3, m.cfg.d_model);
        for li in 0..cache.n_layers() {
            cache.append(li, &k, &v);
        }
        cache.advance(3);
        assert_eq!(cache.len(), 3);
        assert!(cache.memory_bytes() > 0);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.layer(0).0.len(), 0);
    }

    #[test]
    fn prefill_matches_full_forward() {
        let m = tiny_model(32);
        let toks: Vec<u8> = (0..12).map(|i| (i * 19 + 3) as u8).collect();
        let full = forward_logits(&m, &toks);
        let mut cache = KvCache::oracle(&m.cfg);
        let cached = forward_logits_cached(&m, &mut cache, &toks);
        assert_eq!(cache.len(), toks.len());
        assert_eq!((cached.rows(), cached.cols()), (full.rows(), full.cols()));
        assert_close(cached.as_slice(), full.as_slice(), 1e-12, 1e-12, "prefill").unwrap();
    }

    #[test]
    fn incremental_decode_matches_full_recompute() {
        // the tentpole parity requirement: token-by-token cached logits
        // equal the full-recompute logits to 1e-6 (they match bitwise —
        // the row-wise float ops are identical — but 1e-6 is the contract)
        let m = tiny_model(33);
        let toks: Vec<u8> = (0..16).map(|i| (i * 37 + 11) as u8).collect();
        let mut cache = KvCache::oracle(&m.cfg);
        // prefill on the first 4 tokens, then extend one token at a time
        forward_logits_cached(&m, &mut cache, &toks[..4]);
        let mut last_logits = None;
        for t in 4..toks.len() {
            last_logits = Some(forward_logits_cached(&m, &mut cache, &toks[t..t + 1]));
        }
        let inc = last_logits.unwrap();
        assert_eq!(inc.rows(), 1);
        let full = forward_logits(&m, &toks);
        let want = full.row(full.rows() - 1);
        assert_close(inc.row(0), want, 1e-6, 1e-6, "incremental").unwrap();
    }

    #[test]
    fn truncate_rolls_back_to_a_consistent_state() {
        // speculative decode's rollback: extend the cache past the
        // accepted stream, truncate, then re-extend with the *accepted*
        // tokens — logits must match a cache that never saw the rejects
        let m = tiny_model(35);
        let toks: Vec<u8> = (0..12).map(|i| (i * 23 + 5) as u8).collect();
        let rejects: Vec<u8> = vec![250, 251, 252];
        let mut cache = KvCache::oracle(&m.cfg);
        forward_logits_cached(&m, &mut cache, &toks[..8]);
        // speculate 3 wrong tokens, then roll them back
        forward_logits_cached(&m, &mut cache, &rejects);
        assert_eq!(cache.len(), 11);
        cache.truncate(8);
        assert_eq!(cache.len(), 8);
        // truncate is shrink-only
        cache.truncate(100);
        assert_eq!(cache.len(), 8);
        let after = forward_logits_cached(&m, &mut cache, &toks[8..]);
        let full = forward_logits(&m, &toks);
        for r in 0..after.rows() {
            assert_close(after.row(r), full.row(8 + r), 1e-12, 1e-12, "rollback").unwrap();
        }
    }

    #[test]
    fn memory_bytes_reports_exact_resident_bytes() {
        // regression: memory_bytes summed Vec::capacity, so doubling
        // slack inflated the reported budget by up to 2×. It must equal
        // len-derived bytes exactly — including after truncate, where
        // capacity stays large but residency shrinks, and after clear.
        let m = tiny_model(36);
        let mut cache = KvCache::oracle(&m.cfg);
        let exact = |positions: usize| {
            // k + v, per layer, d_model f64s per row
            positions * m.cfg.d_model * 2 * m.cfg.n_layers * std::mem::size_of::<f64>()
        };
        assert_eq!(cache.memory_bytes(), 0);
        let k = Matrix::zeros(7, m.cfg.d_model);
        let v = Matrix::zeros(7, m.cfg.d_model);
        for li in 0..cache.n_layers() {
            cache.append(li, &k, &v);
        }
        cache.advance(7);
        assert_eq!(cache.memory_bytes(), exact(7));
        // truncate keeps capacity; the report must track len
        cache.truncate(2);
        assert_eq!(cache.memory_bytes(), exact(2));
        cache.clear();
        assert_eq!(cache.memory_bytes(), 0);
        // trait dispatch agrees with the inherent method
        let dyn_bytes = <KvCache as KvSeq>::memory_bytes(&cache);
        assert_eq!(dyn_bytes, 0);
    }

    #[test]
    fn kv_seq_rows_match_layer_slices() {
        // the trait's row reads are exactly the contiguous layer slices
        let m = tiny_model(37);
        let toks: Vec<u8> = (0..9).map(|i| (i * 29 + 3) as u8).collect();
        let mut cache = KvCache::oracle(&m.cfg);
        forward_logits_cached(&m, &mut cache, &toks);
        let d = m.cfg.d_model;
        for li in 0..cache.n_layers() {
            for row in 0..cache.len() {
                let (k_all, v_all) = {
                    let (k, v) = cache.layer(li);
                    (k[row * d..(row + 1) * d].to_vec(), v[row * d..(row + 1) * d].to_vec())
                };
                assert_eq!(cache.k_row(li, row), &k_all[..]);
                assert_eq!(cache.v_row(li, row), &v_all[..]);
            }
        }
    }

    #[test]
    fn chunked_extension_matches_full_forward_rows() {
        let m = tiny_model(34);
        let toks: Vec<u8> = (0..10).map(|i| (i * 5 + 2) as u8).collect();
        let full = forward_logits(&m, &toks);
        let mut cache = KvCache::oracle(&m.cfg);
        forward_logits_cached(&m, &mut cache, &toks[..6]);
        let tail = forward_logits_cached(&m, &mut cache, &toks[6..]);
        assert_eq!(tail.rows(), 4);
        for r in 0..4 {
            assert_close(tail.row(r), full.row(6 + r), 1e-9, 1e-9, "chunk").unwrap();
        }
    }
}
