//! Native rust forward pass of the Llama-architecture byte LM.
//!
//! Exactly mirrors `python/compile/model.py` (RMSNorm eps 1e-5, split-half
//! RoPE with theta 10000, causal softmax, SwiGLU, untied head) so that
//! logits cross-check against the AOT HLO executed via PJRT — an
//! integration test asserts this. Supports an activation hook used by the
//! coordinator to accumulate per-linear-layer Hessians (inputs to Wq/Wk/Wv,
//! Wo, WGate/WUp, WDown).

use crate::model::kv::{KvCache, KvSeq};
use crate::model::{LinearKind, Model};
use crate::tensor::{matmul, Matrix};

/// Observer of linear-layer inputs during a forward pass. Called once per
/// (layer, kind) with the activation matrix [seq, in_dim].
pub type ActivationHook<'a> = &'a mut dyn FnMut(usize, LinearKind, &Matrix);

/// Strategy for applying a (possibly compressed) linear layer: given the
/// activation matrix `x [s, in]`, produce `x @ W [s, out]` in storage
/// layout. The serving backends implement this — dense matmul for
/// decoded weights, fused LUT decode-matmul for packed VQ containers —
/// so one forward pass serves every execution mode.
pub trait LinearApply {
    fn apply(&self, layer: usize, kind: LinearKind, x: &Matrix) -> Matrix;
}

/// Dense weights straight from the `Model` (the default execution mode).
pub struct DenseLinears<'a>(pub &'a Model);

impl LinearApply for DenseLinears<'_> {
    fn apply(&self, layer: usize, kind: LinearKind, x: &Matrix) -> Matrix {
        matmul(x, self.0.linear(layer, kind))
    }
}

fn rmsnorm(x: &Matrix, weight: &[f64], eps: f64) -> Matrix {
    let (s, d) = (x.rows(), x.cols());
    assert_eq!(d, weight.len());
    let mut out = Matrix::zeros(s, d);
    for r in 0..s {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..d {
            orow[c] = row[c] * inv * weight[c];
        }
    }
    out
}

/// Apply split-half RoPE in place to a [seq, d_model] matrix organized as
/// n_heads blocks of head_dim columns.
fn apply_rope(x: &mut Matrix, n_heads: usize, head_dim: usize, theta: f64) {
    apply_rope_offset(x, n_heads, head_dim, theta, 0)
}

/// RoPE with a position offset: row `r` rotates as absolute position
/// `pos0 + r` — what incremental decode needs for rows appended behind a
/// KV cache. `pos0 = 0` reproduces [`apply_rope`] exactly.
fn apply_rope_offset(x: &mut Matrix, n_heads: usize, head_dim: usize, theta: f64, pos0: usize) {
    apply_rope_rows(x, 0, x.rows(), n_heads, head_dim, theta, pos0)
}

/// RoPE over the row range `row0 .. row0 + rows` of a stacked matrix:
/// row `row0 + r` rotates as absolute position `pos0 + r`. The batched
/// forward rotates each slot's slice of the stacked Q/K projection at
/// that slot's own KV offset; the arithmetic per row is identical to
/// [`apply_rope_offset`], so a slot's rows come out bitwise the same
/// whether it was batched or forwarded alone.
fn apply_rope_rows(
    x: &mut Matrix,
    row0: usize,
    rows: usize,
    n_heads: usize,
    head_dim: usize,
    theta: f64,
    pos0: usize,
) {
    let half = head_dim / 2;
    // precompute cos/sin per (row, j) at the absolute position
    let mut cos = vec![0.0; rows * half];
    let mut sin = vec![0.0; rows * half];
    for r in 0..rows {
        for j in 0..half {
            let freq = theta.powf(-(j as f64) / half as f64);
            let ang = (pos0 + r) as f64 * freq;
            cos[r * half + j] = ang.cos();
            sin[r * half + j] = ang.sin();
        }
    }
    for pos in 0..rows {
        let row = x.row_mut(row0 + pos);
        for h in 0..n_heads {
            let base = h * head_dim;
            for j in 0..half {
                let c = cos[pos * half + j];
                let s = sin[pos * half + j];
                let x1 = row[base + j];
                let x2 = row[base + half + j];
                row[base + j] = x1 * c - x2 * s;
                row[base + half + j] = x2 * c + x1 * s;
            }
        }
    }
}

fn softmax_rows_causal(scores: &mut Matrix) {
    let s = scores.rows();
    for q in 0..s {
        let row = scores.row_mut(q);
        // causal: keys > q are masked
        let mut mx = f64::NEG_INFINITY;
        for item in row.iter().take(q + 1) {
            mx = mx.max(*item);
        }
        let mut sum = 0.0;
        for (k, item) in row.iter_mut().enumerate() {
            if k <= q {
                *item = (*item - mx).exp();
                sum += *item;
            } else {
                *item = 0.0;
            }
        }
        let inv = 1.0 / sum;
        for item in row.iter_mut().take(q + 1) {
            *item *= inv;
        }
    }
}

fn silu(v: f64) -> f64 {
    v / (1.0 + (-v).exp())
}

/// Forward one sequence of token ids; returns logits [seq, vocab].
/// `hook` observes every linear layer's input (for Hessian capture).
pub fn forward_logits_hook(model: &Model, tokens: &[u8], mut hook: Option<ActivationHook>) -> Matrix {
    let cfg = &model.cfg;
    let (s, d) = (tokens.len(), cfg.d_model);
    let (nh, hd) = (cfg.n_heads, cfg.head_dim());
    let scale = 1.0 / (hd as f64).sqrt();

    // embedding lookup
    let mut x = Matrix::zeros(s, d);
    for (r, &t) in tokens.iter().enumerate() {
        x.row_mut(r).copy_from_slice(model.embed.row(t as usize));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // ---- attention ----
        let h = rmsnorm(&x, &layer.ln_attn, cfg.norm_eps);
        if let Some(hk) = hook.as_mut() {
            hk(li, LinearKind::Wq, &h);
            hk(li, LinearKind::Wk, &h);
            hk(li, LinearKind::Wv, &h);
        }
        let mut q = matmul(&h, &layer.wq);
        let mut k = matmul(&h, &layer.wk);
        let v = matmul(&h, &layer.wv);
        apply_rope(&mut q, nh, hd, cfg.rope_theta);
        apply_rope(&mut k, nh, hd, cfg.rope_theta);

        let mut attn_out = Matrix::zeros(s, d);
        for head in 0..nh {
            let c0 = head * hd;
            // scores [s, s] for this head
            let mut scores = Matrix::zeros(s, s);
            for qi in 0..s {
                let qrow = &q.row(qi)[c0..c0 + hd];
                for ki in 0..=qi {
                    let krow = &k.row(ki)[c0..c0 + hd];
                    let dot: f64 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                    scores.set(qi, ki, dot * scale);
                }
            }
            softmax_rows_causal(&mut scores);
            for qi in 0..s {
                let out_row = attn_out.row_mut(qi);
                for ki in 0..=qi {
                    let p = scores.get(qi, ki);
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(ki)[c0..c0 + hd];
                    for (t, &vv) in vrow.iter().enumerate() {
                        out_row[c0 + t] += p * vv;
                    }
                }
            }
        }
        if let Some(hk) = hook.as_mut() {
            hk(li, LinearKind::Wo, &attn_out);
        }
        let proj = matmul(&attn_out, &layer.wo);
        x.add_assign(&proj);

        // ---- ffn ----
        let h = rmsnorm(&x, &layer.ln_ffn, cfg.norm_eps);
        if let Some(hk) = hook.as_mut() {
            hk(li, LinearKind::WGate, &h);
            hk(li, LinearKind::WUp, &h);
        }
        let g = matmul(&h, &layer.w_gate);
        let u = matmul(&h, &layer.w_up);
        let mut act = Matrix::zeros(s, cfg.d_ffn);
        for r in 0..s {
            let (gr, ur) = (g.row(r), u.row(r));
            let arow = act.row_mut(r);
            for c in 0..cfg.d_ffn {
                arow[c] = silu(gr[c]) * ur[c];
            }
        }
        if let Some(hk) = hook.as_mut() {
            hk(li, LinearKind::WDown, &act);
        }
        let down = matmul(&act, &layer.w_down);
        x.add_assign(&down);
    }

    let xn = rmsnorm(&x, &model.final_norm, cfg.norm_eps);
    matmul(&xn, &model.head)
}

/// Forward without hooks.
pub fn forward_logits(model: &Model, tokens: &[u8]) -> Matrix {
    forward_logits_hook(model, tokens, None)
}

/// One sequence's slice of a ragged cross-slot batch: the new tokens to
/// run and the KV cache they extend. The batched forward stacks every
/// item's tokens into one activation matrix (item `i`'s rows are
/// contiguous, in item order) while attention, RoPE, and the KV append
/// stay per-item — each slot sees only its own cache, at its own
/// position offset (`cache.len()` at entry). Generic over the cache
/// backing ([`KvSeq`]): the contiguous oracle and the paged/quantized
/// pool handles run the identical forward. The default keeps plain
/// `BatchItem<'_>` meaning the contiguous cache.
pub struct BatchItem<'a, C: KvSeq = KvCache> {
    /// KV cache holding this sequence's committed positions; extended in
    /// place by the batched forward
    pub cache: &'a mut C,
    /// new tokens to forward for this sequence (must be non-empty)
    pub tokens: &'a [u8],
}

/// Ragged cross-slot batched forward: run every item's `tokens` through
/// the model in ONE pass, attending each item over its own `cache`
/// (extended in place). All linear layers are applied to the stacked
/// `[sum(tokens), d]` activation matrix through `lin`, so a fused-VQ
/// backend pays one weight decode per linear for the whole batch instead
/// of one per slot. Every op outside the linears (rmsnorm, RoPE, silu,
/// attention, the final head) is row- or item-local and every `lin`
/// implementation computes output rows independently, so each item's
/// logits and cache rows are bitwise identical to a dedicated
/// [`forward_logits_cached_with`] call — the engine's batched step
/// leans on exactly this. Returns stacked logits `[sum(tokens), vocab]`
/// with item `i`'s rows at offset `sum(len of items 0..i)`.
pub fn forward_logits_batched_with<C: KvSeq>(
    model: &Model,
    lin: &impl LinearApply,
    items: &mut [BatchItem<'_, C>],
) -> Matrix {
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let (nh, hd) = (cfg.n_heads, cfg.head_dim());
    let scale = 1.0 / (hd as f64).sqrt();
    assert!(!items.is_empty(), "forward_logits_batched_with: empty batch");
    let mut row0s = Vec::with_capacity(items.len());
    let mut starts = Vec::with_capacity(items.len());
    let mut rows_total = 0usize;
    let mut max_total = 0usize;
    for it in items.iter() {
        assert!(!it.tokens.is_empty(), "forward_logits_batched_with: empty token slice");
        assert_eq!(it.cache.n_layers(), cfg.n_layers, "cache built for another model");
        row0s.push(rows_total);
        starts.push(it.cache.len());
        rows_total += it.tokens.len();
        max_total = max_total.max(it.cache.len() + it.tokens.len());
    }
    // softmax-scores scratch for the whole forward, one slab per head:
    // row `qi` of item `i` uses slots 0..starts[i]+qi+1 of its head's
    // slab. Hoisted here so the attention loop below stays
    // allocation-free (it is a detlint hot region). Stale slots beyond
    // a row's `total` are never read — every slot read in passes 2–3
    // was written in pass 1 of the same (item, row) iteration.
    let mut scores = vec![0.0f64; nh * max_total];

    // stacked embedding lookup: item i occupies rows row0s[i]..+len
    let mut x = Matrix::zeros(rows_total, d);
    let mut r = 0;
    for it in items.iter() {
        for &t in it.tokens {
            x.row_mut(r).copy_from_slice(model.embed.row(t as usize));
            r += 1;
        }
    }

    for li in 0..cfg.n_layers {
        // ---- attention ----
        let h = rmsnorm(&x, &model.layers[li].ln_attn, cfg.norm_eps);
        let mut q = lin.apply(li, LinearKind::Wq, &h);
        let mut k = lin.apply(li, LinearKind::Wk, &h);
        let v = lin.apply(li, LinearKind::Wv, &h);
        // rotate and append per item: each slot's rows rotate at its own
        // absolute positions and land in its own cache
        for (i, it) in items.iter_mut().enumerate() {
            let (r0, s) = (row0s[i], it.tokens.len());
            apply_rope_rows(&mut q, r0, s, nh, hd, cfg.rope_theta, starts[i]);
            apply_rope_rows(&mut k, r0, s, nh, hd, cfg.rope_theta, starts[i]);
            it.cache.append_rows(
                li,
                &k.as_slice()[r0 * d..(r0 + s) * d],
                &v.as_slice()[r0 * d..(r0 + s) * d],
            );
        }

        let mut attn_out = Matrix::zeros(rows_total, d);
        // detlint: hot(attn-page-read) — the cache-row read loop runs
        // once per (item, position, key) per layer per step; paged
        // stores dequantize into the cache's preallocated scratch row
        // here, so the whole region must stay allocation-free (the
        // scores scratch is hoisted above the layer loop). Three passes
        // per query row — K dots, per-head softmax, V accumulation —
        // fetch each cached row exactly once for all heads; the float
        // ops and their order are identical to the per-(head, row)
        // structure they replaced, so logits are bitwise unchanged.
        for (i, it) in items.iter_mut().enumerate() {
            let (r0, s, start) = (row0s[i], it.tokens.len(), starts[i]);
            for qi in 0..s {
                let total = start + qi + 1; // causal: keys 0..=start+qi
                for ki in 0..total {
                    let krow = it.cache.k_row(li, ki);
                    for head in 0..nh {
                        let c0 = head * hd;
                        let qrow = &q.row(r0 + qi)[c0..c0 + hd];
                        let dot: f64 =
                            qrow.iter().zip(&krow[c0..c0 + hd]).map(|(a, b)| a * b).sum();
                        scores[head * max_total + ki] = dot * scale;
                    }
                }
                // softmax over the visible keys (same op order as the
                // full pass's softmax_rows_causal for bitwise parity)
                for head in 0..nh {
                    let sc = &mut scores[head * max_total..head * max_total + total];
                    let mut mx = f64::NEG_INFINITY;
                    for v in sc.iter() {
                        mx = mx.max(*v);
                    }
                    let mut sum = 0.0;
                    for v in sc.iter_mut() {
                        *v = (*v - mx).exp();
                        sum += *v;
                    }
                    let inv = 1.0 / sum;
                    for v in sc.iter_mut() {
                        *v *= inv;
                    }
                }
                for ki in 0..total {
                    let vrow = it.cache.v_row(li, ki);
                    let out_row = attn_out.row_mut(r0 + qi);
                    for head in 0..nh {
                        let p = scores[head * max_total + ki];
                        if p == 0.0 {
                            continue;
                        }
                        let c0 = head * hd;
                        for (t, &vv) in vrow[c0..c0 + hd].iter().enumerate() {
                            out_row[c0 + t] += p * vv;
                        }
                    }
                }
            }
        }
        // detlint: endhot
        let proj = lin.apply(li, LinearKind::Wo, &attn_out);
        x.add_assign(&proj);

        // ---- ffn ----
        let h = rmsnorm(&x, &model.layers[li].ln_ffn, cfg.norm_eps);
        let g = lin.apply(li, LinearKind::WGate, &h);
        let u = lin.apply(li, LinearKind::WUp, &h);
        let mut act = Matrix::zeros(rows_total, cfg.d_ffn);
        for r in 0..rows_total {
            let (gr, ur) = (g.row(r), u.row(r));
            let arow = act.row_mut(r);
            for c in 0..cfg.d_ffn {
                arow[c] = silu(gr[c]) * ur[c];
            }
        }
        let down = lin.apply(li, LinearKind::WDown, &act);
        x.add_assign(&down);
    }
    for it in items.iter_mut() {
        it.cache.advance(it.tokens.len());
    }

    let xn = rmsnorm(&x, &model.final_norm, cfg.norm_eps);
    matmul(&xn, &model.head)
}

/// Incremental forward pass: run only `new_tokens` through the model,
/// attending over `cache` (which is extended in place). With an empty
/// cache this is a prefill whose logits match [`forward_logits`] bitwise;
/// afterwards each call appends `new_tokens.len()` positions. The linears
/// are applied through `lin`, so the same code drives the dense and the
/// fused-VQ serving backends. This is exactly the one-item case of
/// [`forward_logits_batched_with`] — per-slot and batched stepping share
/// one forward implementation, which is what makes the engine's
/// cross-slot batching token-identical by construction. Returns logits
/// `[new_tokens.len(), vocab]`.
pub fn forward_logits_cached_with<C: KvSeq>(
    model: &Model,
    lin: &impl LinearApply,
    cache: &mut C,
    new_tokens: &[u8],
) -> Matrix {
    forward_logits_batched_with(model, lin, &mut [BatchItem { cache, tokens: new_tokens }])
}

/// Incremental forward over the model's own dense weights.
pub fn forward_logits_cached(model: &Model, cache: &mut impl KvSeq, new_tokens: &[u8]) -> Matrix {
    forward_logits_cached_with(model, &DenseLinears(model), cache, new_tokens)
}

/// Per-token next-token negative log-likelihood: position t predicts
/// token t+1; returns seq-1 values.
pub fn nll_per_token(model: &Model, tokens: &[u8]) -> Vec<f64> {
    let logits = forward_logits(model, tokens);
    nll_from_logits(&logits, tokens)
}

/// NLL given precomputed logits (shared by the PJRT path).
pub fn nll_from_logits(logits: &Matrix, tokens: &[u8]) -> Vec<f64> {
    let s = tokens.len();
    let mut out = Vec::with_capacity(s - 1);
    for t in 0..s - 1 {
        let row = logits.row(t);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        out.push(lse - row[tokens[t + 1] as usize]);
    }
    out
}

/// Sum of log-probabilities of `completion` tokens given `prompt` —
/// the zero-shot choice-scoring primitive (LM-eval-harness style).
pub fn completion_logprob(model: &Model, prompt: &[u8], completion: &[u8]) -> f64 {
    let mut tokens = Vec::with_capacity(prompt.len() + completion.len());
    tokens.extend_from_slice(prompt);
    tokens.extend_from_slice(completion);
    let nll = nll_per_token(model, &tokens);
    // completion tokens are predicted at positions prompt.len()-1 ..
    let start = prompt.len() - 1;
    -nll[start..].iter().sum::<f64>()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::Rng;

    pub(crate) fn tiny_model(seed: u64) -> Model {
        Model::synthetic(ModelConfig::demo(32), seed)
    }

    #[test]
    fn logits_shape_and_finite() {
        let m = tiny_model(1);
        let toks: Vec<u8> = (0..10).map(|i| (i * 17) as u8).collect();
        let logits = forward_logits(&m, &toks);
        assert_eq!(logits.rows(), 10);
        assert_eq!(logits.cols(), 256);
        assert!(logits.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let m = tiny_model(2);
        let mut toks: Vec<u8> = (0..12).map(|i| (i * 7 + 3) as u8).collect();
        let base = forward_logits(&m, &toks);
        toks[8] = toks[8].wrapping_add(13);
        let pert = forward_logits(&m, &toks);
        for t in 0..8 {
            crate::util::prop::assert_close(base.row(t), pert.row(t), 1e-10, 1e-10, "pre")
                .unwrap();
        }
        let post_diff: f64 = (8..12)
            .map(|t| {
                base.row(t)
                    .iter()
                    .zip(pert.row(t))
                    .map(|(a, b)| (a - b).abs())
                    .sum::<f64>()
            })
            .sum();
        assert!(post_diff > 1e-6, "future tokens must change");
    }

    #[test]
    fn rope_zero_position_identity() {
        let mut x = Matrix::from_fn(1, 8, |_, c| c as f64);
        let orig = x.clone();
        apply_rope(&mut x, 2, 4, 10000.0);
        crate::util::prop::assert_close(x.row(0), orig.row(0), 1e-12, 1e-12, "pos0").unwrap();
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(3);
        let mut x = Matrix::from_fn(6, 16, |_, _| rng.gaussian());
        let before: Vec<f64> = (0..6)
            .map(|r| x.row(r).iter().map(|v| v * v).sum::<f64>())
            .collect();
        apply_rope(&mut x, 2, 8, 10000.0);
        let after: Vec<f64> = (0..6)
            .map(|r| x.row(r).iter().map(|v| v * v).sum::<f64>())
            .collect();
        crate::util::prop::assert_close(&after, &before, 1e-9, 1e-9, "norm").unwrap();
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut rng = Rng::new(4);
        let mut s = Matrix::from_fn(5, 5, |_, _| rng.gaussian());
        softmax_rows_causal(&mut s);
        for q in 0..5 {
            let sum: f64 = s.row(q).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
            for k in q + 1..5 {
                assert_eq!(s.get(q, k), 0.0, "future not masked");
            }
        }
    }

    #[test]
    fn nll_consistency_with_logits() {
        let m = tiny_model(5);
        let toks: Vec<u8> = vec![1, 50, 100, 150, 200];
        let nll = nll_per_token(&m, &toks);
        assert_eq!(nll.len(), 4);
        assert!(nll.iter().all(|v| *v > 0.0 && v.is_finite()));
        // near-uniform logits -> nll near ln(256)
        let avg = nll.iter().sum::<f64>() / 4.0;
        assert!((avg - (256f64).ln()).abs() < 1.0);
    }

    #[test]
    fn hook_sees_all_linears_with_right_shapes() {
        let m = tiny_model(6);
        let toks: Vec<u8> = (0..8).collect();
        let mut seen = std::collections::HashMap::new();
        let mut hook = |li: usize, kind: LinearKind, x: &Matrix| {
            seen.insert((li, kind), (x.rows(), x.cols()));
        };
        forward_logits_hook(&m, &toks, Some(&mut hook));
        assert_eq!(seen.len(), 2 * 7);
        assert_eq!(seen[&(0, LinearKind::Wq)], (8, 16));
        assert_eq!(seen[&(1, LinearKind::WDown)], (8, 24));
    }

    #[test]
    fn completion_logprob_prefers_likely() {
        let m = tiny_model(7);
        let prompt: Vec<u8> = (10..20).collect();
        // score all single-byte completions; the argmax of the logits at
        // the last prompt position must win. Routed through the shared
        // NaN-filtered helper — the inlined
        // max_by(partial_cmp().unwrap()) it replaced panicked on NaN.
        let logits = forward_logits(&m, &prompt);
        let last = logits.row(prompt.len() - 1);
        let best = crate::serve::argmax_logits(last);
        let lp_best = completion_logprob(&m, &prompt, &[best]);
        let lp_other = completion_logprob(&m, &prompt, &[best.wrapping_add(7)]);
        assert!(lp_best > lp_other);
    }

    #[test]
    fn batched_ragged_prefill_is_bitwise_identical_to_per_slot() {
        // three sequences of different lengths in ONE ragged batched
        // call: every logit row and every cached K/V row must equal the
        // dedicated single-slot forwards bit for bit
        let m = tiny_model(41);
        let seqs: Vec<Vec<u8>> = vec![
            (0..7).map(|i| (i * 13 + 2) as u8).collect(),
            (0..3).map(|i| (i * 29 + 7) as u8).collect(),
            (0..11).map(|i| (i * 5 + 1) as u8).collect(),
        ];
        let mut ref_caches: Vec<KvCache> = seqs.iter().map(|_| KvCache::oracle(&m.cfg)).collect();
        let ref_logits: Vec<Matrix> = seqs
            .iter()
            .zip(ref_caches.iter_mut())
            .map(|(s, c)| forward_logits_cached(&m, c, s))
            .collect();

        let mut caches: Vec<KvCache> = seqs.iter().map(|_| KvCache::oracle(&m.cfg)).collect();
        let mut items: Vec<BatchItem> = caches
            .iter_mut()
            .zip(&seqs)
            .map(|(cache, s)| BatchItem { cache, tokens: s })
            .collect();
        let logits = forward_logits_batched_with(&m, &DenseLinears(&m), &mut items);
        drop(items);

        assert_eq!(logits.rows(), seqs.iter().map(Vec::len).sum::<usize>());
        let mut r0 = 0;
        for (i, s) in seqs.iter().enumerate() {
            for r in 0..s.len() {
                assert_eq!(logits.row(r0 + r), ref_logits[i].row(r), "logits row drifted");
            }
            r0 += s.len();
            assert_eq!(caches[i].len(), ref_caches[i].len());
            for li in 0..caches[i].n_layers() {
                let (k, v) = caches[i].layer(li);
                let (rk, rv) = ref_caches[i].layer(li);
                assert_eq!(k, rk, "cached K drifted (item {i}, layer {li})");
                assert_eq!(v, rv, "cached V drifted (item {i}, layer {li})");
            }
        }
    }

    #[test]
    fn batched_step_with_mixed_kv_offsets_is_bitwise_identical() {
        // a realistic engine batch: slot A mid-decode (1 token behind a
        // deep cache), slot B mid-prefill (a 3-token chunk behind a
        // partial cache), slot C fresh prefill — one ragged call vs
        // three dedicated ones, compared bitwise
        let m = tiny_model(42);
        let a: Vec<u8> = (0..9).map(|i| (i * 31 + 4) as u8).collect();
        let b: Vec<u8> = (0..8).map(|i| (i * 17 + 9) as u8).collect();
        let c: Vec<u8> = (0..5).map(|i| (i * 11 + 6) as u8).collect();

        let setup = |cache: &mut KvCache| {
            forward_logits_cached(&m, cache, &a[..8]); // A: cache depth 8
        };
        let setup_b = |cache: &mut KvCache| {
            forward_logits_cached(&m, cache, &b[..4]); // B: cache depth 4
        };

        let mut ra = KvCache::oracle(&m.cfg);
        let mut rb = KvCache::oracle(&m.cfg);
        let mut rc = KvCache::oracle(&m.cfg);
        setup(&mut ra);
        setup_b(&mut rb);
        let la = forward_logits_cached(&m, &mut ra, &a[8..]);
        let lb = forward_logits_cached(&m, &mut rb, &b[4..7]);
        let lc = forward_logits_cached(&m, &mut rc, &c);

        let mut ba = KvCache::oracle(&m.cfg);
        let mut bb = KvCache::oracle(&m.cfg);
        let mut bc = KvCache::oracle(&m.cfg);
        setup(&mut ba);
        setup_b(&mut bb);
        let logits = forward_logits_batched_with(
            &m,
            &DenseLinears(&m),
            &mut [
                BatchItem { cache: &mut ba, tokens: &a[8..] },
                BatchItem { cache: &mut bb, tokens: &b[4..7] },
                BatchItem { cache: &mut bc, tokens: &c },
            ],
        );
        assert_eq!(logits.rows(), 1 + 3 + 5);
        assert_eq!(logits.row(0), la.row(0));
        for r in 0..3 {
            assert_eq!(logits.row(1 + r), lb.row(r));
        }
        for r in 0..5 {
            assert_eq!(logits.row(4 + r), lc.row(r));
        }
        assert_eq!((ba.len(), bb.len(), bc.len()), (ra.len(), rb.len(), rc.len()));
        for (got, want) in [(&ba, &ra), (&bb, &rb), (&bc, &rc)] {
            for li in 0..got.n_layers() {
                assert_eq!(got.layer(li), want.layer(li), "cache drifted at layer {li}");
            }
        }
    }

    #[test]
    fn argmax_over_forward_logits_tolerates_nan() {
        // regression for the NaN-unsafe inlined argmax this file used to
        // carry: poisoning any losing logit must not panic or flip the
        // winner, because argmax_logits filters NaN before comparing
        let m = tiny_model(7);
        let prompt: Vec<u8> = (10..20).collect();
        let logits = forward_logits(&m, &prompt);
        let mut last = logits.row(prompt.len() - 1).to_vec();
        let clean = crate::serve::argmax_logits(&last);
        let victim = (clean as usize + 1) % last.len();
        last[victim] = f64::NAN;
        assert_eq!(crate::serve::argmax_logits(&last), clean);
        // even an all-NaN row must stay total: falls back, no panic
        let poisoned = vec![f64::NAN; last.len()];
        let _ = crate::serve::argmax_logits(&poisoned);
    }
}
