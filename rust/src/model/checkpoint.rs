//! GVQCKPT1 checkpoint container — rust reader/writer for the JAX→rust
//! weight interchange format (mirror of `python/compile/checkpoint.py`).
//!
//! Layout (little-endian): magic `GVQCKPT1`, u32 tensor count, then per
//! tensor: u16 name length, utf-8 name, u8 dtype (0=f32 1=i32 2=u8 3=u16),
//! u8 ndim, ndim×u32 dims, raw data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"GVQCKPT1";

/// Raw tensor as stored: shape + one of the supported payloads.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
    U16(Vec<u16>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(if self.shape.is_empty() { 1 } else { 0 })
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => Err(Error::msg(format!("expected f32 tensor, got {other:?}"))),
        }
    }
}

/// An ordered named-tensor collection.
pub type Checkpoint = BTreeMap<String, Tensor>;

fn read_exact(r: &mut impl Read, n: usize, path: &str) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)
        .map_err(|e| Error::format(path, format!("truncated read of {n} bytes: {e}")))?;
    Ok(buf)
}

fn rd_u16(r: &mut impl Read, path: &str) -> Result<u16> {
    Ok(u16::from_le_bytes(read_exact(r, 2, path)?.try_into().unwrap()))
}

fn rd_u32(r: &mut impl Read, path: &str) -> Result<u32> {
    Ok(u32::from_le_bytes(read_exact(r, 4, path)?.try_into().unwrap()))
}

/// Load a checkpoint from disk.
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path_str = path.as_ref().display().to_string();
    let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
    let magic = read_exact(&mut f, 8, &path_str)?;
    if magic != MAGIC {
        return Err(Error::format(&path_str, format!("bad magic {magic:?}")));
    }
    let count = rd_u32(&mut f, &path_str)?;
    let mut out = Checkpoint::new();
    for _ in 0..count {
        let name_len = rd_u16(&mut f, &path_str)? as usize;
        let name = String::from_utf8(read_exact(&mut f, name_len, &path_str)?)
            .map_err(|e| Error::format(&path_str, format!("bad tensor name: {e}")))?;
        let meta = read_exact(&mut f, 2, &path_str)?;
        let (dtype, ndim) = (meta[0], meta[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(rd_u32(&mut f, &path_str)? as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(usize::from(ndim == 0));
        let data = match dtype {
            0 => {
                let raw = read_exact(&mut f, numel * 4, &path_str)?;
                TensorData::F32(
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                )
            }
            1 => {
                let raw = read_exact(&mut f, numel * 4, &path_str)?;
                TensorData::I32(
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                )
            }
            2 => TensorData::U8(read_exact(&mut f, numel, &path_str)?),
            3 => {
                let raw = read_exact(&mut f, numel * 2, &path_str)?;
                TensorData::U16(
                    raw.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect(),
                )
            }
            other => return Err(Error::format(&path_str, format!("unknown dtype {other}"))),
        };
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

/// Write a checkpoint (used by tests and by `gptvq quantize --emit-dense`).
pub fn save(path: impl AsRef<Path>, tensors: &Checkpoint) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let dtype: u8 = match &t.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::U8(_) => 2,
            TensorData::U16(_) => 3,
        };
        f.write_all(&[dtype, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::U8(v) => f.write_all(v)?,
            TensorData::U16(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gptvq_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_mixed() {
        let mut ck = Checkpoint::new();
        ck.insert(
            "w".into(),
            Tensor { shape: vec![2, 3], data: TensorData::F32(vec![1.0, -2.0, 0.5, 3.0, 4.0, -0.25]) },
        );
        ck.insert("idx".into(), Tensor { shape: vec![4], data: TensorData::I32(vec![1, -2, 3, 4]) });
        ck.insert("bytes".into(), Tensor { shape: vec![3], data: TensorData::U8(vec![0, 128, 255]) });
        ck.insert("codes".into(), Tensor { shape: vec![2], data: TensorData::U16(vec![777, 65535]) });
        let p = tmpfile("roundtrip");
        save(&p, &ck).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("badmagic");
        std::fs::write(&p, b"NOTMAGIC....").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let mut ck = Checkpoint::new();
        ck.insert("w".into(), Tensor { shape: vec![10], data: TensorData::F32(vec![0.0; 10]) });
        let p = tmpfile("trunc");
        save(&p, &ck).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 8]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scalar_tensor() {
        let mut ck = Checkpoint::new();
        ck.insert("s".into(), Tensor { shape: vec![], data: TensorData::F32(vec![2.5]) });
        let p = tmpfile("scalar");
        save(&p, &ck).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back["s"].shape, Vec::<usize>::new());
        assert_eq!(back["s"].as_f32().unwrap(), &[2.5]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn reads_python_written_checkpoint_if_present() {
        // integration with the build-time artifacts (skipped when absent)
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/model_tiny.ckpt");
        if !path.exists() {
            eprintln!("skipping: {path:?} not built");
            return;
        }
        let ck = load(&path).unwrap();
        assert!(ck.contains_key("embed"));
        assert!(ck.contains_key("head"));
        assert!(ck.contains_key("layers.0.attn.wq"));
        let embed = &ck["embed"];
        assert_eq!(embed.shape.len(), 2);
        assert_eq!(embed.shape[0], 256); // byte vocab
    }
}
